# Tier-1 verification (see ROADMAP.md) and helpers.
PYTHON ?= python

.PHONY: test test-fast bench install

install:
	$(PYTHON) -m pip install -r requirements.txt

# the tier-1 command, verbatim
test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q

# skip the slow launch/distributed suites during development
test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q \
		tests/core tests/kernels tests/substrate

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m benchmarks.run

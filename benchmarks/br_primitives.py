"""Paper Table 2 / §5: BR-CR primitive microbenchmarks.

Every BR configuration the 7 applications use, timed per strategy:
push (baseline Alg. 1), segment (Alg. 2), ell (Alg. 3 blocked pull),
onehot (MXU formulation). The paper reports BR speedups of 1.72×–34×; the
analogue here is ell/segment-vs-push per config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_coo, gspmm, planner
from repro.core.binary_reduce import parse_op
from repro.data import rmat_graph

from .common import time_fn, row

# the exact configurations from the paper's Table 2
CONFIGS = [
    "u_copy_add_v",        # GCN/SAGE/GCMC/LGNN/RGCN
    "u_mul_e_add_v",       # MoNet, GAT
    "e_copy_add_v",        # GAT
    "e_copy_max_v",        # GAT
    "u_add_v_copy_e",      # GAT
    "e_sub_v_copy_e",      # GAT
    "e_div_v_copy_e",      # GAT
    "v_mul_e_copy_e",      # GAT
    "u_dot_v_add_e",       # GCMC
]

STRATEGIES = ("push", "segment", "ell", "auto")


def main(d: int = 128, strategy: str = None):
    src, dst, n = rmat_graph(15, 200_000, seed=3)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    # packs come from the planner's per-graph cache (built once, shared
    # between the pinned-ell sweep and the auto mode)
    planner.get_plan_cache(g).ell()
    nnz = g.n_edges
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    V = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    E = jnp.asarray(rng.normal(size=(nnz, d)).astype(np.float32))

    strategies = (STRATEGIES if strategy is None
                  else tuple(dict.fromkeys(("push", strategy))))
    for name in CONFIGS:
        times = {}
        for s in strategies:
            if name.endswith("_e") and s == "ell":
                continue   # edge-output configs have no blocked-pull stage
            fn = jax.jit(lambda u, v, e, s=s, nm=name:
                         gspmm(g, nm, u=u, v=v, e=e, strategy=s))
            # auto rows feed drift: measured median lands next to the
            # plan row's predicted cost (keyed by the canonical spec)
            op = parse_op(name).name if s == "auto" else None
            times[s] = time_fn(fn, U, V, E, iters=5, warmup=2, op=op)
        base = times["push"]
        optimized = [k for k in times if k != "push"]
        best_name = (min(optimized, key=lambda k: times[k])
                     if optimized else None)
        sp = base / times[best_name] if best_name else 1.0
        for s, t in times.items():
            tag = (f"speedup={sp:.2f}x({best_name})"
                   if s == best_name else "")
            if s == "auto":
                chosen = planner.last_plan(name) or "edge-order"
                tag = f"plan={chosen}" + (f";{tag}" if tag else "")
            print(row(f"br_{name}_{s}", t, tag))


if __name__ == "__main__":
    main()

"""Paper Table 2 / §5: BR-CR primitive microbenchmarks.

Every BR configuration the 7 applications use, timed per strategy:
push (baseline Alg. 1), segment (Alg. 2), ell (Alg. 3 blocked pull),
onehot (MXU formulation). The paper reports BR speedups of 1.72×–34×; the
analogue here is ell/segment-vs-push per config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_coo, gspmm, build_ell, build_tiles
from repro.data import rmat_graph

from .common import time_fn, row

# the exact configurations from the paper's Table 2
CONFIGS = [
    "u_copy_add_v",        # GCN/SAGE/GCMC/LGNN/RGCN
    "u_mul_e_add_v",       # MoNet, GAT
    "e_copy_add_v",        # GAT
    "e_copy_max_v",        # GAT
    "u_add_v_copy_e",      # GAT
    "e_sub_v_copy_e",      # GAT
    "e_div_v_copy_e",      # GAT
    "v_mul_e_copy_e",      # GAT
    "u_dot_v_add_e",       # GCMC
]

STRATEGIES = ("push", "segment", "ell")


def main(d: int = 128):
    src, dst, n = rmat_graph(15, 200_000, seed=3)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    ell = build_ell(g)
    nnz = g.n_edges
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    V = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    E = jnp.asarray(rng.normal(size=(nnz, d)).astype(np.float32))

    for name in CONFIGS:
        times = {}
        for strategy in STRATEGIES:
            if name.endswith("_e") and strategy in ("ell",):
                continue   # edge-output configs have no blocked-pull stage
            kw = {"ell": ell} if strategy == "ell" else {}
            fn = jax.jit(lambda u, v, e, s=strategy, nm=name, kw=kw:
                         gspmm(g, nm, u=u, v=v, e=e, strategy=s, **kw))
            times[strategy] = time_fn(fn, U, V, E, iters=5, warmup=2)
        base = times["push"]
        best_name = min((k for k in times if k != "push"),
                        key=lambda k: times[k])
        sp = base / times[best_name]
        for strategy, t in times.items():
            tag = (f"speedup={sp:.2f}x({best_name})"
                   if strategy == best_name else "")
            print(row(f"br_{name}_{strategy}", t, tag))


if __name__ == "__main__":
    main()

"""Benchmark timing helpers (median-of-N, compile excluded) + a
process-wide result collector so ``run.py`` can emit BENCH_*.json."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

# Every row() call records here; benchmarks.run dumps it as JSON along
# with the planner's per-op chosen-strategy log.
RESULTS: List[Dict] = []


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            **kw) -> float:
    """Median seconds per call; jit warmup excluded."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": derived})
    return f"{name},{seconds*1e6:.1f},{derived}"

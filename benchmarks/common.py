"""Benchmark timing helpers (median-of-N, compile excluded)."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import numpy as np


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            **kw) -> float:
    """Median seconds per call; jit warmup excluded."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds*1e6:.1f},{derived}"

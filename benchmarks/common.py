"""Benchmark timing helpers (median-of-N, compile excluded) + a
process-wide result collector so ``run.py`` can emit BENCH_*.json.

Every timing loop fences with ``jax.block_until_ready`` — async
dispatch otherwise returns before the work runs and the row measures
dispatch latency, not the kernel.  Rows are mirrored into the
telemetry registry (``bench.<name>`` gauges), and callers may tag a
measurement with the planner op it exercises (``op=``) so
``planner.drift_report()`` gets a measured wall time next to the
predicted cost for that plan row.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.obs import events as _obs_events
from repro.obs import metrics as _obs_metrics

# Every row() call records here; benchmarks.run dumps it as JSON along
# with the planner's per-op chosen-strategy log and a metrics snapshot.
RESULTS: List[Dict] = []


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            op: Optional[str] = None, **kw) -> float:
    """Median seconds per call; jit warmup excluded.

    ``op`` (optional) attributes the median to a planner plan-log key
    (e.g. ``"u_copy_add_v"`` or ``"attn:fused"``) as a measured event,
    feeding the predicted-vs-measured drift report.
    """
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    med = float(np.median(ts))
    if op is not None:
        _obs_events.measured_event(op, med)
    return med


def row(name: str, seconds: float, derived: str = "") -> str:
    RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": derived})
    _obs_metrics.gauge(f"bench.{name}").set(seconds)
    return f"{name},{seconds*1e6:.1f},{derived}"

"""Paper Fig. 2: per-epoch training time of the GNN applications,
baseline push (DGL Alg. 1 analogue) vs optimized blocked pull (Alg. 3).

Datasets are synthetic stand-ins at CPU scale (see data.synthetic.DATASETS
and EXPERIMENTS.md for the mapping). The reported metric matches the
paper's evaluation axis: speedup of optimized over baseline per epoch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_coo
from repro.data import (make_node_dataset, sbm_graph, bipartite_ratings,
                        relational_graph)
from repro.models.gnn import (gcn, sage, gat, monet, rgcn, gcmc, lgnn,
                              make_bundle)
from repro.models.gnn.train import make_train_step
from repro.substrate.nn import cross_entropy_loss

from .common import time_fn, row

BASELINE = "push"
OPTIMIZED = "ell"       # default; main(strategy=...) overrides (e.g. auto)


def _epoch_time(mod, params, bundle, x, labels, mask, strategy):
    opt_init, step = make_train_step(mod.forward, strategy)
    opt_state = opt_init(params)
    rng = jax.random.PRNGKey(0)
    return time_fn(
        lambda: step(params, opt_state, 0, bundle, x, labels, mask, rng)[2],
        iters=3, warmup=1)


def _bench_node_app(name, mod, dataset="pubmed-like", hidden=16,
                    krel=None, **init_kw):
    g, feats, labels, tm, vm, nc = make_node_dataset(dataset)
    bundle = make_bundle(g, krel=krel)
    params = mod.init(jax.random.PRNGKey(0), feats.shape[1], hidden, nc,
                      **init_kw)
    x, y, m = jnp.asarray(feats), jnp.asarray(labels), jnp.asarray(tm)
    t_base = _epoch_time(mod, params, bundle, x, y, m, BASELINE)
    t_opt = _epoch_time(mod, params, bundle, x, y, m, OPTIMIZED)
    sp = t_base / t_opt
    print(row(f"fig2_{name}_baseline_epoch", t_base, dataset))
    print(row(f"fig2_{name}_optimized_epoch", t_opt,
              f"speedup={sp:.2f}x"))
    return sp


def bench_gcmc():
    u, i, r = bipartite_ratings(2000, 1500, 60_000, 5)
    fwd, bwd = gcmc.build_level_relgraphs(u, i, r, 2000, 1500, 5)
    fwd.cache.ell()             # pinned 'ell' runs blocked pull in-trace
    bwd.cache.ell()
    g_all = from_coo(u, i, n_src=2000, n_dst=1500)
    params = gcmc.init(jax.random.PRNGKey(0), 64, 64, 64, 32, 5)
    rng = np.random.default_rng(0)
    xu = jnp.asarray(rng.normal(size=(2000, 64)).astype(np.float32))
    xi = jnp.asarray(rng.normal(size=(1500, 64)).astype(np.float32))
    labels = jnp.asarray(r)

    def loss(strategy):
        @jax.jit
        def f():
            return cross_entropy_loss(
                gcmc.forward(params, (fwd, bwd, g_all), xu, xi,
                             strategy=strategy), labels)
        return f

    t_base = time_fn(loss(BASELINE), iters=3, warmup=1)
    t_opt = time_fn(loss(OPTIMIZED), iters=3, warmup=1)
    print(row("fig2_gcmc_baseline_epoch", t_base, "ml1m-like"))
    print(row("fig2_gcmc_optimized_epoch", t_opt,
              f"speedup={t_base/t_opt:.2f}x"))
    return t_base / t_opt


def bench_rgcn():
    n, n_rel = 5000, 8
    rels = relational_graph(n, n_rel, 25_000)
    rg = rgcn.build_relgraph(rels, n)
    rg.cache.ell()              # pinned 'ell' runs blocked pull in-trace
    params = rgcn.init(jax.random.PRNGKey(0), 32, 32, 4, n_rel=n_rel)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, 32)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, n))

    def loss(strategy):
        @jax.jit
        def f():
            return cross_entropy_loss(
                rgcn.forward(params, rg, x, strategy=strategy), labels)
        return f

    t_base = time_fn(loss(BASELINE), iters=3, warmup=1)
    t_opt = time_fn(loss(OPTIMIZED), iters=3, warmup=1)
    print(row("fig2_rgcn_baseline_epoch", t_base, "bgs-like"))
    print(row("fig2_rgcn_optimized_epoch", t_opt,
              f"speedup={t_base/t_opt:.2f}x"))
    return t_base / t_opt


def bench_lgnn():
    src, dst, comm = sbm_graph(800, 2, 0.06, 0.003)
    g = from_coo(src, dst, n_src=800, n_dst=800)
    lg = lgnn.build_line_graph(g)
    rg = lgnn.build_relgraph(g, lg)
    rg.cache.ell()              # pinned 'ell' runs blocked pull in-trace
    params = lgnn.init(jax.random.PRNGKey(0), 800, 16, 16, 2)
    labels = jnp.asarray(comm)

    def loss(strategy):
        @jax.jit
        def f():
            logits, _ = lgnn.forward(params, g, lg, rg=rg,
                                     strategy=strategy)
            return cross_entropy_loss(logits, labels)
        return f

    t_base = time_fn(loss(BASELINE), iters=3, warmup=1)
    t_opt = time_fn(loss(OPTIMIZED), iters=3, warmup=1)
    print(row("fig2_lgnn_baseline_epoch", t_base, "sbm"))
    print(row("fig2_lgnn_optimized_epoch", t_opt,
              f"speedup={t_base/t_opt:.2f}x"))
    return t_base / t_opt


def main(strategy: str = None):
    global OPTIMIZED
    if strategy is not None:
        OPTIMIZED = strategy
    speedups = {}
    speedups["gcn"] = _bench_node_app("gcn", gcn)
    speedups["graphsage"] = _bench_node_app("graphsage", sage)
    speedups["gat"] = _bench_node_app("gat", gat, n_heads=4)
    speedups["monet"] = _bench_node_app("monet", monet, krel=2,
                                        n_kernels=2)
    speedups["gcmc"] = bench_gcmc()
    speedups["rgcn"] = bench_rgcn()
    speedups["lgnn"] = bench_lgnn()
    geo = float(np.exp(np.mean(np.log(list(speedups.values())))))
    print(row("fig2_geomean_speedup", 0.0, f"{geo:.2f}x"))
    return speedups


if __name__ == "__main__":
    main()

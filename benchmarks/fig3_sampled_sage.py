"""Paper Fig. 3: sampled GraphSAGE per-epoch time, baseline vs optimized.

Synthetic datasets stand in for Reddit / OGB-Products (scaled to CPU;
see EXPERIMENTS.md). Each configuration trains real minibatch epochs
through ONE jitted train step per strategy — host-side neighbor
sampling (double-buffered prefetch) overlapped with the device step.
Reported per row: epoch wall time, the sampling-vs-aggregation split,
and (via ``benchmarks.run``'s JSON dump) the planner's chosen block
plan per op. ``push`` is the DGL baseline; ``segment`` the vendor
analogue; ``auto`` lets the shape-keyed block planner pick per op.
"""
from __future__ import annotations

import os

import jax

from repro.data import make_node_dataset
from repro.models.gnn import sage
from repro.models.gnn.train import train_sampled

from .common import row

import numpy as np

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

# (dataset, fanouts, batch_size, n_batches) sweep — EXPERIMENTS.md maps
# each dataset preset to the paper dataset it stands in for.
SWEEP = [
    ("pubmed-like", (5, 5), 64, 8),
    ("pubmed-like", (10, 10), 64, 8),
    ("pubmed-like", (10, 10), 256, 4),
    ("reddit-like", (10, 10), 64, 4),
]
if QUICK:
    SWEEP = [("tiny", (5, 5), 32, 4), ("tiny", (10, 10), 32, 4)]

_DATASETS = {}


def _dataset(name):
    if name not in _DATASETS:
        _DATASETS[name] = make_node_dataset(name)
    return _DATASETS[name]


def bench_config(dataset: str, fanouts, batch_size: int, n_batches: int,
                 strategies) -> dict:
    g, feats, labels, tm, vm, nc = _dataset(dataset)
    ids = np.nonzero(tm)[0]
    tag = f"fig3_sage_{dataset}_f{'x'.join(map(str, fanouts))}_b{batch_size}"
    out = {}
    for strategy in strategies:
        params = sage.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc,
                           n_layers=len(fanouts))
        # epoch 0 pays the jit compile; epoch 1 is the measured epoch
        # (matches the paper's compile-excluded epoch averages)
        _, hist = train_sampled(
            sage.forward_blocks, params, g, feats, labels, ids,
            fanouts=fanouts, batch_size=batch_size, strategy=strategy,
            epochs=2, seed=1, max_batches=n_batches)
        epoch = hist["epoch_time"][1]
        sample = hist["sample_time"][1]
        agg = hist["step_time"][1]
        out[strategy] = epoch
        split = (f"sample={sample/max(epoch, 1e-12):.0%}"
                 f" agg={agg/max(epoch, 1e-12):.0%}"
                 f" batches={hist['n_batches'][1]}")
        if strategy != "push" and "push" in out:
            split += f" speedup={out['push']/max(epoch, 1e-12):.2f}x"
        print(row(f"{tag}_{strategy}", epoch, split))
    return out


def main(strategy: str = None):
    if strategy is None:
        strategies = ("push", "segment", "auto")
    elif strategy == "push":
        strategies = ("push",)          # baseline only, not twice
    else:
        strategies = ("push", strategy)
    for dataset, fanouts, batch_size, n_batches in SWEEP:
        bench_config(dataset, fanouts, batch_size, n_batches, strategies)


if __name__ == "__main__":
    main()

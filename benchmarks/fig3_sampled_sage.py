"""Paper Fig. 3: sampled GraphSAGE per-epoch time, baseline vs optimized.

Synthetic datasets stand in for Reddit / OGB-Products (scaled to CPU;
see EXPERIMENTS.md). Each configuration trains real minibatch epochs
through ONE jitted train step per strategy — host-side neighbor
sampling (double-buffered prefetch) overlapped with the device step.
Reported per row: epoch wall time, the sampling-vs-aggregation split,
and (via ``benchmarks.run``'s JSON dump) the planner's chosen block
plan per op. ``push`` is the DGL baseline; ``segment`` the vendor
analogue; ``auto`` lets the shape-keyed block planner pick per op.

Two backward measurements ride along (DESIGN.md §7):

* per sweep config, one extra epoch with ``bwd_strategy="scatter"``
  pins the autodiff backward, so the ``auto`` row's speedup isolates
  what the reverse-table gather VJP buys end-to-end;
* :func:`bench_bwd_split` times the differentiated block aggregation
  alone (one jitted grad per backward strategy) on each config's
  minibatch shape — the bwd-time split, free of sampling/optimizer
  noise.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.data import NeighborSampler, make_node_dataset
from repro.models.gnn import sage
from repro.models.gnn.train import train_sampled
from repro.core.blocks import block_gspmm

from .common import row, time_fn

import numpy as np

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

# (dataset, fanouts, batch_size, n_batches) sweep — EXPERIMENTS.md maps
# each dataset preset to the paper dataset it stands in for. The
# products-like rows are the ROADMAP's 2.4M-node/120M-edge shape class
# scaled to CPU (2^17 nodes / 1.2M edges), batched like the paper's
# OGB-Products runs (large batch, deeper fanout).
SWEEP = [
    ("pubmed-like", (5, 5), 64, 8),
    ("pubmed-like", (10, 10), 64, 8),
    ("pubmed-like", (10, 10), 256, 4),
    ("reddit-like", (10, 10), 64, 4),
    ("products-like", (15, 10), 512, 3),
    ("products-like", (10, 10), 1024, 2),
]
if QUICK:
    SWEEP = [("tiny", (5, 5), 32, 4), ("tiny", (10, 10), 32, 4)]

_DATASETS = {}


def _dataset(name):
    if name not in _DATASETS:
        _DATASETS[name] = make_node_dataset(name)
    return _DATASETS[name]


def bench_config(dataset: str, fanouts, batch_size: int, n_batches: int,
                 strategies) -> dict:
    g, feats, labels, tm, vm, nc = _dataset(dataset)
    ids = np.nonzero(tm)[0]
    tag = f"fig3_sage_{dataset}_f{'x'.join(map(str, fanouts))}_b{batch_size}"
    out = {}
    # (fwd strategy, bwd strategy, row suffix): the scatter-bwd variant
    # of auto isolates the reverse-block VJP's end-to-end contribution
    variants = [(s, "auto", s) for s in strategies]
    if "auto" in strategies:
        variants.append(("auto", "scatter", "auto_scatterbwd"))
    for strategy, bwd, name in variants:
        params = sage.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc,
                           n_layers=len(fanouts))
        # epoch 0 pays the jit compile; epoch 1 is the measured epoch
        # (matches the paper's compile-excluded epoch averages)
        _, hist = train_sampled(
            sage.forward_blocks, params, g, feats, labels, ids,
            fanouts=fanouts, batch_size=batch_size, strategy=strategy,
            bwd_strategy=bwd, epochs=2, seed=1, max_batches=n_batches)
        epoch = hist["epoch_time"][1]
        sample = hist["sample_time"][1]
        agg = hist["step_time"][1]
        out[name] = epoch
        split = (f"sample={sample/max(epoch, 1e-12):.0%}"
                 f" agg={agg/max(epoch, 1e-12):.0%}"
                 f" batches={hist['n_batches'][1]}")
        if name != "push" and "push" in out:
            split += f" speedup={out['push']/max(epoch, 1e-12):.2f}x"
        if name == "auto_scatterbwd" and "auto" in out:
            split += (f" gather_bwd_speedup="
                      f"{epoch/max(out['auto'], 1e-12):.2f}x")
        print(row(f"{tag}_{name}", epoch, split))
    return out


def bench_bwd_split(dataset: str, fanouts, batch_size: int) -> dict:
    """Backward-time split: the differentiated block aggregation alone.

    One minibatch of the config's shape; per op (SAGE's mean CR and
    GCN's weighted sum), a jitted ∂x+∂w computation with the backward
    pinned to 'gather' (reverse-table VJP) vs 'scatter' (autodiff) —
    the direct measurement of what the reverse table buys, reported as
    ``bwd=`` rows next to the epoch rows in BENCH_fig3.json.
    """
    g, feats, labels, tm, vm, nc = _dataset(dataset)
    d = 64      # hidden width — where train steps spend backward time
    sampler = NeighborSampler(g, fanouts, batch_size, seed=3)
    ids = np.nonzero(tm)[0]
    mb = sampler.sample(ids[:batch_size], labels[ids[:batch_size]])
    blk = mb.blocks[0]          # outermost hop: the big block
    bg = blk.bg
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(bg.g.n_src, d)).astype(np.float32))
    e = blk.gcn_norm[:, None]
    ct = jnp.asarray(rng.normal(size=(bg.n_dst_real, d))
                     .astype(np.float32))
    tag = (f"fig3_bwdsplit_{dataset}_"
           f"f{'x'.join(map(str, fanouts))}_b{batch_size}")
    out = {}
    for op, args in [("u_copy_mean_v", {"u": u}),
                     ("u_mul_e_add_v", {"u": u, "e": e})]:
        for bwd in ("gather", "scatter"):
            @jax.jit
            def grad_fn(bg, ct, *leaves, bwd=bwd, op=op, keys=tuple(args)):
                a = dict(zip(keys, leaves))

                def loss(a):
                    return jnp.sum(block_gspmm(bg, op, **a,
                                               bwd_strategy=bwd) * ct)

                return jax.grad(loss)(a)

            t = time_fn(grad_fn, bg, ct, *args.values(), iters=7)
            out[op, bwd] = t
            derived = ""
            if bwd == "scatter":
                derived = (f"gather_speedup="
                           f"{t/max(out[op, 'gather'], 1e-12):.2f}x")
            print(row(f"{tag}_{op}_{bwd}", t, derived))
    return out


def main(strategy: str = None):
    if strategy is None:
        strategies = ("push", "segment", "auto")
    elif strategy == "push":
        strategies = ("push",)          # baseline only, not twice
    else:
        strategies = ("push", strategy)
    for dataset, fanouts, batch_size, n_batches in SWEEP:
        bench_config(dataset, fanouts, batch_size, n_batches, strategies)
    # backward split once per distinct (dataset, fanouts, batch) shape
    for dataset, fanouts, batch_size, _ in SWEEP:
        bench_bwd_split(dataset, fanouts, batch_size)


if __name__ == "__main__":
    main()

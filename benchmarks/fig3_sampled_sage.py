"""Paper Fig. 3: sampled GraphSAGE per-epoch time, baseline vs optimized.

Two synthetic datasets stand in for Reddit / OGB-Products (scaled to CPU;
see EXPERIMENTS.md). Sampling (host) + aggregation (device) per batch —
the aggregation strategy is the variable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_node_dataset, NeighborSampler
from repro.models.gnn import sage

from .common import row


def bench(dataset: str, n_batches: int = 8, batch_size: int = 64):
    g, feats, labels, tm, vm, nc = make_node_dataset(dataset)
    fz = np.vstack([feats, np.zeros((1, feats.shape[1]), np.float32)])
    feats_j = jnp.asarray(fz)
    params = sage.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)

    def feats_fn(ids):
        safe = jnp.where(jnp.asarray(ids) >= 0, jnp.asarray(ids),
                         feats_j.shape[0] - 1)
        return jnp.take(feats_j, safe, axis=0)

    ids = np.nonzero(tm)[0]
    out = {}
    for strategy in ("push", "segment"):
        fwd = jax.jit(lambda blocks_leaves, ids_in:  # noqa: E731
                      None)  # placeholder; defined below per strategy

        def run_epoch():
            sampler = NeighborSampler(g, fanouts=[10, 10],
                                      batch_size=batch_size, seed=1)
            t_total = 0.0
            n = 0
            for mb in sampler.batches(ids, labels[ids]):
                t0 = time.perf_counter()
                o = sage.forward_sampled(params, mb.blocks, feats_fn,
                                         strategy=strategy,
                                         batch_size=batch_size)
                jax.block_until_ready(o)
                t_total += time.perf_counter() - t0
                n += 1
                if n >= n_batches:
                    break
            return t_total

        run_epoch()           # warmup/compile
        out[strategy] = run_epoch()

    sp = out["push"] / out["segment"]
    print(row(f"fig3_sage_{dataset}_baseline", out["push"],
              f"{n_batches} batches"))
    print(row(f"fig3_sage_{dataset}_optimized", out["segment"],
              f"speedup={sp:.2f}x"))
    return sp


def main():
    bench("pubmed-like")
    bench("reddit-like", n_batches=4)


if __name__ == "__main__":
    main()

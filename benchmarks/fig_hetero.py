"""Relation-fused heterogeneous execution benchmark (DESIGN.md §8).

Two sweeps, fused vs the pre-refactor per-relation loop, forward AND
backward (the acceptance axis of the hetero subsystem):

* **BGS-like**: R-GCN layer shapes on synthetic typed multigraphs with
  50–100 relations (BGS has 103) — the regime where the loop pays R
  sequential gathers + reduces per layer and the fused path pays one.
  Rows time ``hetero_gspmm`` with the basis-decomposed weights exactly
  as the model runs it: ``_fwd`` is the jitted aggregation alone,
  ``_fwdbwd`` the jitted value+grad w.r.t. (features, basis, coeff).
* **GCMC levels**: the encoder's user→item direction swept over rating
  level counts — few relations, large per-relation matmuls, the regime
  where the planner keeps the loop competitive.

An ``auto`` row per config records what the planner picks (plan log →
``BENCH_hetero.json`` via ``benchmarks.run``). ``REPRO_BENCH_QUICK=1``
shrinks every config for CI.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hetero_gspmm
from repro.data import bipartite_ratings, relational_graph
from repro.models.gnn import gcmc, rgcn

from .common import row, time_fn

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

# (n_nodes, n_rel, edges_per_rel) — BGS-like typed multigraphs
BGS_SWEEP = [(4000, 50, 700), (4000, 100, 350)]
# (n_users, n_items, n_ratings, levels) — GCMC level sweep
GCMC_SWEEP = [(2000, 1500, 60_000, 5), (2000, 1500, 60_000, 10)]
D_IN, D_HID, N_BASES = 32, 16, 4

if QUICK:
    BGS_SWEEP = [(300, 50, 40)]
    GCMC_SWEEP = [(200, 150, 2_000, 5)]
    D_IN = 16


def _sweep_strategies(tag: str, agg, grad, args, note: str,
                      op: str = "hetero:u_w_mean_v") -> float:
    """Time loop/fused/auto × fwd/fwd+bwd; print + record the rows.

    ``agg(strategy)``/``grad(strategy)`` return jitted callables over
    ``args``. Returns the forward fused-over-loop speedup. The auto
    forward row is attributed to the hetero plan-log key (``op``) so
    the drift report gets a measurement for the planner's choice.
    """
    t = {}
    for s in ("loop", "fused", "auto"):
        t[s, "fwd"] = time_fn(agg(s), *args, iters=5,
                              op=op if s == "auto" else None)
        t[s, "bwd"] = time_fn(grad(s), *args, iters=5)
    for phase in ("fwd", "bwd"):
        sp = t["loop", phase] / max(t["fused", phase], 1e-12)
        name = "_fwdbwd" if phase == "bwd" else "_fwd"
        print(row(f"{tag}{name}_loop", t["loop", phase], note))
        print(row(f"{tag}{name}_fused", t["fused", phase],
                  f"fused_speedup={sp:.2f}x"))
        print(row(f"{tag}{name}_auto", t["auto", phase],
                  f"vs_loop="
                  f"{t['loop', phase] / max(t['auto', phase], 1e-12):.2f}x"))
    return t["loop", "fwd"] / max(t["fused", "fwd"], 1e-12)


def bench_bgs(n: int, n_rel: int, epr: int) -> float:
    rels = relational_graph(n, n_rel, epr, seed=0)
    rg = rgcn.build_relgraph(rels, n)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(n, D_IN)).astype(np.float32))
    basis = jnp.asarray(rng.normal(size=(N_BASES, D_IN, D_HID))
                        .astype(np.float32) * 0.3)
    coeff = jnp.asarray(rng.normal(size=(n_rel, N_BASES))
                        .astype(np.float32) * 0.3)
    tag = f"fig_hetero_bgs_n{n}_r{n_rel}"

    def agg(strategy):
        @jax.jit
        def f(h, basis, coeff):
            return hetero_gspmm(rg, h, basis=basis, coeff=coeff,
                                reduce="mean", strategy=strategy)
        return f

    def grad(strategy):
        @jax.jit
        def f(h, basis, coeff):
            def loss(h, basis, coeff):
                out = hetero_gspmm(rg, h, basis=basis, coeff=coeff,
                                   reduce="mean", strategy=strategy)
                return jnp.sum(out * out)
            return jax.value_and_grad(loss, argnums=(0, 1, 2))(
                h, basis, coeff)
        return f

    return _sweep_strategies(tag, agg, grad, (h, basis, coeff),
                             f"edges={n_rel * epr}")


def bench_gcmc_levels(n_users: int, n_items: int, n_ratings: int,
                      levels: int) -> float:
    u, i, r = bipartite_ratings(n_users, n_items, n_ratings, levels,
                                seed=0)
    rg_fwd, _ = gcmc.build_level_relgraphs(u, i, r, n_users, n_items,
                                           levels)
    rng = np.random.default_rng(0)
    d = 64 if not QUICK else 16
    xu = jnp.asarray(rng.normal(size=(n_users, d)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(levels, d, d)).astype(np.float32)
                    * 0.1)
    tag = f"fig_hetero_gcmc_l{levels}"

    def agg(strategy):
        @jax.jit
        def f(xu, W):
            return hetero_gspmm(rg_fwd, xu, w=W, reduce="mean",
                                strategy=strategy)
        return f

    def grad(strategy):
        @jax.jit
        def f(xu, W):
            def loss(xu, W):
                out = hetero_gspmm(rg_fwd, xu, w=W, reduce="mean",
                                   strategy=strategy)
                return jnp.sum(out * out)
            return jax.value_and_grad(loss, argnums=(0, 1))(xu, W)
        return f

    return _sweep_strategies(tag, agg, grad, (xu, W),
                             f"ratings={n_ratings}")


def main():
    # no --strategy knob: the sweep already times loop/fused/auto
    # explicitly (plain strategy pins map onto the loop baseline)
    for n, n_rel, epr in BGS_SWEEP:
        bench_bgs(n, n_rel, epr)
    for cfg in GCMC_SWEEP:
        bench_gcmc_levels(*cfg)


if __name__ == "__main__":
    main()

"""Partitioned full-graph training swept over shard counts (DESIGN.md §6).

For each (dataset, app) the single-device full-graph epoch is the
baseline; the partitioned rows train the same model across 2/4/8
host-emulated shards (ring execution) plus a delayed-halo row for GCN.
Emulated devices need ``XLA_FLAGS=--xla_force_host_platform_device_count``
set BEFORE jax imports, so the shard sweep re-execs itself in one child
process and streams rows back; a child killed by a signal (the known
host-platform emulation crash) downgrades to a skip note instead of
failing the whole benchmark run.

Reported per row: measured epoch wall time (compile excluded — the
train loops warm up before timing), the speedup over the single-device
baseline, and the partition's cut fraction. The child's plan log is
replayed into the parent so ``BENCH_partitioned.json`` carries the
chosen plans like every other section.

NOTE: on host-EMULATED devices all "shards" share one CPU's cores, so
wall-clock speedups > 1 are not expected at these scales — the sweep
tracks the communication/padding overhead trend across shard counts
(the real-hardware signal), not raw speed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from .common import row, time_fn

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

# power-law (R-MAT) ragged-ring leg: hash placement is the scalable
# mode for skewed graphs (DistGNN-style random placement), and also the
# worst case for max-width bucket padding — one hub-heavy bucket sets
# the global eb all S² buckets pad to, so this is where the per-bucket
# eb[i,j] widths decide the pad+wire bill
POWERLAW_SHAPE = (11, 12_000) if QUICK else (13, 60_000)
POWERLAW_SHARDS = 8

if QUICK:
    DATASET = "tiny"
    SHARDS = (2, 4)
    APPS = ("gcn", "sage")
    EPOCHS = 2
    HALO = ()
else:
    DATASET = "pubmed-like"
    SHARDS = (2, 4, 8)
    APPS = ("gcn", "sage", "gat")
    EPOCHS = 3
    HALO = (4,)          # gcn halo-staleness rows

_CHILD = r"""
import json, os, sys
cfg = json.loads(sys.argv[1])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%d" % max(cfg["shards"]))
import numpy as np, jax
from repro.core import planner
from repro.data import make_node_dataset
from repro.launch.mesh import make_shard_mesh
from repro.models.gnn import gcn, sage, gat
from repro.models.gnn.train import train_partitioned

mods = {"gcn": gcn, "sage": sage, "gat": gat}
g, feats, labels, tm, vm, nc = make_node_dataset(cfg["dataset"])
for app in cfg["apps"]:
    mod = mods[app]
    params = mod.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)
    for S in cfg["shards"]:
        mesh = make_shard_mesh(S)
        _, hist = train_partitioned(
            mod.forward_partitioned, params, g, feats, labels, tm,
            n_shards=S, mesh=mesh, epochs=cfg["epochs"], drop=0.0, seed=1)
        pg = planner.get_plan_cache(g).partition(S, "contiguous")
        print(json.dumps({"kind": "row", "app": app, "shards": S,
                          "halo": 0,
                          "epoch_time": hist["epoch_time"][-1],
                          "loss": hist["loss"][-1],
                          "cut": pg.stats.cut_fraction,
                          "eb": pg.stats.eb}), flush=True)
# delayed-halo rows (gcn only): the reported time is a STALE epoch —
# the ring-free step the staleness knob buys
for k in cfg["halo"]:
    S = max(cfg["shards"])
    mesh = make_shard_mesh(S)
    params = gcn.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)
    _, hist = train_partitioned(
        gcn.forward_partitioned, params, g, feats, labels, tm,
        n_shards=S, mesh=mesh, epochs=cfg["epochs"] * 2, drop=0.0,
        halo_staleness=k, init_halo_fn=gcn.init_halo, seed=1)
    # same estimator as every other row: the LAST epoch of the kind the
    # row reports (here: the last stale, i.e. ring-free, epoch)
    stale_epochs = [t for t, r in zip(hist["epoch_time"],
                                      hist["refreshed"]) if not r]
    print(json.dumps({"kind": "row", "app": "gcn", "shards": S,
                      "halo": k,
                      "epoch_time": (stale_epochs[-1] if stale_epochs
                                     else hist["epoch_time"][-1]),
                      "loss": hist["loss"][-1], "cut": 0.0,
                      "eb": 0}), flush=True)
# precision x compression sweep (gcn): exchange bytes come from the obs
# metrics registry (comm.ring.*_bytes), step time and final-loss delta
# vs the fp32 row ride along (DESIGN.md SS12)
from repro.obs import metrics as _metrics
from repro.optim import Precision
S = 4 if 4 in cfg["shards"] else max(cfg["shards"])
mesh = make_shard_mesh(S)
params = gcn.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)
base_loss = None
for pname, comm in (("fp32", "none"), ("bf16", "none"),
                    ("fp32", "int8"), ("bf16", "int8")):
    prec = Precision.parse(pname, comm=comm)
    prev = _metrics.set_enabled(True)
    _metrics.reset_metrics()
    _, hist = train_partitioned(
        gcn.forward_partitioned, params, g, feats, labels, tm,
        n_shards=S, mesh=mesh, epochs=cfg["epochs"], drop=0.0, seed=1,
        precision=prec,
        init_comm_fn=gcn.init_comm if comm == "int8" else None)
    snap = _metrics.snapshot()
    _metrics.set_enabled(prev)
    loss = hist["loss"][-1]
    if base_loss is None:
        base_loss = loss
    print(json.dumps({"kind": "prec_row", "app": "gcn", "shards": S,
                      "precision": prec.tag(),
                      "epoch_time": hist["epoch_time"][-1],
                      "loss": loss, "loss_delta": loss - base_loss,
                      "raw_bytes": snap.get("comm.ring.raw_bytes",
                                            {}).get("value", 0),
                      "wire_bytes": snap.get("comm.ring.wire_bytes",
                                             {}).get("value", 0)}),
          flush=True)
print(json.dumps({"kind": "plans",
                  "plans": {f"{op}|{req}": dict(cnt) for (op, req), cnt
                            in planner.plan_log().items()}}), flush=True)
"""


def _baseline(dataset: str, apps, epochs: int) -> dict:
    """Single-device full-graph epoch per app (strategy=auto)."""
    import jax

    from repro.data import make_node_dataset
    from repro.models.gnn import gat, gcn, sage
    from repro.models.gnn.common import make_bundle
    from repro.models.gnn.train import train_full_graph

    mods = {"gcn": gcn, "sage": sage, "gat": gat}
    g, feats, labels, tm, vm, nc = make_node_dataset(dataset)
    base = {}
    for app in apps:
        mod = mods[app]
        params = mod.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)
        fw = (lambda m: lambda p, b, x, **kw: m.forward(p, b, x, drop=0.0,
                                                        **kw))(mod)
        _, hist = train_full_graph(fw, params, make_bundle(g), feats,
                                   labels, tm, epochs=epochs, seed=1)
        base[app] = hist["epoch_time"][-1]
        print(row(f"figp_{dataset}_{app}_s1_single", base[app],
                  f"loss={hist['loss'][-1]:.3f}"))
    return base


def powerlaw_ring_rows() -> None:
    """Ragged ring buckets on a power-law graph (emulated, 8 shards).

    Reports the dense (max-width ``eb``) vs ragged (per-bucket
    ``eb[i,j]`` diagonal schedule) pad-slot and pad+wire byte bills,
    the emulated ring fwd+bwd wall time, and the gradient gap vs the
    single-device reference. Runs parent-side: the emulated ring shares
    the bucket math and transposed-ring VJP with the mesh path, so no
    device emulation subprocess is needed.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import from_coo, gspmm
    from repro.core.partition import build_partition, ring_gspmm
    from repro.data import rmat_graph

    n_log2, nnz = POWERLAW_SHAPE
    S = POWERLAW_SHARDS
    src, dst, n = rmat_graph(n_log2, nnz, seed=13)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    pg = build_partition(g, S, "hash")
    st = pg.stats
    tag = f"figp_powerlaw_s{S}"

    F = 8
    dense_slots = S * S * st.eb
    stages = st.ragged_stages if st.ragged_stages >= 0 else S - 1
    # wire: S·stages block-sends of rows×F fp32; pad: slots beyond the
    # real edges, each touching an F-wide feature row (same units both
    # sides, so the cut is layout-only)
    wire_d = S * (S - 1) * pg.rows * F * 4
    wire_r = S * stages * pg.rows * F * 4
    pad_d = (dense_slots - g.n_edges) * F * 4
    pad_r = (st.ragged_slots - g.n_edges) * F * 4
    print(row(f"{tag}_pad_dense", 0.0,
              f"slots={dense_slots} edges={g.n_edges} "
              f"padwire_bytes={pad_d + wire_d}"))
    print(row(f"{tag}_pad_ragged", 0.0,
              f"slots={st.ragged_slots} stages={stages} "
              f"padwire_bytes={pad_r + wire_r} "
              f"cut={(pad_d + wire_d) / max(pad_r + wire_r, 1):.2f}x"))

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(g.n_src, F)).astype(np.float32))
    w = pg.scatter_edges(jnp.ones((g.n_edges,), jnp.float32))

    def ring_loss(x):
        out = pg.gather_nodes(ring_gspmm(pg, pg.scatter_nodes(x), w))
        return jnp.sum(out ** 2)

    def ref_loss(x):
        return jnp.sum(gspmm(g, "u_copy_add_v", u=x,
                             strategy="segment") ** 2)

    gr = jax.grad(ring_loss)(x)
    gf = jax.grad(ref_loss)(x)
    # hub gradients reach O(1e3), so the honest parity number is the
    # relative gap (absolute diff is pure fp32 reduction-order noise)
    gdiff = float(jnp.max(jnp.abs(gr - gf)))
    grel = gdiff / max(float(jnp.max(jnp.abs(gf))), 1e-12)
    t = time_fn(jax.jit(jax.grad(ring_loss)), x, iters=3)
    print(row(f"{tag}_ring_fwdbwd", t,
              f"edges={g.n_edges} grad_reldiff={grel:.1e}"))


def main() -> None:
    base = _baseline(DATASET, APPS, EPOCHS)
    powerlaw_ring_rows()
    cfg = {"dataset": DATASET, "shards": list(SHARDS), "apps": list(APPS),
           "epochs": EPOCHS, "halo": list(HALO)}
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _CHILD, json.dumps(cfg)],
                       env=env, capture_output=True, text=True,
                       timeout=3600)
    if r.returncode < 0:
        print(f"# partitioned sweep skipped: emulation subprocess died "
              f"with signal {-r.returncode}", file=sys.stderr)
        return
    if r.returncode != 0:
        print(r.stderr[-3000:], file=sys.stderr)
        raise RuntimeError("partitioned benchmark child failed")
    from repro.core import planner
    for line in r.stdout.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        msg = json.loads(line)
        if msg["kind"] == "row":
            app, S, k = msg["app"], msg["shards"], msg["halo"]
            name = f"figp_{DATASET}_{app}_s{S}"
            if k:
                name += f"_halo{k}"
            derived = (f"loss={msg['loss']:.3f}"
                       f" speedup={base[app] / max(msg['epoch_time'], 1e-12):.2f}x")
            if not k:
                derived += f" cut={msg['cut']:.0%}"
            else:
                derived += " stale-epoch"
            print(row(name, msg["epoch_time"], derived))
        elif msg["kind"] == "prec_row":
            tag = msg["precision"].replace("+", "_")
            name = f"figp_{DATASET}_{msg['app']}_s{msg['shards']}_{tag}"
            ratio = (msg["raw_bytes"] / msg["wire_bytes"]
                     if msg["wire_bytes"] else float("nan"))
            print(row(name, msg["epoch_time"],
                      f"loss={msg['loss']:.3f}"
                      f" dloss={msg['loss_delta']:+.4f}"
                      f" wire={msg['wire_bytes']}"
                      f" comp={ratio:.2f}x"))
        elif msg["kind"] == "plans":
            # replay the child's decisions into the parent's plan log so
            # the BENCH json reports them like every other section
            for key, counts in msg["plans"].items():
                op, req = key.split("|", 1)
                for chosen, cnt in counts.items():
                    for _ in range(int(cnt)):
                        planner._record(op, req, chosen)


if __name__ == "__main__":
    main()

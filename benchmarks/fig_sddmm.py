"""Planned gSDDMM + fused-attention benchmark (DESIGN.md §9).

The GAT attention pipeline, multipass (planned gsddmm logits → leaky →
edge softmax → weighted gspmm: four kernel-sized passes with per-edge α
materialized in HBM) vs :func:`repro.core.fused_attention` (ONE pass in
canonical dst-sorted order, α never stored), forward AND forward+
backward — the acceptance axis of the fused-attention subsystem. An
``auto`` row per config records what the attention planner picks.

Configs: the Fig. 2 pubmed-like full-graph shape at the GAT defaults
(hidden=16, heads=4) and a products-like shape (the scale where pass
fusion pays most). A gsddmm strategy sweep (canonical vs the
caller-order gather baseline) rides along on the logits op.
``REPRO_BENCH_QUICK=1`` shrinks every config for CI.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (from_coo, fused_attention, get_plan_cache,
                        gsddmm, gspmm)
from repro.core import planner as _planner
from repro.core.edge_softmax import edge_softmax
from repro.data import make_node_dataset, rmat_graph
from repro.substrate.nn import leaky_relu

from .common import row, time_fn

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

HIDDEN, HEADS = 16, 4
# products-like: the dense-ish large shape where the multipass α tensor
# is the biggest intermediate (scaled to CPU bench time)
PRODUCTS_SHAPE = (32_768, 400_000)
# power-law (R-MAT) degree tail: the padding-tax shape — hub rows make
# the row-complete ELL slot count explode, so this is where the ragged
# per-class packs decide whether the Pallas megakernel is viable at all
POWERLAW_SHAPE = (15, 180_000)          # (n_log2, n_edges)
if QUICK:
    PRODUCTS_SHAPE = (2_048, 12_000)
    POWERLAW_SHAPE = (11, 12_000)


def _attention_fns(g, pallas: bool = False):
    """Jitted (fwd, fwd+bwd) callables per pipeline variant."""

    def multipass(el, er, z):
        logits = gsddmm(g, "u_add_v_copy_e", u=el, v=er)
        alpha = edge_softmax(g, leaky_relu(logits))
        return gspmm(g, "u_mul_e_add_v", u=z, e=alpha[:, :, None])

    def fused(el, er, z):
        return fused_attention(g, el, er, z, strategy="fused")

    def auto(el, er, z):
        return fused_attention(g, el, er, z, strategy="auto")

    def pallas_fn(el, er, z):
        return fused_attention(g, el, er, z, strategy="pallas")

    variants = [("multipass", multipass), ("fused", fused),
                ("auto", auto)]
    if pallas:
        variants.append(("pallas", pallas_fn))
    out = {}
    for name, fn in variants:
        fwd = jax.jit(fn)

        def fwdbwd(el, er, z, _fn=fn):
            def loss(el, er, z):
                return jnp.sum(_fn(el, er, z) ** 2)
            return jax.value_and_grad(loss, argnums=(0, 1, 2))(el, er, z)

        out[name] = (fwd, jax.jit(fwdbwd))
    return out


def bench_attention(tag: str, g, note: str, pallas: bool = False) -> float:
    rng = np.random.default_rng(0)
    n_src, n_dst = g.n_src, g.n_dst
    el = jnp.asarray(rng.normal(size=(n_src, HEADS)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(n_dst, HEADS)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(n_src, HEADS, HIDDEN))
                    .astype(np.float32))
    fns = _attention_fns(g, pallas=pallas)
    t = {}
    for name, (fwd, fwdbwd) in fns.items():
        t[name, "fwd"] = time_fn(fwd, el, er, z, iters=5,
                                 op="attn:fused" if name == "fused"
                                 else None)
        t[name, "bwd"] = time_fn(fwdbwd, el, er, z, iters=5)
    for phase in ("fwd", "bwd"):
        sp = t["multipass", phase] / max(t["fused", phase], 1e-12)
        suffix = "_fwdbwd" if phase == "bwd" else "_fwd"
        print(row(f"{tag}{suffix}_multipass", t["multipass", phase],
                  note))
        print(row(f"{tag}{suffix}_fused", t["fused", phase],
                  f"fused_speedup={sp:.2f}x"))
        print(row(f"{tag}{suffix}_auto", t["auto", phase],
                  f"vs_multipass="
                  f"{t['multipass', phase] / max(t['auto', phase], 1e-12):.2f}x"))
        if pallas:
            print(row(f"{tag}{suffix}_pallas", t["pallas", phase],
                      f"vs_fused={t['fused', phase] / max(t['pallas', phase], 1e-12):.2f}x"))
    return t["multipass", "fwd"] / max(t["fused", "fwd"], 1e-12)


def bench_gsddmm_strategies(tag: str, g, note: str) -> None:
    """The logits op alone: canonical stream vs caller-order gather."""
    rng = np.random.default_rng(1)
    el = jnp.asarray(rng.normal(size=(g.n_src, HEADS)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(g.n_dst, HEADS)).astype(np.float32))
    t = {}
    for s in ("canonical", "gather"):
        fn = jax.jit(lambda el, er, _s=s: gsddmm(
            g, "u_add_v_copy_e", u=el, v=er, strategy=_s))
        t[s] = time_fn(fn, el, er, iters=5,
                       op="sddmm:u_add_v_copy_e" if s == "canonical"
                       else None)
    sp = t["gather"] / max(t["canonical"], 1e-12)
    print(row(f"{tag}_logits_gather", t["gather"], note))
    print(row(f"{tag}_logits_canonical", t["canonical"],
              f"canonical_speedup={sp:.2f}x"))


def _products_like():
    n, nnz = PRODUCTS_SHAPE
    rng = np.random.default_rng(7)
    src = rng.integers(0, n, nnz)
    dst = rng.integers(0, n, nnz)
    return from_coo(src, dst, n_src=n, n_dst=n)


def _powerlaw():
    n_log2, nnz = POWERLAW_SHAPE
    src, dst, n = rmat_graph(n_log2, nnz, seed=11)
    return from_coo(src, dst, n_src=n, n_dst=n)


def report_pad_slots(tag: str, g) -> None:
    """Pad-slot accounting rows: row-complete ELL vs ragged classes.

    Slot counts land in ``derived`` (they are not timings); the
    pad-ratio trajectory itself is tracked by the
    ``planner.pad_ratio.*`` gauges in the BENCH JSON metrics snapshot.
    """
    deg = np.asarray(g.in_degrees)
    nz = int((deg > 0).sum())
    uniform = nz * int(deg.max()) if nz else 0
    ragged, n_classes = _planner.ell_rowcomplete_padding(deg)
    drop = uniform / max(ragged, 1)
    print(row(f"{tag}_pad_slots_rowcomplete", 0.0,
              f"slots={uniform} edges={g.n_edges} "
              f"ratio={uniform / max(g.n_edges, 1):.2f}"))
    print(row(f"{tag}_pad_slots_ragged", 0.0,
              f"slots={ragged} classes={n_classes} "
              f"ratio={ragged / max(g.n_edges, 1):.2f} drop={drop:.2f}x"))


def main():
    # no --strategy knob: the sweep times multipass/fused/auto explicitly
    g, *_ = make_node_dataset("pubmed-like")
    gp = _products_like()
    gw = _powerlaw()
    for gr in (g, gp, gw):
        # packs build host-side, not in-trace: the recalibrated cost
        # model picks pallas well below power-law scale, so every graph
        # auto touches needs its ragged pack prebuilt or the in-trace
        # path silently demotes to 'fused'
        get_plan_cache(gr).ell()
        get_plan_cache(gr).ell_ragged()
    bench_attention("fig_sddmm_pubmed", g, f"edges={g.n_edges}")
    bench_gsddmm_strategies("fig_sddmm_pubmed", g, f"edges={g.n_edges}")
    bench_attention("fig_sddmm_products", gp, f"edges={gp.n_edges}")
    bench_gsddmm_strategies("fig_sddmm_products", gp,
                            f"edges={gp.n_edges}")
    bench_attention("fig_sddmm_powerlaw", gw, f"edges={gw.n_edges}",
                    pallas=True)
    report_pad_slots("fig_sddmm_powerlaw", gw)


if __name__ == "__main__":
    main()

"""Serving-tier SLO benchmark (DESIGN.md §10) → BENCH_serve.json.

Three measurements:

* **SLO sweep** — p50/p99 request latency and throughput at N
  concurrent closed-loop requesters (N = 1/4/8) driving a layer-wise
  GCN server through the RequestQueue + prefetcher path, steady-state
  recompile count logged per row (must be 0).
* **layer-wise vs fan-out** — per-batch serve latency of the two
  planned modes on the products-like config (the ROADMAP's scaled
  OGB-Products shape class): row lookups through the hot-node cache
  vs per-request L-hop re-expansion through the block path. The
  layer-wise plan must win ≥ 2× (2210.03900's re-expansion tax).
* **app coverage** — one serve latency row per app (GCN/SAGE/GAT/RGCN)
  so every serve path stays on the perf record.

``REPRO_BENCH_QUICK=1`` shrinks datasets/iterations for CI smoke.
"""
from __future__ import annotations

import os

import numpy as np

from repro.launch.serve_gnn import build_server, run_session

from .common import row, time_fn

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))

SLO_DATASET = "tiny" if QUICK else "reddit-like"
CMP_DATASET = "tiny" if QUICK else "products-like"
APP_DATASET = "tiny" if QUICK else "pubmed-like"
CONCURRENCY = (1, 2, 4) if QUICK else (1, 4, 8)
REQS_PER_CLIENT = 15 if QUICK else 50
# production-shaped sampling fan-out for the re-expansion baseline
# (full-neighbor would only widen the gap on power-law graphs)
CMP_FANOUT = 5 if QUICK else 10


def bench_slo() -> None:
    srv = build_server("gcn", SLO_DATASET, mode="layerwise",
                       classes=(8, 32, 128))
    n_nodes = srv.g.n_src

    def ids_fn(rng):
        return rng.integers(0, n_nodes, 4)

    for n_clients in CONCURRENCY:
        res = run_session(srv, n_clients=n_clients,
                          requests_per_client=REQS_PER_CLIENT,
                          ids_fn=ids_fn, max_wait=0.0005)
        cs = res["stats"]["out_cache"]
        print(row(f"serve_slo_{SLO_DATASET}_gcn_c{n_clients}",
                  res["p50_ms"] / 1e3,
                  f"p50_ms={res['p50_ms']:.3f};p99_ms={res['p99_ms']:.3f};"
                  f"rps={res['throughput_rps']:.0f};"
                  f"recompiles={res['recompiles_steady']};"
                  f"hit_ratio={cs.hit_ratio:.3f}"))
        assert res["recompiles_steady"] == 0, \
            f"steady-state recompiles at c={n_clients}"


def bench_modes() -> None:
    rng = np.random.default_rng(0)
    times = {}
    for mode in ("layerwise", "fanout"):
        srv = build_server("gcn", CMP_DATASET, mode=mode, classes=(8,),
                           fanout=CMP_FANOUT)
        srv.warmup()
        compiles = srv.compiles
        ids = rng.integers(0, srv.g.n_src, 8)
        t = time_fn(lambda: srv.serve([(0, ids)]),
                    iters=5 if QUICK else 10)
        times[mode] = t
        print(row(f"serve_mode_{CMP_DATASET}_{mode}", t,
                  f"recompiles={srv.compiles - compiles}"))
        assert srv.compiles == compiles, f"{mode} recompiled while timed"
    speedup = times["fanout"] / max(times["layerwise"], 1e-12)
    print(row(f"serve_mode_{CMP_DATASET}_speedup", times["layerwise"],
              f"layerwise_over_fanout={speedup:.1f}x"))


def bench_apps() -> None:
    rng = np.random.default_rng(1)
    for app in ("gcn", "sage", "gat", "rgcn"):
        srv = build_server(app, APP_DATASET, mode="auto", classes=(8,))
        srv.warmup()
        ids = rng.integers(0, srv.g.n_src, 8)
        t = time_fn(lambda: srv.serve([(0, ids)]),
                    iters=5 if QUICK else 10)
        mode = srv.mode_for_class(8)
        print(row(f"serve_app_{APP_DATASET}_{app}", t, f"mode={mode}"))


def main() -> None:
    bench_slo()
    bench_modes()
    bench_apps()


if __name__ == "__main__":
    main()

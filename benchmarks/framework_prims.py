"""Paper §4: framework-primitive benchmarks (BatchNorm1d, Embedding).

The paper reports 13× (BatchNorm1d) and 76× (Embedding backward) from
replacing serialized CPU kernels. The analogue here: fused batchnorm vs a
per-feature serial loop, and CR-backward embedding vs autodiff scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.substrate import (batchnorm1d_init, batchnorm1d_apply,
                             batchnorm1d_naive, embedding_lookup,
                             embedding_lookup_naive)

from .common import time_fn, row


def bench_batchnorm(n: int = 100_000, d: int = 64):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    st = batchnorm1d_init(d)
    fused = jax.jit(lambda x: batchnorm1d_apply(st, x, train=True)[0])
    naive = jax.jit(lambda x: batchnorm1d_naive(st, x))
    t_naive = time_fn(naive, x, iters=3, warmup=1)
    t_fused = time_fn(fused, x, iters=5, warmup=2)
    print(row("batchnorm1d_naive", t_naive, f"n={n},d={d}"))
    print(row("batchnorm1d_fused", t_fused,
              f"speedup={t_naive/t_fused:.2f}x"))


def bench_embedding(vocab: int = 200_000, d: int = 128,
                    n_lookup: int = 65_536):
    rng = np.random.default_rng(1)
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    ids = jnp.asarray(rng.integers(0, vocab, (n_lookup,)))
    ct = jax.random.normal(key, (n_lookup, d), jnp.float32)

    g_cr = jax.jit(jax.grad(
        lambda t: jnp.sum(embedding_lookup(t, ids) * ct)))
    g_naive = jax.jit(jax.grad(
        lambda t: jnp.sum(embedding_lookup_naive(t, ids) * ct)))
    t_naive = time_fn(g_naive, table, iters=5, warmup=2)
    t_cr = time_fn(g_cr, table, iters=5, warmup=2)
    print(row("embedding_bwd_scatter", t_naive,
              f"V={vocab},lookups={n_lookup}"))
    print(row("embedding_bwd_copyreduce", t_cr,
              f"speedup={t_naive/t_cr:.2f}x"))


def main():
    bench_batchnorm()
    bench_embedding()


if __name__ == "__main__":
    main()

"""Aggregation-kernel strategy sweep (CR = SpMM).

Times the four executable strategies on a power-law graph at several
feature widths. The Pallas kernels run in interpret mode on CPU (their
timings are NOT meaningful hardware numbers — they validate numerics; the
MXU story is the dry-run roofline's job) and are excluded here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_coo, copy_reduce, build_ell, build_tiles
from repro.data import rmat_graph

from .common import time_fn, row


def main():
    src, dst, n = rmat_graph(14, 120_000, seed=5)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    ell = build_ell(g)
    tiles = build_tiles(g)
    rng = np.random.default_rng(0)
    for d in (32, 128, 512):
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        for strategy in ("push", "segment", "ell", "onehot"):
            kw = {}
            if strategy == "ell":
                kw["ell"] = ell
            if strategy == "onehot":
                kw["tiles"] = tiles
            fn = jax.jit(lambda x, s=strategy, kw=kw:
                         copy_reduce(g, x, "sum", strategy=s, **kw))
            t = time_fn(fn, x, iters=5, warmup=2)
            gbps = (g.n_edges * d * 4) / t / 1e9
            print(row(f"spmm_d{d}_{strategy}", t,
                      f"{gbps:.1f}GB/s-gathered"))


if __name__ == "__main__":
    main()

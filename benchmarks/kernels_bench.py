"""Aggregation-kernel strategy sweep (CR = SpMM).

Times the four executable strategies on a power-law graph at several
feature widths. The Pallas kernels run in interpret mode on CPU (their
timings are NOT meaningful hardware numbers — they validate numerics; the
MXU story is the dry-run roofline's job) and are excluded here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_coo, copy_reduce, planner
from repro.data import rmat_graph

from .common import time_fn, row


def main(strategy: str = None):
    src, dst, n = rmat_graph(14, 120_000, seed=5)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    # pre-build through the shared per-graph cache (once per process)
    cache = planner.get_plan_cache(g)
    cache.ell()
    cache.tiles()
    rng = np.random.default_rng(0)
    strategies = (("push", "segment", "ell", "onehot", "auto")
                  if strategy is None else ("push", strategy))
    for d in (32, 128, 512):
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        for s in strategies:
            fn = jax.jit(lambda x, s=s:
                         copy_reduce(g, x, "sum", strategy=s))
            t = time_fn(fn, x, iters=5, warmup=2,
                        op="u_copy_add_v" if s == "auto" else None)
            gbps = (g.n_edges * d * 4) / t / 1e9
            tag = f"{gbps:.1f}GB/s-gathered"
            if s == "auto":
                tag += f";plan={planner.last_plan('u_copy_add_v')}"
            print(row(f"spmm_d{d}_{s}", t, tag))


if __name__ == "__main__":
    main()

"""CI telemetry-overhead gate (DESIGN.md §11).

Runs the quick serve SLO benchmark twice per trial — telemetry OFF
then ON, interleaved so machine drift hits both arms equally — and
fails (exit 1) if the median telemetry-on p50 regresses more than
``GATE_REL`` over telemetry-off plus a small absolute epsilon (the
quick bench p50 is ~1–3 ms, so a pure ratio gate would be decided by
scheduler noise).

The toggle is in-process (:func:`repro.obs.set_enabled`); the server
is rebuilt per arm because instruments resolved at construction time
(feature-cache counters) bind to the enabled state then in force.

Usage: ``PYTHONPATH=src python -m benchmarks.overhead_gate``
Writes ``overhead_gate.json`` next to the BENCH artifacts.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro import obs
from repro.launch.serve_gnn import build_server, run_session

GATE_REL = 1.05          # on may be at most 5% over off ...
GATE_ABS_MS = 0.05       # ... plus this absolute floor
TRIALS = int(os.environ.get("REPRO_GATE_TRIALS", "3"))


def _one_session(app: str = "gcn", dataset: str = "tiny") -> float:
    srv = build_server(app, dataset, classes=(8, 32))
    n_nodes = srv.g.n_src

    def ids_fn(rng: np.random.Generator) -> np.ndarray:
        return rng.integers(0, n_nodes, 4)

    res = run_session(srv, n_clients=2, requests_per_client=8,
                      ids_fn=ids_fn)
    return res["p50_ms"]


def main() -> int:
    p50 = {"off": [], "on": []}
    for trial in range(TRIALS):
        for arm, on in (("off", False), ("on", True)):
            prev = obs.set_enabled(on)
            try:
                obs.clear_trace()          # bound the span buffer
                p50[arm].append(_one_session())
            finally:
                obs.set_enabled(prev)
        print(f"# trial {trial}: off {p50['off'][-1]:.3f} ms, "
              f"on {p50['on'][-1]:.3f} ms", file=sys.stderr)

    med_off = float(np.median(p50["off"]))
    med_on = float(np.median(p50["on"]))
    limit = med_off * GATE_REL + GATE_ABS_MS
    ok = med_on <= limit
    result = {"p50_off_ms": med_off, "p50_on_ms": med_on,
              "overhead_pct": 100.0 * (med_on / med_off - 1.0),
              "limit_ms": limit, "trials": TRIALS, "ok": ok,
              "samples": p50}
    with open("overhead_gate.json", "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# telemetry overhead: off {med_off:.3f} ms → on "
          f"{med_on:.3f} ms ({result['overhead_pct']:+.1f}%), "
          f"limit {limit:.3f} ms → {'OK' if ok else 'FAIL'}",
          file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  fig2  — 7 GNN apps full-graph, baseline push vs optimized pull (Fig. 2)
  fig3  — sampled GraphSAGE (Fig. 3)
  br    — BR/CR primitive configs (Table 2)
  prims — BatchNorm1d / Embedding (paper §4)
  spmm  — CR strategy sweep
  partitioned — multi-device ring training swept over shard counts
                (2/4/8 host-emulated shards, GCN/SAGE/GAT + delayed halo)
  hetero — relation-fused aggregation: BGS-like 50–100-relation RGCN
           shapes + GCMC rating-level sweep, fused vs per-relation
           loop, forward and backward
  sddmm — planned gSDDMM + fused GAT attention: the multipass pipeline
          (logits → softmax → aggregate) vs the single-pass
          fused_attention, forward and forward+backward
  serve — inference serving SLO: p50/p99 latency + throughput at N
          concurrent requesters, layer-wise vs fan-out re-expansion,
          per-app serve latency (steady state must log 0 recompiles)

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One section: ``PYTHONPATH=src python -m benchmarks.run --only fig2``
Planner mode: ``--strategy auto`` times push vs the planner's choice and
reports which plan served each op (also recorded in the JSON output).

Every run writes ``BENCH_<section>.json`` (``--json`` overrides the
path) with the timed rows plus the planner's plan log, so the perf
trajectory is tracked across PRs.
"""
import argparse
import inspect
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig2", "fig3", "br", "prims", "spmm",
                             "partitioned", "hetero", "sddmm", "serve"])
    ap.add_argument("--strategy", default=None,
                    choices=["auto", "push", "segment", "ell", "onehot",
                             "pallas"],
                    help="pin/override the optimized strategy under "
                         "test (sections still time 'push' as baseline)")
    ap.add_argument("--json", default=None,
                    help="output path for the JSON results "
                         "(default BENCH_<section>.json)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="also export the run's spans as Chrome-trace "
                         "JSON (open in Perfetto)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    sections = {
        "fig2": "benchmarks.fig2_full_graph",
        "fig3": "benchmarks.fig3_sampled_sage",
        "br": "benchmarks.br_primitives",
        "prims": "benchmarks.framework_prims",
        "spmm": "benchmarks.kernels_bench",
        "partitioned": "benchmarks.fig_partitioned",
        "hetero": "benchmarks.fig_hetero",
        "sddmm": "benchmarks.fig_sddmm",
        "serve": "benchmarks.fig_serve",
    }
    import importlib

    from repro.core import planner
    from repro import obs
    from . import common

    # the JSON contract includes the metrics snapshot (pad-ratio gauges,
    # comm counters) — force telemetry on so BENCH_*.json always carries
    # it even under REPRO_TELEMETRY=0 environments
    obs.set_enabled(True)

    for key, modname in sections.items():
        if args.only and key != args.only:
            continue
        print(f"# --- {key} ---", file=sys.stderr)
        mod = importlib.import_module(modname)
        kw = {}
        if (args.strategy is not None
                and "strategy" in inspect.signature(mod.main).parameters):
            kw["strategy"] = args.strategy
        mod.main(**kw)

    out_path = args.json or f"BENCH_{args.only or 'all'}.json"
    plans = {f"{op}|{requested}": chosen
             for (op, requested), chosen in planner.plan_log().items()}
    from repro import obs
    drift = planner.drift_report()
    with open(out_path, "w") as f:
        json.dump({"section": args.only or "all",
                   "strategy": args.strategy,
                   "rows": common.RESULTS,
                   "plans": plans,
                   "metrics": obs.snapshot(),
                   "plan_events": obs.plan_events(),
                   "drift": drift}, f, indent=2, sort_keys=True)
    print(f"# wrote {out_path} ({len(common.RESULTS)} rows, "
          f"{len(drift)} drift rows)", file=sys.stderr)
    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"# wrote {args.trace} ({len(obs.trace_events())} span "
              f"events)", file=sys.stderr)


if __name__ == '__main__':
    main()

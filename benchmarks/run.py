"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  fig2  — 7 GNN apps full-graph, baseline push vs optimized pull (Fig. 2)
  fig3  — sampled GraphSAGE (Fig. 3)
  br    — BR/CR primitive configs (Table 2)
  prims — BatchNorm1d / Embedding (paper §4)
  spmm  — CR strategy sweep

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One section: ``PYTHONPATH=src python -m benchmarks.run --only fig2``
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig2", "fig3", "br", "prims", "spmm"])
    args = ap.parse_args()

    print("name,us_per_call,derived")
    sections = {
        "fig2": "benchmarks.fig2_full_graph",
        "fig3": "benchmarks.fig3_sampled_sage",
        "br": "benchmarks.br_primitives",
        "prims": "benchmarks.framework_prims",
        "spmm": "benchmarks.kernels_bench",
    }
    import importlib
    for key, modname in sections.items():
        if args.only and key != args.only:
            continue
        print(f"# --- {key} ---", file=sys.stderr)
        mod = importlib.import_module(modname)
        mod.main()


if __name__ == '__main__':
    main()

"""GAT with the paper's 7-primitive attention chain vs the fused
edge-softmax kernel — same numbers, one HBM pass instead of five.

    PYTHONPATH=src python examples/gat_attention.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_node_dataset
from repro.models.gnn import gat, make_bundle


def main():
    g, feats, labels, tm, vm, nc = make_node_dataset("tiny")
    bundle = make_bundle(g)
    params = gat.init(jax.random.PRNGKey(0), feats.shape[1], 32, nc,
                      n_heads=4)
    x = jnp.asarray(feats)

    composed = jax.jit(lambda p, x: gat.forward(p, bundle, x,
                                                fused_softmax=False))
    fused = jax.jit(lambda p, x: gat.forward(p, bundle, x,
                                             fused_softmax=True))
    a = composed(params, x)
    b = fused(params, x)
    err = float(jnp.abs(a - b).max())
    print(f"composed-vs-fused max err: {err:.2e}")

    for name, fn in (("composed (5 BR passes)", composed),
                     ("fused (1 pass)", fused)):
        fn(params, x)  # warm
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(params, x))
        print(f"{name}: {(time.perf_counter()-t0)/10*1e3:.2f} ms/fwd")


if __name__ == "__main__":
    main()

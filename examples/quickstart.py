"""Quickstart: train GCN on a synthetic citation graph with the paper's
optimized aggregation, and verify the baseline/optimized paths agree.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import copy_reduce, from_coo, planner
from repro.data import make_node_dataset
from repro.models.gnn import gcn, make_bundle
from repro.models.gnn.train import train_full_graph


def main():
    # --- the primitive itself -------------------------------------------
    g = from_coo([0, 1, 2, 0], [2, 2, 1, 1], n_src=3, n_dst=3)
    x = jnp.asarray(np.eye(3, dtype=np.float32))
    print("Copy-Reduce (paper Eq. 3), three strategies + the planner:")
    for s in ("push", "segment", "ell", "auto"):
        print(f"  {s:8s} ->\n{np.asarray(copy_reduce(g, x, strategy=s))}")
    print(f"planner chose: {planner.last_plan('u_copy_add_v')} "
          f"(strategy='auto' is the default everywhere)")

    # --- a real application ---------------------------------------------
    graph, feats, labels, train_mask, val_mask, nc = \
        make_node_dataset("tiny")
    bundle = make_bundle(graph)
    params = gcn.init(jax.random.PRNGKey(0), feats.shape[1], 32, nc)
    params, hist = train_full_graph(
        gcn.forward, params, bundle, feats, labels, train_mask,
        strategy="ell", epochs=20, val_mask=val_mask)
    print(f"\nGCN on {graph}: loss {hist['loss'][0]:.3f} -> "
          f"{hist['loss'][-1]:.3f}, val acc {hist['val_acc'][-1]:.3f}")
    print(f"median epoch time {1e3*np.median(hist['epoch_time']):.1f} ms "
          f"(strategy='ell', the paper's blocked pull)")


if __name__ == "__main__":
    main()

"""Serve a small LM with batched requests (prefill + decode loop).

Uses the same prefill/decode step functions the production dry-run lowers
for the 512-chip mesh — here on a CPU-sized smoke config.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2_7b
"""
import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "qwen2_7b"] + argv
    sys.argv = [sys.argv[0]] + argv + ["--smoke", "--batch", "8",
                                       "--prompt-len", "48", "--gen", "24",
                                       "--temperature", "0.8"]
    serve.main()


if __name__ == "__main__":
    main()

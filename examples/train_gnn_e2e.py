"""End-to-end driver: train GraphSAGE for a few hundred steps with
checkpointing + auto-resume (kill it anywhere; rerun resumes).

    PYTHONPATH=src python examples/train_gnn_e2e.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import make_node_dataset
from repro.models.gnn import sage, make_bundle
from repro.models.gnn.train import make_train_step
from repro.substrate.nn import accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dataset", default="pubmed-like")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_sage_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--strategy", default="auto",
                    help="aggregation strategy; 'auto' lets the planner "
                         "pick per op (pin 'push'/'ell' to reproduce the "
                         "paper's baseline/optimized runs)")
    args = ap.parse_args()

    g, feats, labels, tm, vm, nc = make_node_dataset(args.dataset)
    bundle = make_bundle(g)
    params = sage.init(jax.random.PRNGKey(0), feats.shape[1], 64, nc)
    opt_init, step_fn = make_train_step(sage.forward, args.strategy,
                                        lr=5e-3)
    opt_state = opt_init(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}

    mgr = CheckpointManager(args.ckpt_dir)
    restored = mgr.restore_latest(state)
    start = 0
    if restored is not None:
        state, start = restored
        print(f"[e2e] resumed from step {start}")

    x = jnp.asarray(feats)
    y = jnp.asarray(labels)
    m = jnp.asarray(tm)
    rng = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        rng, sub = jax.random.split(rng)
        p, o, loss = step_fn(state["params"], state["opt"], step, bundle,
                             x, y, m, sub)
        state = {"params": p, "opt": o,
                 "step": jnp.asarray(step + 1, jnp.int32)}
        if step % 25 == 0:
            logits = sage.forward(p, bundle, x, strategy=args.strategy)
            va = float(accuracy(logits, y, jnp.asarray(vm)))
            print(f"[e2e] step={step} loss={float(loss):.4f} "
                  f"val_acc={va:.3f}")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(state, step + 1)
    dt = time.perf_counter() - t0
    logits = sage.forward(state["params"], bundle, x,
                          strategy=args.strategy)
    print(f"[e2e] done ({args.steps - start} steps in {dt:.1f}s). "
          f"final val acc "
          f"{float(accuracy(logits, y, jnp.asarray(vm))):.3f}")


if __name__ == "__main__":
    main()

"""Atomic, mesh-independent checkpointing with corruption recovery.

Layout:  <dir>/step_<N>/
            manifest.json       {step, leaves: {name: {file, crc32, shape,
                                 dtype}}, "complete": true}
            <leaf>.npy ...

Guarantees:
  * atomicity — written to ``step_<N>.tmp`` then renamed; a crash mid-save
    never corrupts the latest good checkpoint;
  * integrity — CRC32 per leaf, verified on restore; a corrupt step is
    skipped and the previous good one used (tested);
  * elasticity — leaves are stored as full (unsharded) arrays keyed by
    pytree path, so restore re-shards onto whatever mesh the restarted job
    has (512→256 chip restarts, or CPU debugging of a pod checkpoint).

On a real multi-host pod, save() is called on host 0 after a
fully-replicated gather, or extended to per-shard files keyed by
(leaf, shard-index) — the manifest format already carries shape/dtype so
per-shard assembly is a local change (documented in DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return ".".join(parts) or "leaf"


def save_pytree(tree: Any, out_dir: str) -> None:
    """Write one pytree to ``out_dir`` (not atomic by itself)."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"leaves": {}, "complete": False}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = name + ".npy"
        np.save(os.path.join(out_dir, fn), arr)
        with open(os.path.join(out_dir, fn), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"][name] = {
            "file": fn, "crc32": crc, "shape": list(arr.shape),
            "dtype": str(arr.dtype)}
    manifest["complete"] = True
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())


def load_pytree(template: Any, in_dir: str, *, shardings: Any = None) -> Any:
    """Load into the structure of ``template``; verify CRCs.

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put with them (elastic re-shard)."""
    with open(os.path.join(in_dir, "manifest.json")) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise IOError("incomplete checkpoint")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, shard_flat):
        name = _leaf_name(path)
        ent = manifest["leaves"].get(name)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        fp = os.path.join(in_dir, ent["file"])
        with open(fp, "rb") as f:
            raw = f.read()
        if zlib.crc32(raw) != ent["crc32"]:
            raise IOError(f"CRC mismatch for {name}")
        arr = np.load(fp)
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(
                np.asarray(leaf).dtype if hasattr(leaf, "dtype") else
                arr.dtype)))
    return treedef.unflatten(out)


class CheckpointManager:
    """Latest-good discovery + atomic save + bounded retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, state: Any, step: int) -> str:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(state, tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def restore_latest(self, template: Any, mesh=None, shardings=None
                       ) -> Optional[Tuple[Any, int]]:
        """Try newest -> oldest; skip corrupt/incomplete checkpoints."""
        for step in reversed(self.steps()):
            path = os.path.join(self.dir, f"step_{step}")
            try:
                state = load_pytree(template, path, shardings=shardings)
                return state, step
            except Exception as e:
                print(f"[ckpt] step_{step} unusable ({e}); trying older")
        return None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

"""Architecture registry: one module per assigned arch (+ GNN presets).

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
``SHAPES`` defines the assigned input-shape cells; ``cells()`` enumerates
the (arch × shape) grid honoring the long_500k sub-quadratic skip rule.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from ..models.lm.config import ModelConfig

ARCHS = [
    "zamba2_2p7b", "qwen2_7b", "qwen2p5_14b", "llama3p2_3b",
    "internlm2_20b", "whisper_medium", "qwen2_vl_2b", "mixtral_8x22b",
    "granite_moe_3b", "mamba2_1p3b",
]

# canonical assignment ids -> module names
ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b", "qwen2-7b": "qwen2_7b",
    "qwen2.5-14b": "qwen2p5_14b", "llama3.2-3b": "llama3p2_3b",
    "internlm2-20b": "internlm2_20b", "whisper-medium": "whisper_medium",
    "qwen2-vl-2b": "qwen2_vl_2b", "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-3b-a800m": "granite_moe_3b", "mamba2-1.3b": "mamba2_1p3b",
}

SHAPES: Dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _module(arch: str):
    name = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def cells() -> List[Tuple[str, str]]:
    """All live (arch, shape) dry-run cells (skips noted in DESIGN.md)."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape_applicable(cfg, shape):
                out.append((arch, shape))
    return out

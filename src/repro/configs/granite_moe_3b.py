"""granite-moe-3b-a800m [moe] — 40 experts top-8 (structured field of the
assignment; its trailing comment says 32 — we follow the field, see
DESIGN.md config notes) [hf:ibm-granite/granite-3.0 family]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155,
        n_experts=40, top_k=8, rope_theta=1e4, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, n_experts=8, top_k=4, tie_embeddings=True,
        dtype="float32")

"""llama3.2-3b [dense] — small llama3 GQA [hf:meta-llama/Llama-3.2-*]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, rope_theta=5e5, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1,
        d_ff=96, vocab=128, tie_embeddings=True, dtype="float32")

"""mamba2-1.3b [ssm] — SSD, attention-free [arXiv:2405.21060]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=128, ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        ssm_chunk=32, tie_embeddings=True, dtype="float32")

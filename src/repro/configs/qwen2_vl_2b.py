"""qwen2-vl-2b [vlm] — M-RoPE (t/h/w sections), dynamic-resolution vision
frontend STUBBED (input_specs supplies merged embeddings + 3-row position
ids) [arXiv:2409.12191; hf]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24), tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, qkv_bias=True,
        mrope_sections=(2, 3, 3), tie_embeddings=True, dtype="float32")

"""whisper-medium [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs supplies precomputed 1500-frame embeddings). 24 encoder +
24 decoder layers (the real whisper-medium; the assignment's "24L" is
read as per-stack depth — DESIGN.md config notes)
[arXiv:2212.04356]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        norm="layernorm", act="gelu",
        n_enc_layers=24, enc_seq=1500)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, norm="layernorm", act="gelu",
        n_enc_layers=2, enc_seq=30, dtype="float32")

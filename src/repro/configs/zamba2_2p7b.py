"""zamba2-2.7b [hybrid] — 54 Mamba2 layers + shared attention block every
6 layers (single shared copy; the real model alternates two shared blocks
with LoRA — simplification noted in DESIGN.md) [arXiv:2411.15242; hf]."""
from ..models.lm.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        shared_attn_every=6, rope_theta=1e4, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        ssm_chunk=32, shared_attn_every=2, tie_embeddings=True,
        dtype="float32")

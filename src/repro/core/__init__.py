"""repro.core — the paper's contribution: BR/CR aggregation primitives."""
from .graph import Graph, from_coo, reverse, add_self_loops
from .tiling import (ELLPack, ELLClass, TilePack, build_ell,
                     build_ell_uniform, build_tiles)
from . import planner
from .planner import (GraphStats, Plan, PlanCache, get_plan_cache,
                      use_ring, active_ring)
from .partition import (PartitionStats, PartitionedGraph, build_partition,
                        ring_gspmm, ring_edge_values, bucket_softmax,
                        local_gspmm, ring_gspmm_delayed, ring_reference)
from .binary_reduce import (BRSpec, parse_op, gspmm, gsddmm, copy_reduce,
                            binary_reduce, BINARY_OPS, REDUCE_OPS)
from .edge_softmax import (edge_softmax, edge_softmax_fused,
                           block_edge_softmax, fused_attention,
                           block_fused_attention,
                           fused_attention_partitioned)
from .blocks import (BlockGraph, block_gspmm, block_supports,
                     build_reverse_table, attach_reverse,
                     serve_block_signature)
from .hetero import (RelGraph, from_typed, from_rels, hetero_gspmm,
                     hetero_block_gspmm)
from .serving import (CacheStats, FeatureCache, MicroBatch, MicroBatcher,
                      GNNServer, hot_node_ids, SERVE_APPS)

__all__ = [
    "BlockGraph", "block_gspmm", "block_supports", "block_edge_softmax",
    "build_reverse_table", "attach_reverse", "serve_block_signature",
    "CacheStats", "FeatureCache", "MicroBatch", "MicroBatcher",
    "GNNServer", "hot_node_ids", "SERVE_APPS",
    "RelGraph", "from_typed", "from_rels", "hetero_gspmm",
    "hetero_block_gspmm",
    "Graph", "from_coo", "reverse", "add_self_loops",
    "ELLPack", "ELLClass", "TilePack", "build_ell",
    "build_ell_uniform", "build_tiles",
    "planner", "GraphStats", "Plan", "PlanCache", "get_plan_cache",
    "use_ring", "active_ring",
    "PartitionStats", "PartitionedGraph", "build_partition",
    "ring_gspmm", "ring_edge_values", "bucket_softmax",
    "local_gspmm", "ring_gspmm_delayed", "ring_reference",
    "BRSpec", "parse_op", "gspmm", "gsddmm", "copy_reduce",
    "binary_reduce", "BINARY_OPS", "REDUCE_OPS",
    "edge_softmax", "edge_softmax_fused", "fused_attention",
    "block_fused_attention", "fused_attention_partitioned",
]

"""The Binary-Reduce / Copy-Reduce primitive lattice (paper §2).

``BR(x, y, ⊗, ⊕, z) : z ← ⊕(⊗(x, y), z)`` over a graph, where the operands
live on source nodes (``u``), destination nodes (``v``) or edges (``e``);

  ⊗ ∈ {add, sub, mul, div, dot, copy}          (element-wise; dot sums feat)
  ⊕ ∈ {add(sum), max, min, mul(prod), mean, copy}

Configs are named DGL-style, e.g. ``u_mul_e_add_v`` (BR) or ``u_copy_add_v``
(CR) — exactly the names in the paper's Table 2. ``copy`` as the reducer
means the per-edge result is written to edges without reduction.

The reduce stage dispatches across execution strategies (see
``strategies.py``): ``push`` (baseline Alg. 1), ``segment`` (Alg. 2),
``ell`` (Alg. 3 blocked pull), ``onehot`` (MXU adaptation), ``pallas``
(TPU kernel, see ``repro.kernels``). By default (``strategy="auto"``)
the planner (``planner.py``) selects the strategy from graph statistics
and memoizes any blocked packs per graph; pinning a strategy reproduces
the paper's baseline-vs-optimized experiments, and a pinned strategy
that cannot execute a spec falls back gracefully instead of raising.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import planner
from . import strategies as S
from .graph import Graph
from ..obs.events import timed as _timed
from .tiling import ELLPack, TilePack

__all__ = ["BRSpec", "parse_op", "gspmm", "gsddmm", "copy_reduce",
           "binary_reduce", "BINARY_OPS", "REDUCE_OPS", "OP_TARGETS"]

OP_TARGETS = ("u", "v", "e")

BINARY_OPS: Dict[str, Callable] = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "dot": lambda a, b: jnp.sum(a * b, axis=-1, keepdims=True),
    "copy": lambda a, b: a,  # unary: rhs ignored (CR, Eq. 3)
}

# DGL name -> internal reducer name
REDUCE_OPS: Dict[str, str] = {
    "add": "sum", "sum": "sum", "max": "max", "min": "min",
    "mul": "prod", "prod": "prod", "mean": "mean", "copy": "none",
}


@dataclasses.dataclass(frozen=True)
class BRSpec:
    """Parsed configuration of a Binary-Reduce."""
    lhs: str          # 'u' | 'v' | 'e'
    op: str           # key of BINARY_OPS
    rhs: Optional[str]  # 'u' | 'v' | 'e' | None (CR)
    reduce: str       # 'sum'|'max'|'min'|'prod'|'mean'|'none'
    out: str          # 'u' | 'v' | 'e'

    @property
    def name(self) -> str:
        r = "copy" if self.reduce == "none" else (
            "add" if self.reduce == "sum" else
            "mul" if self.reduce == "prod" else self.reduce)
        if self.op == "copy":
            return f"{self.lhs}_copy_{r}_{self.out}"
        return f"{self.lhs}_{self.op}_{self.rhs}_{r}_{self.out}"


def parse_op(name: str) -> BRSpec:
    """Parse a DGL-style op name into a :class:`BRSpec`.

    CR: ``<x>_copy_<red>_<z>``; BR: ``<x>_<op>_<y>_<red>_<z>``.
    """
    toks = name.split("_")
    if len(toks) == 4 and toks[1] == "copy":
        lhs, _, red, out = toks
        rhs = None
        op = "copy"
    elif len(toks) == 5:
        lhs, op, rhs, red, out = toks
        if rhs not in OP_TARGETS:
            raise ValueError(f"bad rhs target in {name!r}")
    else:
        raise ValueError(f"cannot parse BR op name {name!r}")
    if lhs not in OP_TARGETS or out not in OP_TARGETS:
        raise ValueError(f"bad operand targets in {name!r}")
    if op not in BINARY_OPS:
        raise ValueError(f"unknown binary op in {name!r}")
    if red not in REDUCE_OPS:
        raise ValueError(f"unknown reduce op in {name!r}")
    return BRSpec(lhs=lhs, op=op, rhs=rhs, reduce=REDUCE_OPS[red], out=out)


# --------------------------------------------------------------------- #
# operand gathering (canonical edge order = sorted by dst)
# --------------------------------------------------------------------- #
def _edge_val(g: Graph, target: str, data: jnp.ndarray) -> jnp.ndarray:
    """Per-edge operand values in canonical edge order."""
    if target == "u":
        return jnp.take(data, g.src, axis=0)
    if target == "v":
        return jnp.take(data, g.dst, axis=0)
    if target == "e":
        return jnp.take(data, g.eid, axis=0)
    raise ValueError(target)


def _as2d(x: jnp.ndarray) -> jnp.ndarray:
    return x[:, None] if x.ndim == 1 else x


# --------------------------------------------------------------------- #
# ⊗-adjoint machinery (shared by the gsddmm VJP and blocks.py's
# reverse-table backward)
# --------------------------------------------------------------------- #
def _unbroadcast(grad: jnp.ndarray, feat_shape: Tuple[int, ...]
                 ) -> jnp.ndarray:
    """Reduce a per-edge gradient ``(E, *G)`` to an operand's per-edge
    shape ``(E, *feat_shape)`` (right-aligned broadcasting adjoint)."""
    extra = (grad.ndim - 1) - len(feat_shape)
    if extra > 0:
        grad = grad.sum(axis=tuple(range(1, 1 + extra)))
    axes = tuple(i + 1 for i, w in enumerate(feat_shape)
                 if w == 1 and grad.shape[i + 1] != 1)
    if axes:
        grad = grad.sum(axis=axes, keepdims=True)
    return grad


# ⊗-adjoint factors: which operand values the partial derivative needs
_NEEDS_OTHER = ("mul", "div", "dot")


def _dmsg(op: str, side: str, lhs_val, rhs_val, ct_e):
    """Per-edge cotangent of ``msg = lhs ⊗ rhs`` w.r.t. one side."""
    if op in ("copy", "add"):
        return ct_e
    if op == "sub":
        return ct_e if side == "l" else -ct_e
    if op in ("mul", "dot"):    # dot: ct_e has a trailing 1 — broadcasts
        return ct_e * (rhs_val if side == "l" else lhs_val)
    if op == "div":
        if side == "l":
            return ct_e / rhs_val
        return -ct_e * lhs_val / (rhs_val * rhs_val)
    raise ValueError(f"no ⊗-adjoint for {op!r}")


# --------------------------------------------------------------------- #
# main entry
# --------------------------------------------------------------------- #
def gspmm(g: Graph, op_name: str, *,
          u: Optional[jnp.ndarray] = None,
          v: Optional[jnp.ndarray] = None,
          e: Optional[jnp.ndarray] = None,
          strategy: str = "auto",
          ell: Optional[ELLPack] = None,
          tiles: Optional[TilePack] = None,
          cache: Optional[planner.PlanCache] = None) -> jnp.ndarray:
    """Generalized sparse aggregation (paper Eq. 1/3).

    Operand tensors are indexed by node/edge id: ``u``: (n_src, d) or
    (n_src,), ``v``: (n_dst, d), ``e``: (n_edges, d) in the caller's
    original edge order. Returns features on ``spec.out`` — edge outputs
    are returned in the caller's original edge order.

    ``strategy="auto"`` (default) routes through the planner; explicit
    ``ell``/``tiles`` packs override the per-graph :class:`PlanCache`,
    and ``cache`` carries a pre-populated cache through ``jit`` (model
    bundles do this so planning works inside jitted train steps).
    """
    spec = parse_op(op_name)
    data = {"u": u, "v": v, "e": e}
    if data[spec.lhs] is None:
        raise ValueError(f"{op_name}: operand {spec.lhs!r} missing")
    if spec.rhs is not None and data[spec.rhs] is None:
        raise ValueError(f"{op_name}: operand {spec.rhs!r} missing")

    lhs_data = _as2d(data[spec.lhs])
    rhs_data = _as2d(data[spec.rhs]) if spec.rhs is not None else None

    # edge outputs are gSDDMMs — delegate to the planned path. Pinned
    # gspmm strategy names map onto the sddmm lattice: pallas stays
    # pallas, the baselines (push/segment) pin the caller-order gather,
    # the optimized names pin the canonical stream.
    if spec.out == "e":
        sddmm_req = {"auto": "auto", "pallas": "pallas",
                     "push": "gather", "segment": "gather"
                     }.get(strategy, "canonical")
        return gsddmm(g, op_name, u=u, v=v, e=e, strategy=sddmm_req)

    if spec.reduce == "none":
        raise ValueError(f"{op_name}: copy-reduce to nodes needs a reducer")

    runner = None
    if planner.get_mode() == "autotune" and strategy == "auto":
        def runner(s):
            return gspmm(g, op_name, u=u, v=v, e=e, strategy=s,
                         ell=ell, tiles=tiles, cache=cache)

    plan = planner.plan_gspmm(g, spec, lhs_data, rhs_data,
                              requested=strategy, cache=cache,
                              ell=ell, tiles=tiles, runner=runner)
    # eager calls are fenced + timed under the op's plan-log key, so
    # drift_report can hold the cost model against reality
    out = _timed(spec.name,
                 lambda: _execute(g, spec, lhs_data, rhs_data, plan))
    # node outputs keep the feature operand's dtype: a bf16 feature
    # against fp32 edge norms silently promotes the message stream to
    # fp32 under JAX's type rules, which would upcast every layer of a
    # half-precision model after its first aggregation
    if (jnp.issubdtype(lhs_data.dtype, jnp.floating)
            and jnp.issubdtype(out.dtype, jnp.floating)
            and out.dtype != lhs_data.dtype):
        out = out.astype(lhs_data.dtype)
    return out


# --------------------------------------------------------------------- #
# gSDDMM: planned edge-output computation (DESIGN.md §9)
# --------------------------------------------------------------------- #
def gsddmm(g: Graph, op_name: str, *,
           u: Optional[jnp.ndarray] = None,
           v: Optional[jnp.ndarray] = None,
           e: Optional[jnp.ndarray] = None,
           strategy: str = "auto") -> jnp.ndarray:
    """Generalized SDDMM: per-edge ⊗ of node/edge operands (the second
    core primitive of the DGL architecture — attention logits, softmax
    shift/divide, bilinear edge scores).

    Operand conventions match :func:`gspmm`; the op's ``out`` target
    must be ``e``. Returns (n_edges, d) in the caller's original edge
    order (1-D operands widen to d=1, like the node-output path).

    ``strategy``: 'auto' (planner, logged ``sddmm:<op>``), 'canonical'
    (gather in dst-sorted order, ⊗ on the sorted stream, one un-permute
    out), 'gather' (operands gathered straight into caller order — the
    DGL-style baseline), or 'pallas' (tiled kernel over the canonical
    stream, ``repro.kernels.sddmm``).

    Floating operands run under a scatter-free custom VJP: ∂u rides the
    graph's free src-sorted view (``perm_src`` + one SORTED segment
    reduce), ∂v the canonical dst-sorted stream, ∂e stays per-edge —
    mirroring the reverse-block backward, no scatter anywhere.
    """
    spec = parse_op(op_name)
    if spec.out != "e":
        raise ValueError(f"{op_name}: gsddmm computes edge outputs "
                         f"(got out={spec.out!r}); use gspmm")
    data = {"u": u, "v": v, "e": e}
    if data[spec.lhs] is None:
        raise ValueError(f"{op_name}: operand {spec.lhs!r} missing")
    if spec.rhs is not None and data[spec.rhs] is None:
        raise ValueError(f"{op_name}: operand {spec.rhs!r} missing")

    lhs_data = _as2d(data[spec.lhs])
    rhs_data = _as2d(data[spec.rhs]) if spec.rhs is not None else None

    if spec.op == "dot":
        d = 1
    elif rhs_data is None:
        d = int(math.prod(lhs_data.shape[1:]))
    else:
        d = int(max(math.prod(lhs_data.shape[1:]),
                    math.prod(rhs_data.shape[1:])))

    runner = None
    if (planner.get_mode() == "autotune" and strategy == "auto"
            and not planner.graph_is_traced(g)
            and not planner._is_traced(lhs_data)
            and (rhs_data is None
                 or not planner._is_traced(rhs_data))):
        def runner(s):
            return _sddmm_execute(g, spec, lhs_data, rhs_data, s)

    chosen = planner.plan_sddmm((g.n_src, g.n_dst, g.n_edges), spec, d,
                                requested=strategy, lhs_data=lhs_data,
                                rhs_data=rhs_data, runner=runner)

    floating = (jnp.issubdtype(lhs_data.dtype, jnp.floating)
                and (rhs_data is None
                     or jnp.issubdtype(rhs_data.dtype, jnp.floating)))
    if floating:
        return _timed(f"sddmm:{spec.name}",
                      lambda: _sddmm_exec_rev(spec, chosen, g,
                                              lhs_data, rhs_data))
    return _timed(f"sddmm:{spec.name}",
                  lambda: _sddmm_execute(g, spec, lhs_data, rhs_data,
                                         chosen))


def _sddmm_execute(g: Graph, spec: BRSpec, lhs_data, rhs_data,
                   chosen: str) -> jnp.ndarray:
    """Run one edge-output BR with an already-resolved strategy."""
    if chosen == "gather":
        # caller-order view of the endpoints: one double-indirect
        # gather per operand, no output permute
        src_c = jnp.take(g.src, g.eid_inv)
        dst_c = jnp.take(g.dst, g.eid_inv)

        def fetch(target, data):
            if target == "u":
                return jnp.take(data, src_c, axis=0)
            if target == "v":
                return jnp.take(data, dst_c, axis=0)
            return data                       # e: identity in caller order

        lhs_val = fetch(spec.lhs, lhs_data)
        rhs_val = (fetch(spec.rhs, rhs_data)
                   if spec.rhs is not None else None)
        return BINARY_OPS[spec.op](lhs_val, rhs_val)

    # canonical / pallas: dst-sorted operand streams, one un-permute out
    lhs_val = _edge_val(g, spec.lhs, lhs_data)
    rhs_val = (_edge_val(g, spec.rhs, rhs_data)
               if spec.rhs is not None else None)
    if chosen == "pallas":
        from repro.kernels.sddmm.ops import sddmm as sddmm_pallas

        msg = sddmm_pallas(lhs_val, rhs_val, spec.op)
    else:
        msg = BINARY_OPS[spec.op](lhs_val, rhs_val)
    return jnp.take(msg, g.eid_inv, axis=0)


def _sddmm_grads(g: Graph, spec: BRSpec, lhs_data, rhs_data, ct):
    """Scatter-free adjoints of one edge-output BR.

    ∂(u-operand): per-edge cotangent products pulled through the graph's
    src-sorted view (``perm_src``) + one SORTED segment reduce.
    ∂(v-operand): same products on the canonical dst-sorted stream.
    ∂(e-operand): per-edge, directly in caller order. Mirrors the
    reverse-block VJP — no scatter anywhere.
    """
    perm = g.perm_src
    src_sorted = jnp.take(g.src, perm)
    orders = {
        "srcsort": (src_sorted, jnp.take(g.dst, perm),
                    jnp.take(g.eid, perm)),
        "canon": (g.src, g.dst, g.eid),
        "caller": (jnp.take(g.src, g.eid_inv), jnp.take(g.dst, g.eid_inv),
                   None),      # eid in caller order is the identity
    }

    def fetch(target, data, order):
        s, dd, eid = orders[order]
        if target == "u":
            return jnp.take(data, s, axis=0)
        if target == "v":
            return jnp.take(data, dd, axis=0)
        return data if eid is None else jnp.take(data, eid, axis=0)

    def ct_in(order):
        # ct arrives in caller edge order; eid maps any other order's
        # positions back to caller ids
        eid = orders[order][2]
        return ct if eid is None else jnp.take(ct, eid, axis=0)

    def grad_for(side):
        target = spec.lhs if side == "l" else spec.rhs
        data = lhs_data if side == "l" else rhs_data
        other = rhs_data if side == "l" else lhs_data
        other_t = spec.rhs if side == "l" else spec.lhs
        order = {"u": "srcsort", "v": "canon", "e": "caller"}[target]
        lhs_val = rhs_val = None
        if spec.op in _NEEDS_OTHER:
            val = fetch(other_t, other, order)
            lhs_val, rhs_val = ((None, val) if side == "l" else (val, None))
            if spec.op == "div" and side == "r":
                rhs_val = fetch(target, data, order)  # d/dr needs both
        gmsg = _dmsg(spec.op, side, lhs_val, rhs_val, ct_in(order))
        gmsg = _unbroadcast(gmsg, tuple(data.shape[1:]))
        if target == "u":
            out = jax.ops.segment_sum(gmsg, src_sorted,
                                      num_segments=g.n_src,
                                      indices_are_sorted=True)
        elif target == "v":
            out = jax.ops.segment_sum(gmsg, g.dst,
                                      num_segments=g.n_dst,
                                      indices_are_sorted=True)
        else:
            out = gmsg
        return out.astype(data.dtype)

    dlhs = grad_for("l")
    drhs = grad_for("r") if spec.rhs is not None else None
    return dlhs, drhs


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sddmm_exec_rev(spec: BRSpec, chosen: str, g: Graph,
                    lhs_data, rhs_data):
    """``_sddmm_execute`` with the scatter-free backward."""
    return _sddmm_execute(g, spec, lhs_data, rhs_data, chosen)


def _sddmm_exec_rev_fwd(spec, chosen, g, lhs_data, rhs_data):
    out = _sddmm_execute(g, spec, lhs_data, rhs_data, chosen)
    return out, (g, lhs_data, rhs_data)


def _sddmm_exec_rev_bwd(spec, chosen, res, ct):
    g, lhs_data, rhs_data = res
    dlhs, drhs = _sddmm_grads(g, spec, lhs_data, rhs_data, ct)
    return None, dlhs, drhs


_sddmm_exec_rev.defvjp(_sddmm_exec_rev_fwd, _sddmm_exec_rev_bwd)


def _execute(g: Graph, spec: BRSpec, lhs_data, rhs_data,
             plan: planner.Plan) -> jnp.ndarray:
    """Run one node-output BR with a resolved plan."""
    if plan.strategy == "ell":
        return _gspmm_ell(g, spec, plan.ell, lhs_data, rhs_data)
    if plan.strategy == "onehot":
        return _gspmm_onehot(g, spec, plan.tiles, lhs_data, rhs_data)
    if plan.strategy == "pallas":
        return _gspmm_pallas_diff(g, spec, plan.tiles, lhs_data, rhs_data)
    if plan.strategy == "ring":
        return _gspmm_ring(g, spec, plan.partition, lhs_data, rhs_data)

    # ---- generic path: per-edge messages then reduce
    lhs_val = _edge_val(g, spec.lhs, lhs_data)
    rhs_val = (_edge_val(g, spec.rhs, rhs_data)
               if spec.rhs is not None else None)
    msg = BINARY_OPS[spec.op](lhs_val, rhs_val)

    if spec.out == "v":
        tgt, n_tgt, deg = g.dst, g.n_dst, g.in_degrees
    else:  # 'u'
        msg = jnp.take(msg, g.perm_src, axis=0)
        tgt = jnp.take(g.src, g.perm_src)
        n_tgt, deg = g.n_src, g.out_degrees

    if plan.strategy == "push":
        return S.push_scatter(msg, tgt, n_tgt, spec.reduce, deg)
    # default: segment (Alg. 2)
    return S.pull_segment(msg, tgt, n_tgt, spec.reduce, deg)


def _gspmm_ring(g: Graph, spec: BRSpec, pg, lhs_data, rhs_data
                ) -> jnp.ndarray:
    """Partitioned (multi-device ring) execution of a weighted CR.

    The planner only routes here under an active :func:`planner.use_ring`
    context (``supports``/``pack_available`` gate it), so the mesh is
    live. Mean folds 1/deg into the per-edge weights — the ring itself
    is a pure weighted CR-sum (core/partition.py). Layout conversions
    happen per call; partitioned *training* keeps features in the
    padded sharded layout end-to-end instead (models/gnn/train.py).
    """
    from .partition import ring_gspmm

    ctx = planner.active_ring()
    # weights stay at ≥fp32: degree norms truncated to bf16 before the
    # multiply lose precision the fp32 accumulators can't win back
    wdt = (jnp.promote_types(lhs_data.dtype, jnp.float32)
           if jnp.issubdtype(lhs_data.dtype, jnp.floating)
           else lhs_data.dtype)
    if spec.op == "mul":
        w = rhs_data[:, 0]
    else:                       # copy
        w = jnp.ones((g.n_edges,), wdt)
    if spec.reduce == "mean":
        deg = jnp.maximum(g.in_degrees, 1).astype(wdt)
        dst_caller = jnp.take(g.dst, g.eid_inv)
        w = w / jnp.take(deg, dst_caller)
    out = ring_gspmm(pg, pg.scatter_nodes(lhs_data), pg.scatter_edges(w),
                     mesh=ctx.mesh if ctx is not None else None,
                     axis=ctx.axis if ctx is not None else "data")
    return pg.gather_nodes(out, g.n_dst)


def _gspmm_pallas_diff(g: Graph, spec: BRSpec, tiles, lhs_data, rhs_data
                       ) -> jnp.ndarray:
    """Pallas forward with a segment-path adjoint.

    ``pallas_call`` has no transpose rule (and interpret mode never will),
    but the kernel computes the same operator as the segment strategy —
    so the segment path's VJP IS the pallas path's VJP. This keeps the
    planner free to choose pallas inside differentiated train steps.
    """
    from repro.kernels.dispatch import gspmm_pallas

    seg_plan = planner.Plan(strategy="segment", requested="segment",
                            reason="pallas-adjoint")

    def seg(l, r):
        return _execute(g, spec, l, r, seg_plan)

    if rhs_data is None:
        @jax.custom_vjp
        def f(l):
            return gspmm_pallas(g, spec, l, None, tiles=tiles)

        f.defvjp(lambda l: (f(l), (l,)),
                 lambda res, ct: jax.vjp(lambda l: seg(l, None),
                                         *res)[1](ct))
        return f(lhs_data)

    @jax.custom_vjp
    def f2(l, r):
        return gspmm_pallas(g, spec, l, r, tiles=tiles)

    f2.defvjp(lambda l, r: (f2(l, r), (l, r)),
              lambda res, ct: jax.vjp(seg, *res)[1](ct))
    return f2(lhs_data, rhs_data)


def _gspmm_ell(g: Graph, spec: BRSpec, pack: ELLPack,
               lhs_data, rhs_data) -> jnp.ndarray:
    """Blocked pull with the ⊗ fused into the per-class chunk gather."""
    def chunk_fetch(cls, target: str, data):
        if target == "u":
            return jnp.take(data, cls.chunk_cols, axis=0)      # (C, W, d)
        if target == "e":
            return jnp.take(data, cls.chunk_eids, axis=0)
        if target == "v":
            val = jnp.take(data, cls.chunk_row, axis=0)        # (C, d)
            return val[:, None, :]                             # broadcast W
        raise ValueError(target)

    def msg_fn(cls):
        lhs_val = chunk_fetch(cls, spec.lhs, lhs_data)
        rhs_val = (chunk_fetch(cls, spec.rhs, rhs_data)
                   if spec.rhs is not None else None)
        return BINARY_OPS[spec.op](lhs_val, rhs_val)

    return S.pull_ell_reduce(pack, msg_fn, spec.reduce, deg=g.in_degrees)


def _gspmm_onehot(g: Graph, spec: BRSpec, tiles: TilePack,
                  lhs_data, rhs_data) -> jnp.ndarray:
    """MXU one-hot SpMM path. Supports u_copy_{add,mean}_v and
    u_mul_e_{add,mean}_v with scalar edge weights (the planner's
    ``supports()`` predicate gates dispatch onto this path)."""
    if spec.lhs != "u":
        raise ValueError("onehot strategy needs lhs on source nodes")
    w = None
    if spec.op == "mul" and spec.rhs == "e":
        ew = rhs_data
        if ew.shape[-1] != 1:
            raise ValueError("onehot edge weights must be scalar per edge")
        w = jnp.take(ew[:, 0], tiles.eids, axis=0)  # (T, eb)
    elif spec.op != "copy":
        raise ValueError(f"onehot strategy does not support ⊗={spec.op}")
    return S.onehot_spmm(tiles, lhs_data, spec.reduce, edge_weight=w,
                         deg=g.in_degrees)


# --------------------------------------------------------------------- #
# sugar
# --------------------------------------------------------------------- #
def copy_reduce(g: Graph, x: jnp.ndarray, reduce: str = "sum",
                strategy: str = "auto", **kw) -> jnp.ndarray:
    """CR: ``u_copy_<reduce>_v`` (paper Eq. 3/4)."""
    red = {"sum": "add", "prod": "mul"}.get(reduce, reduce)
    return gspmm(g, f"u_copy_{red}_v", u=x, strategy=strategy, **kw)


def binary_reduce(g: Graph, op_name: str, lhs: jnp.ndarray,
                  rhs: Optional[jnp.ndarray] = None,
                  strategy: str = "auto", **kw) -> jnp.ndarray:
    """Positional-operand flavour: operands assigned per the op name."""
    spec = parse_op(op_name)
    ops: Dict[str, jnp.ndarray] = {spec.lhs: lhs}
    if spec.rhs is not None:
        if rhs is None:
            raise ValueError(f"{op_name} needs two operands")
        if spec.rhs == spec.lhs:
            raise ValueError(f"{op_name}: operands share a target; use gspmm")
        ops[spec.rhs] = rhs
    return gspmm(g, op_name, strategy=strategy,
                 **{k: v for k, v in ops.items()}, **kw)

"""Minibatch block-graph execution — sampled training (paper Fig. 3).

A *block* is the bipartite graph of one message-passing layer of a
sampled minibatch: sources are the layer-l frontier nodes, destinations
the layer-(l+1) seeds. Blocks produced by :class:`repro.data.NeighborSampler`
are padded to fully static shapes (node pads into a trailing dummy source
slot, edge pads into a trailing dummy destination row) so one jitted
train step serves every batch.

Because every real destination row holds at most ``fanout`` sampled
in-edges, a block admits a *uniform* blocked-pull format for free: the
sampler emits a dense ``(n_dst_real, fanout)`` neighbor table
(:class:`BlockGraph.nbr`) alongside the COO graph. That table is the
single-class analogue of the degree-bucketed :class:`~repro.core.tiling.ELLPack`
— no host-side pack build, no per-batch pytree-structure changes, and a
mask-corrected mean so pad slots contribute exactly zero.

:func:`block_gspmm` mirrors :func:`repro.core.binary_reduce.gspmm` for
blocks. ``strategy="auto"`` routes through the planner's *shape-keyed*
block plan cache (:func:`repro.core.planner.plan_block_gspmm`): the
decision depends only on the static padded shapes + op + feature width,
so it is stable across batches and valid inside a trace.

Training (DESIGN.md §7): autodiff of any forward block strategy turns
the ∂x computation into a scatter-add — the push pathology the paper
removed from the forward. The sampler therefore also emits a *reverse
table* (the block's edges sorted by source slot: ``rev_src``/
``rev_dst``/``rev_eid``) and :func:`block_gspmm` wraps sum/mean/max/min
reducers in a custom VJP that computes ∂x as a masked pull over that
table (gather cotangents at consuming destinations + one sorted
segment reduce) and ∂e as gathered per-edge products; for max/min the
forward records the winning slot per output element and the pull zeroes
every other slot's cotangent. The backward
strategy is planned independently of the forward one
(:func:`repro.core.planner.plan_block_vjp`, logged as
``block_bwd:<op>``) — ``gather`` is the reverse-table pull, ``scatter``
the autodiff baseline.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import planner
from ..obs.events import timed as _timed
from .binary_reduce import (BINARY_OPS, BRSpec, _NEEDS_OTHER, _as2d,
                            _dmsg, _execute, _unbroadcast, gspmm,
                            parse_op)
from .graph import Graph
from .strategies import REDUCE_IDENTITY

__all__ = ["BlockGraph", "block_gspmm", "block_supports",
           "build_reverse_table", "attach_reverse",
           "serve_block_signature"]


def serve_block_signature(batch_size: int, fanouts, n_layers=None):
    """Predict ``MiniBatch.shape_signature()`` for a sampler config.

    Mirrors ``NeighborSampler``'s static layer-size math — every batch
    of ``batch_size`` seeds under ``fanouts`` (an int with ``n_layers``,
    or a per-layer sequence) produces blocks with EXACTLY these
    ``(n_src_pad, n_dst, n_edges_pad, fanout)`` signatures, outermost
    hop first. The serving tier plans and pre-registers compile-cache
    signatures from this without sampling anything.
    """
    if isinstance(fanouts, int):
        if n_layers is None:
            raise ValueError("int fanout needs n_layers")
        fanouts = [fanouts] * int(n_layers)
    fanouts = list(fanouts)
    sizes = [int(batch_size)]
    for f in reversed(fanouts):
        sizes.append(sizes[-1] * (int(f) + 1))
    sigs = [(sizes[li + 1], sizes[li], sizes[li] * int(f), int(f))
            for li, f in enumerate(reversed(fanouts))]
    return tuple(reversed(sigs))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class BlockGraph:
    """One sampled bipartite layer with its uniform neighbor table.

    ``g`` is the padded COO/CSR block graph (``n_dst = n_dst_real + 1``:
    the extra row absorbs pad edges). The neighbor table views the same
    edges row-major: ``nbr[j, k]`` is the source *slot* of destination
    ``j``'s k-th sampled in-edge (pad slots point at the dummy source and
    are masked out), ``nbr_eid[j, k]`` the matching caller-order edge id
    (edge features are indexed with it), and ``real_deg[j]`` the number
    of real sampled in-edges — the mask-corrected mean denominator.

    The optional *reverse table* (``rev_src``/``rev_dst``/``rev_eid``,
    emitted for free by the sampler) views the same edges sorted by
    source slot: ``rev_src[t]`` is non-decreasing, ``rev_dst[t]`` the
    destination row that consumed reverse slot ``t``, ``rev_eid[t]`` the
    matching caller-order edge id. Pad edges sort last (their source is
    the dummy slot) and point at the dummy destination row, so a zero
    cotangent row masks them out of the gather backward exactly.
    """
    g: Graph
    nbr: jnp.ndarray        # (n_dst_real, fanout) int32 source slots
    nbr_eid: jnp.ndarray    # (n_dst_real, fanout) int32 caller edge ids
    nbr_mask: jnp.ndarray   # (n_dst_real, fanout) bool — True for real edges
    real_deg: jnp.ndarray   # (n_dst_real,) int32
    n_dst_real: int = dataclasses.field(metadata={"static": True})
    fanout: int = dataclasses.field(metadata={"static": True})
    rev_src: Optional[jnp.ndarray] = None   # (n_edges,) int32, sorted
    rev_dst: Optional[jnp.ndarray] = None   # (n_edges,) int32 dst rows
    rev_eid: Optional[jnp.ndarray] = None   # (n_edges,) int32 caller ids

    def tree_flatten(self):
        return ((self.g, self.nbr, self.nbr_eid, self.nbr_mask,
                 self.real_deg, self.rev_src, self.rev_dst,
                 self.rev_eid), (self.n_dst_real, self.fanout))

    @classmethod
    def tree_unflatten(cls, aux, children):
        (g, nbr, nbr_eid, nbr_mask, real_deg,
         rev_src, rev_dst, rev_eid) = children
        return cls(g=g, nbr=nbr, nbr_eid=nbr_eid, nbr_mask=nbr_mask,
                   real_deg=real_deg, n_dst_real=aux[0], fanout=aux[1],
                   rev_src=rev_src, rev_dst=rev_dst, rev_eid=rev_eid)

    @property
    def has_reverse(self) -> bool:
        """True when the reverse table is attached (gather backward
        available)."""
        return self.rev_src is not None

    @property
    def signature(self) -> Tuple[int, int, int, int]:
        """Static shape signature — the planner's block-plan cache key."""
        return (self.g.n_src, self.n_dst_real, self.g.n_edges, self.fanout)

    def __repr__(self):
        return (f"BlockGraph(n_src={self.g.n_src}, "
                f"n_dst_real={self.n_dst_real}, fanout={self.fanout})")


def build_reverse_table(g: Graph) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray]:
    """Host-side reverse table of a (concrete) block graph.

    Returns ``(rev_src, rev_dst, rev_eid)``: the edge list sorted by
    source slot (stable, so a source's consumers stay in canonical
    order), with ``rev_eid`` in CALLER edge order — the order edge
    features are indexed in. The sampler builds the same arrays directly
    from its edge lists; this is the fallback for hand-built blocks.
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    eid = np.asarray(g.eid)
    order = np.argsort(src, kind="stable")
    return (src[order].astype(np.int32), dst[order].astype(np.int32),
            eid[order].astype(np.int32))


def attach_reverse(bg: BlockGraph) -> BlockGraph:
    """Return ``bg`` with the reverse table attached (no-op if present).

    Needs a concrete (non-traced) graph — sampler-produced blocks carry
    the table already; this serves blocks built by hand in tests or
    benchmarks.
    """
    if bg.has_reverse:
        return bg
    if planner.graph_is_traced(bg.g):
        raise ValueError("attach_reverse needs a concrete BlockGraph; "
                         "build the reverse table host-side (the sampler "
                         "emits it for free)")
    rev_src, rev_dst, rev_eid = build_reverse_table(bg.g)
    return dataclasses.replace(bg, rev_src=jnp.asarray(rev_src),
                               rev_dst=jnp.asarray(rev_dst),
                               rev_eid=jnp.asarray(rev_eid))


def block_supports(strategy: str, spec: BRSpec) -> bool:
    """Can ``strategy`` execute this spec on a block?

    The uniform pull ('ell') handles any ⊗ over u/v/e operands and every
    reducer, but only destination outputs. push/segment run the generic
    COO path on the padded graph. The MXU formulations (onehot/pallas)
    need host-built tile packs, which cannot be rebuilt per batch with a
    static pytree structure — they are never block candidates.
    """
    if spec.out != "v" or spec.reduce == "none":
        return False
    if strategy in ("push", "segment"):
        return True
    if strategy == "ell":
        return True
    return False  # onehot / pallas: no static per-batch tile pack


def _nbr_fetch(bg: BlockGraph, target: str, data: jnp.ndarray) -> jnp.ndarray:
    """Operand values laid out on the (n_dst_real, fanout) slot grid."""
    if target == "u":
        return jnp.take(data, bg.nbr, axis=0)            # (nd, F, d)
    if target == "e":
        return jnp.take(data, bg.nbr_eid, axis=0)        # (nd, F, d)
    if target == "v":
        # destination's own value, broadcast along the slot axis;
        # v operands are sized like g.n_dst (they include the pad row)
        return data[: bg.n_dst_real][:, None]            # (nd, 1, d)
    raise ValueError(target)


def _block_pull(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data
                ) -> jnp.ndarray:
    """Uniform blocked pull: dense masked reduce over the fanout axis."""
    lhs_val = _nbr_fetch(bg, spec.lhs, lhs_data)
    rhs_val = (_nbr_fetch(bg, spec.rhs, rhs_data)
               if spec.rhs is not None else None)
    msg = BINARY_OPS[spec.op](lhs_val, rhs_val)          # (nd, F, *feat)
    red = spec.reduce
    ident = jnp.asarray(REDUCE_IDENTITY[red], msg.dtype)
    mask = bg.nbr_mask.reshape(bg.nbr_mask.shape + (1,) * (msg.ndim - 2))
    msg = jnp.where(mask, msg, ident)
    base = "sum" if red in ("sum", "mean") else red
    if base == "sum":
        out = msg.sum(axis=1)
    elif base == "max":
        out = msg.max(axis=1)
    elif base == "min":
        out = msg.min(axis=1)
    elif base == "prod":
        out = msg.prod(axis=1)
    else:
        raise ValueError(f"unknown reduce op {red!r}")
    deg = bg.real_deg
    if red == "mean":
        d = jnp.maximum(deg, 1).astype(out.dtype)
        out = out / d.reshape((out.shape[0],) + (1,) * (out.ndim - 1))
    # DGL semantics: rows with no (real) incoming edge are 0 for every ⊕
    if red != "sum":
        has = (deg > 0).reshape((out.shape[0],) + (1,) * (out.ndim - 1))
        out = jnp.where(has, out, jnp.zeros((), out.dtype))
    return out


def block_gspmm(bg: BlockGraph, op_name: str, *,
                u: Optional[jnp.ndarray] = None,
                v: Optional[jnp.ndarray] = None,
                e: Optional[jnp.ndarray] = None,
                strategy: str = "auto",
                bwd_strategy: str = "auto") -> jnp.ndarray:
    """Generalized sparse aggregation over one sampled block.

    Same operand conventions as :func:`~repro.core.binary_reduce.gspmm`
    on ``bg.g`` — ``u``: (n_src_pad, d); ``v``: (n_dst_real + 1, d)
    (callers pad one dummy row); ``e``: (n_edges_pad, d) caller edge
    order. Node outputs are returned for REAL destination rows only:
    shape (n_dst_real, d) — the pad row is consumed internally.

    ``strategy="auto"`` consults the planner's shape-keyed block plan
    cache, so the choice is identical for every batch of the same
    sampler configuration and survives ``jit`` tracing. Pinned
    strategies unsupported on blocks fall back with a one-time warning.

    ``bwd_strategy`` picks the DIFFERENTIATION path, independently of
    the forward: ``"gather"`` wraps the call in the reverse-table
    custom VJP (∂x as a masked pull, no scatter — needs the sampler's
    reverse table and a linear reducer), ``"scatter"`` keeps plain
    autodiff, ``"auto"`` (default) lets the planner decide per shape
    signature (logged as ``block_bwd:<op>``).
    """
    spec = parse_op(op_name)
    data = {"u": u, "v": v, "e": e}
    if data[spec.lhs] is None:
        raise ValueError(f"{op_name}: operand {spec.lhs!r} missing")
    if spec.rhs is not None and data[spec.rhs] is None:
        raise ValueError(f"{op_name}: operand {spec.rhs!r} missing")
    if bwd_strategy != "auto" and \
            bwd_strategy not in planner.BLOCK_BWD_STRATEGIES:
        raise ValueError(
            f"unknown block backward strategy {bwd_strategy!r}; expected "
            f"one of {planner.BLOCK_BWD_STRATEGIES + ('auto',)}")

    # edge outputs are strategy-free gathers — delegate to the COO path
    # (their autodiff backward is already gather-shaped; bwd_strategy
    # does not apply)
    if spec.out == "e":
        return gspmm(bg.g, op_name, u=u, v=v, e=e)

    if spec.out != "v":
        raise ValueError(f"{op_name}: blocks only produce destination or "
                         f"edge outputs (got {spec.out!r})")
    if spec.reduce == "none":
        raise ValueError(f"{op_name}: copy-reduce to nodes needs a reducer")

    lhs_data = _as2d(data[spec.lhs])
    rhs_data = _as2d(data[spec.rhs]) if spec.rhs is not None else None
    d = int(np.prod(lhs_data.shape[1:]))

    concrete = (not planner.graph_is_traced(bg.g)
                and not planner._is_traced(lhs_data)
                and (rhs_data is None
                     or not planner._is_traced(rhs_data)))
    runner = None
    if (planner.get_mode() == "autotune" and strategy == "auto"
            and concrete):      # measuring candidates only works eagerly
        def runner(s):
            return _block_execute(bg, spec, lhs_data, rhs_data, s)

    chosen = planner.plan_block_gspmm(bg.signature, spec, d,
                                      requested=strategy, runner=runner,
                                      dtype=str(lhs_data.dtype))

    bwd_runner = None
    if (planner.get_mode() == "autotune" and bwd_strategy == "auto"
            and concrete and bg.has_reverse
            and jnp.issubdtype(lhs_data.dtype, jnp.floating)):
        def bwd_runner(s):      # measure the actual differentiated call
            def f(l):
                out = (_block_exec_rev(spec, chosen, bg, l, rhs_data)
                       if s == "gather"
                       else _block_execute(bg, spec, l, rhs_data, chosen))
                return jnp.sum(out)
            return jax.grad(f)(lhs_data)

    bwd = planner.plan_block_vjp(bg.signature, spec, d,
                                 requested=bwd_strategy,
                                 gather_available=bg.has_reverse,
                                 runner=bwd_runner,
                                 dtype=str(lhs_data.dtype))
    # eager calls (serve fan-out, the sampled-train drift probe) are
    # fenced + timed under the block's plan-log key; in-trace calls
    # pass straight through
    if bwd == "gather":
        return _timed(f"block:{spec.name}",
                      lambda: _block_exec_rev(spec, chosen, bg,
                                              lhs_data, rhs_data))
    if jnp.issubdtype(lhs_data.dtype, jnp.floating):
        # route the scatter backward through a custom_vjp shim so the
        # autodiff-derived bwd is also fenced + timed as block_bwd:<op>
        # when it runs eagerly (same computation either way)
        return _timed(f"block:{spec.name}",
                      lambda: _block_exec_scatter(spec, chosen, bg,
                                                  lhs_data, rhs_data))
    return _timed(f"block:{spec.name}",
                  lambda: _block_execute(bg, spec, lhs_data, rhs_data,
                                         chosen))


def _block_execute(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data,
                   chosen: str) -> jnp.ndarray:
    """Run one block aggregation with an already-resolved strategy."""
    if chosen == "ell":
        return _block_pull(bg, spec, lhs_data, rhs_data)
    # planning is already done (shape-keyed) — execute the resolved
    # strategy directly rather than re-entering gspmm's planning front
    # door, which would build a PlanCache + stats for every throwaway
    # per-batch block graph in eager mode
    plan = planner.Plan(strategy=chosen, requested=chosen,
                        reason="block")
    out = _execute(bg.g, spec, lhs_data, rhs_data, plan)
    return out[: bg.n_dst_real]


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _block_exec_scatter(spec: BRSpec, chosen: str, bg: BlockGraph,
                        lhs_data, rhs_data):
    """Scatter-strategy execute whose backward is the plain autodiff
    VJP of :func:`_block_execute`, replayed inside ``_timed`` so eager
    callers (the sampled-train drift probe, serve fan-out) record a
    ``block_bwd:<op>`` measurement for scatter just like the gather
    path does."""
    return _block_execute(bg, spec, lhs_data, rhs_data, chosen)


def _block_exec_scatter_fwd(spec, chosen, bg, lhs_data, rhs_data):
    out, vjp = jax.vjp(
        lambda l, r: _block_execute(bg, spec, l, r, chosen),
        lhs_data, rhs_data)
    # jax.vjp returns a tree_util.Partial — a valid pytree residual
    return out, vjp


def _block_exec_scatter_bwd(spec, chosen, vjp, ct):
    dlhs, drhs = _timed(f"block_bwd:{spec.name}", lambda: vjp(ct))
    return None, dlhs, drhs


_block_exec_scatter.defvjp(_block_exec_scatter_fwd,
                           _block_exec_scatter_bwd)


# --------------------------------------------------------------------- #
# reverse-block VJP: gather-based backward (DESIGN.md §7)
# --------------------------------------------------------------------- #
# (the ⊗-adjoint helpers — _unbroadcast / _NEEDS_OTHER / _dmsg — live in
# binary_reduce.py, shared with the gsddmm custom VJP)


def _slot_of_edge(bg: BlockGraph) -> jnp.ndarray:
    """(n_edges,) int32: each caller edge's slot ``k`` on the neighbor
    grid (``nbr[dst, k]``), -1 for pad edges — index prep for masking
    extrema cotangents to the winning slot."""
    flat_eid = bg.nbr_eid.reshape(-1)
    flat_mask = bg.nbr_mask.reshape(-1)
    slots = jax.lax.broadcasted_iota(
        jnp.int32, bg.nbr.shape, 1).reshape(-1)
    safe = jnp.where(flat_mask, flat_eid, bg.g.n_edges)
    k_of = jnp.full((bg.g.n_edges,), -1, jnp.int32)
    return k_of.at[safe].set(jnp.where(flat_mask, slots, -1), mode="drop")


def _block_arg_extrema(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data
                       ) -> jnp.ndarray:
    """Winning slot per (destination row, feature element) of a max/min
    reduce on the neighbor grid; -1 for rows with no real in-edge."""
    lhs_val = _nbr_fetch(bg, spec.lhs, lhs_data)
    rhs_val = (_nbr_fetch(bg, spec.rhs, rhs_data)
               if spec.rhs is not None else None)
    msg = BINARY_OPS[spec.op](lhs_val, rhs_val)          # (nd, F, *feat)
    ident = jnp.asarray(REDUCE_IDENTITY[spec.reduce], msg.dtype)
    mask = bg.nbr_mask.reshape(bg.nbr_mask.shape + (1,) * (msg.ndim - 2))
    msg = jnp.where(mask, msg, ident)
    arg = (jnp.argmax if spec.reduce == "max" else jnp.argmin)(msg, axis=1)
    has = (bg.real_deg > 0).reshape((arg.shape[0],)
                                    + (1,) * (arg.ndim - 1))
    return jnp.where(has, arg, -1).astype(jnp.int32)


def _reverse_grads(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data, ct,
                   arg=None):
    """Gather-based adjoints of one block aggregation.

    ∂(u-operand): masked pull over the reverse table — gather the
    (mean-scaled, zero-padded) cotangents at each source's consuming
    destinations, one SORTED segment reduce, no scatter. ∂(e-operand):
    per-edge products of gathered endpoint values, directly in caller
    edge order. ∂(v-operand): same per-edge products reduced over the
    forward CSR (canonical order is dst-sorted already). For max/min,
    ``arg`` is the recorded arg-extrema table: cotangents are zeroed on
    every slot except the winner before the pull, which is exactly the
    extrema adjoint. prod stays on the autodiff backward.
    """
    g = bg.g
    if spec.reduce == "mean":
        d = jnp.maximum(bg.real_deg, 1).astype(ct.dtype)
        ct = ct / d.reshape((ct.shape[0],) + (1,) * (ct.ndim - 1))
    # dummy destination row pulls exactly zero: pad edges (and only pad
    # edges) point at it, so no mask arithmetic is needed in the pull
    ct_pad = jnp.concatenate(
        [ct, jnp.zeros((1,) + ct.shape[1:], ct.dtype)], axis=0)

    orders = {
        "rev": (bg.rev_src, bg.rev_dst, bg.rev_eid),
        "canon": (g.src, g.dst, g.eid),
        "caller": (jnp.take(g.src, g.eid_inv), jnp.take(g.dst, g.eid_inv),
                   None),     # eid in caller order is the identity
    }

    if arg is not None:
        # extrema backward: only the winning slot's edge receives the
        # cotangent. arg_pad's dummy row is -1 and pad edges carry slot
        # -1, so they select each other — harmless, their ct is zero.
        k_of = _slot_of_edge(bg)
        arg_pad = jnp.concatenate(
            [arg, jnp.full((1,) + arg.shape[1:], -1, arg.dtype)], axis=0)

    def fetch(target, data, order):
        s, dd, e = orders[order]
        if target == "u":
            return jnp.take(data, s, axis=0)
        if target == "v":
            return jnp.take(data, dd, axis=0)
        return data if e is None else jnp.take(data, e, axis=0)

    def grad_for(side):
        target = spec.lhs if side == "l" else spec.rhs
        data = lhs_data if side == "l" else rhs_data
        other = rhs_data if side == "l" else lhs_data
        other_t = spec.rhs if side == "l" else spec.lhs
        order = {"u": "rev", "v": "canon", "e": "caller"}[target]
        lhs_val = rhs_val = None
        if spec.op in _NEEDS_OTHER:
            val = fetch(other_t, other, order)
            lhs_val, rhs_val = ((None, val) if side == "l" else (val, None))
            if spec.op == "div" and side == "r":
                rhs_val = fetch(target, data, order)  # d/dr needs both
        ct_e = jnp.take(ct_pad, orders[order][1], axis=0)
        if arg is not None:
            e_ids = orders[order][2]
            k_e = k_of if e_ids is None else jnp.take(k_of, e_ids)
            sel = (jnp.take(arg_pad, orders[order][1], axis=0)
                   == k_e.reshape((k_e.shape[0],)
                                  + (1,) * (arg_pad.ndim - 1)))
            ct_e = jnp.where(sel, ct_e, jnp.zeros((), ct_e.dtype))
        gmsg = _dmsg(spec.op, side, lhs_val, rhs_val, ct_e)
        gmsg = _unbroadcast(gmsg, tuple(data.shape[1:]))
        if target == "u":
            out = jax.ops.segment_sum(gmsg, orders[order][0],
                                      num_segments=g.n_src,
                                      indices_are_sorted=True)
        elif target == "v":
            out = jax.ops.segment_sum(gmsg, orders[order][1],
                                      num_segments=g.n_dst,
                                      indices_are_sorted=True)
        else:
            out = gmsg
        return out.astype(data.dtype)

    dlhs = grad_for("l")
    drhs = grad_for("r") if spec.rhs is not None else None
    return dlhs, drhs


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _block_exec_rev(spec: BRSpec, fwd_strategy: str, bg: BlockGraph,
                    lhs_data, rhs_data):
    """``_block_execute`` with the gather (reverse-table) backward."""
    return _block_execute(bg, spec, lhs_data, rhs_data, fwd_strategy)


def _block_exec_rev_fwd(spec, fwd_strategy, bg, lhs_data, rhs_data):
    out = _block_execute(bg, spec, lhs_data, rhs_data, fwd_strategy)
    arg = (_block_arg_extrema(bg, spec, lhs_data, rhs_data)
           if spec.reduce in ("max", "min") else None)
    return out, (bg, lhs_data, rhs_data, arg)


def _block_exec_rev_bwd(spec, fwd_strategy, res, ct):
    bg, lhs_data, rhs_data, arg = res
    # executes eagerly under an un-jitted vjp replay (the drift probe),
    # where _timed measures the gather backward as block_bwd:<op>
    dlhs, drhs = _timed(
        f"block_bwd:{spec.name}",
        lambda: _reverse_grads(bg, spec, lhs_data, rhs_data, ct, arg=arg))
    return None, dlhs, drhs


_block_exec_rev.defvjp(_block_exec_rev_fwd, _block_exec_rev_bwd)

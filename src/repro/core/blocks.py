"""Minibatch block-graph execution — sampled training (paper Fig. 3).

A *block* is the bipartite graph of one message-passing layer of a
sampled minibatch: sources are the layer-l frontier nodes, destinations
the layer-(l+1) seeds. Blocks produced by :class:`repro.data.NeighborSampler`
are padded to fully static shapes (node pads into a trailing dummy source
slot, edge pads into a trailing dummy destination row) so one jitted
train step serves every batch.

Because every real destination row holds at most ``fanout`` sampled
in-edges, a block admits a *uniform* blocked-pull format for free: the
sampler emits a dense ``(n_dst_real, fanout)`` neighbor table
(:class:`BlockGraph.nbr`) alongside the COO graph. That table is the
single-class analogue of the degree-bucketed :class:`~repro.core.tiling.ELLPack`
— no host-side pack build, no per-batch pytree-structure changes, and a
mask-corrected mean so pad slots contribute exactly zero.

:func:`block_gspmm` mirrors :func:`repro.core.binary_reduce.gspmm` for
blocks. ``strategy="auto"`` routes through the planner's *shape-keyed*
block plan cache (:func:`repro.core.planner.plan_block_gspmm`): the
decision depends only on the static padded shapes + op + feature width,
so it is stable across batches and valid inside a trace.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import planner
from .binary_reduce import (BINARY_OPS, BRSpec, _as2d, _execute, gspmm,
                            parse_op)
from .graph import Graph
from .strategies import REDUCE_IDENTITY

__all__ = ["BlockGraph", "block_gspmm", "block_supports"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class BlockGraph:
    """One sampled bipartite layer with its uniform neighbor table.

    ``g`` is the padded COO/CSR block graph (``n_dst = n_dst_real + 1``:
    the extra row absorbs pad edges). The neighbor table views the same
    edges row-major: ``nbr[j, k]`` is the source *slot* of destination
    ``j``'s k-th sampled in-edge (pad slots point at the dummy source and
    are masked out), ``nbr_eid[j, k]`` the matching caller-order edge id
    (edge features are indexed with it), and ``real_deg[j]`` the number
    of real sampled in-edges — the mask-corrected mean denominator.
    """
    g: Graph
    nbr: jnp.ndarray        # (n_dst_real, fanout) int32 source slots
    nbr_eid: jnp.ndarray    # (n_dst_real, fanout) int32 caller edge ids
    nbr_mask: jnp.ndarray   # (n_dst_real, fanout) bool — True for real edges
    real_deg: jnp.ndarray   # (n_dst_real,) int32
    n_dst_real: int = dataclasses.field(metadata={"static": True})
    fanout: int = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return ((self.g, self.nbr, self.nbr_eid, self.nbr_mask,
                 self.real_deg), (self.n_dst_real, self.fanout))

    @classmethod
    def tree_unflatten(cls, aux, children):
        g, nbr, nbr_eid, nbr_mask, real_deg = children
        return cls(g=g, nbr=nbr, nbr_eid=nbr_eid, nbr_mask=nbr_mask,
                   real_deg=real_deg, n_dst_real=aux[0], fanout=aux[1])

    @property
    def signature(self) -> Tuple[int, int, int, int]:
        """Static shape signature — the planner's block-plan cache key."""
        return (self.g.n_src, self.n_dst_real, self.g.n_edges, self.fanout)

    def __repr__(self):
        return (f"BlockGraph(n_src={self.g.n_src}, "
                f"n_dst_real={self.n_dst_real}, fanout={self.fanout})")


def block_supports(strategy: str, spec: BRSpec) -> bool:
    """Can ``strategy`` execute this spec on a block?

    The uniform pull ('ell') handles any ⊗ over u/v/e operands and every
    reducer, but only destination outputs. push/segment run the generic
    COO path on the padded graph. The MXU formulations (onehot/pallas)
    need host-built tile packs, which cannot be rebuilt per batch with a
    static pytree structure — they are never block candidates.
    """
    if spec.out != "v" or spec.reduce == "none":
        return False
    if strategy in ("push", "segment"):
        return True
    if strategy == "ell":
        return True
    return False  # onehot / pallas: no static per-batch tile pack


def _nbr_fetch(bg: BlockGraph, target: str, data: jnp.ndarray) -> jnp.ndarray:
    """Operand values laid out on the (n_dst_real, fanout) slot grid."""
    if target == "u":
        return jnp.take(data, bg.nbr, axis=0)            # (nd, F, d)
    if target == "e":
        return jnp.take(data, bg.nbr_eid, axis=0)        # (nd, F, d)
    if target == "v":
        # destination's own value, broadcast along the slot axis;
        # v operands are sized like g.n_dst (they include the pad row)
        return data[: bg.n_dst_real][:, None]            # (nd, 1, d)
    raise ValueError(target)


def _block_pull(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data
                ) -> jnp.ndarray:
    """Uniform blocked pull: dense masked reduce over the fanout axis."""
    lhs_val = _nbr_fetch(bg, spec.lhs, lhs_data)
    rhs_val = (_nbr_fetch(bg, spec.rhs, rhs_data)
               if spec.rhs is not None else None)
    msg = BINARY_OPS[spec.op](lhs_val, rhs_val)          # (nd, F, *feat)
    red = spec.reduce
    ident = jnp.asarray(REDUCE_IDENTITY[red], msg.dtype)
    mask = bg.nbr_mask.reshape(bg.nbr_mask.shape + (1,) * (msg.ndim - 2))
    msg = jnp.where(mask, msg, ident)
    base = "sum" if red in ("sum", "mean") else red
    if base == "sum":
        out = msg.sum(axis=1)
    elif base == "max":
        out = msg.max(axis=1)
    elif base == "min":
        out = msg.min(axis=1)
    elif base == "prod":
        out = msg.prod(axis=1)
    else:
        raise ValueError(f"unknown reduce op {red!r}")
    deg = bg.real_deg
    if red == "mean":
        d = jnp.maximum(deg, 1).astype(out.dtype)
        out = out / d.reshape((out.shape[0],) + (1,) * (out.ndim - 1))
    # DGL semantics: rows with no (real) incoming edge are 0 for every ⊕
    if red != "sum":
        has = (deg > 0).reshape((out.shape[0],) + (1,) * (out.ndim - 1))
        out = jnp.where(has, out, jnp.zeros((), out.dtype))
    return out


def block_gspmm(bg: BlockGraph, op_name: str, *,
                u: Optional[jnp.ndarray] = None,
                v: Optional[jnp.ndarray] = None,
                e: Optional[jnp.ndarray] = None,
                strategy: str = "auto") -> jnp.ndarray:
    """Generalized sparse aggregation over one sampled block.

    Same operand conventions as :func:`~repro.core.binary_reduce.gspmm`
    on ``bg.g`` — ``u``: (n_src_pad, d); ``v``: (n_dst_real + 1, d)
    (callers pad one dummy row); ``e``: (n_edges_pad, d) caller edge
    order. Node outputs are returned for REAL destination rows only:
    shape (n_dst_real, d) — the pad row is consumed internally.

    ``strategy="auto"`` consults the planner's shape-keyed block plan
    cache, so the choice is identical for every batch of the same
    sampler configuration and survives ``jit`` tracing. Pinned
    strategies unsupported on blocks fall back with a one-time warning.
    """
    spec = parse_op(op_name)
    data = {"u": u, "v": v, "e": e}
    if data[spec.lhs] is None:
        raise ValueError(f"{op_name}: operand {spec.lhs!r} missing")
    if spec.rhs is not None and data[spec.rhs] is None:
        raise ValueError(f"{op_name}: operand {spec.rhs!r} missing")

    # edge outputs are strategy-free gathers — delegate to the COO path
    if spec.out == "e":
        return gspmm(bg.g, op_name, u=u, v=v, e=e)

    if spec.out != "v":
        raise ValueError(f"{op_name}: blocks only produce destination or "
                         f"edge outputs (got {spec.out!r})")
    if spec.reduce == "none":
        raise ValueError(f"{op_name}: copy-reduce to nodes needs a reducer")

    lhs_data = _as2d(data[spec.lhs])
    rhs_data = _as2d(data[spec.rhs]) if spec.rhs is not None else None
    d = int(np.prod(lhs_data.shape[1:]))

    runner = None
    if planner.get_mode() == "autotune" and strategy == "auto":
        concrete = (not planner.graph_is_traced(bg.g)
                    and not planner._is_traced(lhs_data)
                    and (rhs_data is None
                         or not planner._is_traced(rhs_data)))
        if concrete:    # measuring candidates only works eagerly
            def runner(s):
                return _block_execute(bg, spec, lhs_data, rhs_data, s)

    chosen = planner.plan_block_gspmm(bg.signature, spec, d,
                                      requested=strategy, runner=runner)
    return _block_execute(bg, spec, lhs_data, rhs_data, chosen)


def _block_execute(bg: BlockGraph, spec: BRSpec, lhs_data, rhs_data,
                   chosen: str) -> jnp.ndarray:
    """Run one block aggregation with an already-resolved strategy."""
    if chosen == "ell":
        return _block_pull(bg, spec, lhs_data, rhs_data)
    # planning is already done (shape-keyed) — execute the resolved
    # strategy directly rather than re-entering gspmm's planning front
    # door, which would build a PlanCache + stats for every throwaway
    # per-batch block graph in eager mode
    plan = planner.Plan(strategy=chosen, requested=chosen,
                        reason="block")
    out = _execute(bg.g, spec, lhs_data, rhs_data, plan)
    return out[: bg.n_dst_real]

"""Back-compat shims over :mod:`repro.core.partition`.

This module used to carry its own ring plan (``RingPartition`` +
``plan_ring``) and its own single-device oracle — an orphaned,
forward-only primitive no app or planner could reach. PR 3 promoted
that 130-line sketch into the partitioned-execution subsystem
(``core/partition.py``: pytree partition plans, a differentiable ring
with a transposed-ring VJP, planner integration, partitioned training).
The old entry points below delegate:

* ``plan_ring(g, n)``            -> ``build_partition(g, n, "uniform")``
  (the old fixed ``id // rows`` layout is the ``uniform`` mode, under
  which the padded layout is the identity: ``x[:n]`` are the original
  rows)
* ``ring_copy_reduce(mesh, ...)``-> ``ring_gspmm`` with unit weights
* ``ring_copy_reduce_reference`` -> ``ring_reference``

The ring path is now covered by the shared cross-strategy differential
harness (tests/core/test_strategy_equivalence.py) instead of a bespoke
oracle, and by the multi-device tests in tests/launch/.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import Mesh

from .graph import Graph
from .partition import (PartitionedGraph, build_partition, ring_gspmm,
                        ring_reference)

__all__ = ["plan_ring", "ring_copy_reduce", "ring_copy_reduce_reference"]


def plan_ring(g: Graph, n_shards: int) -> PartitionedGraph:
    """Uniform-rows ring plan (the historical layout)."""
    return build_partition(g, n_shards, mode="uniform")


def _unit_weights(plan: PartitionedGraph, dtype) -> jnp.ndarray:
    return jnp.where(plan.mask, 1.0, 0.0).astype(dtype)


def ring_copy_reduce(mesh: Mesh, plan: PartitionedGraph, x: jnp.ndarray,
                     axis: str = "data") -> jnp.ndarray:
    """CR-sum over the ring. ``x``: (n_pad, d); returns (n_pad, d)."""
    return ring_gspmm(plan, x, _unit_weights(plan, x.dtype),
                      mesh=mesh, axis=axis)


def ring_copy_reduce_reference(plan: PartitionedGraph,
                               x: jnp.ndarray) -> jnp.ndarray:
    """Single-device oracle for the ring (same padded layout)."""
    return ring_reference(plan, x)

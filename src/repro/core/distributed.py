"""Distributed Copy-Reduce: the paper's K-blocking mapped to a device ring.

1-D partitioning: destination rows (and their output) are sharded across
the 'data' axis; source features are sharded the same way. Each device
owns the edges whose DESTINATION falls in its shard (pull model — owner
computes, no write conflicts across devices, exactly the paper's Alg. 2
argument lifted to the cluster level).

The source features a device needs live on other shards. Instead of an
up-front all-gather (peak memory = full feature matrix), the shards rotate
around a ``lax.ppermute`` ring: at stage s, device d holds shard
(d - s) mod n and reduces the edges whose sources fall in that shard —
**each ring stage is one paper K-block**: a bounded working set that is
consumed fully while resident, then replaced. Compute at stage s overlaps
the permute launched for stage s+1 (async collective start/done pairs in
the HLO).

Edges are pre-bucketed by source shard host-side (the radix-sort step at
cluster granularity).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .graph import Graph


@dataclasses.dataclass(frozen=True, eq=False)
class RingPartition:
    """Host-side plan: per (dst-shard, src-shard) padded edge buckets."""
    # (n_shards, n_shards, eb): [dst_shard][stage bucket] edges
    src_local: np.ndarray   # source offset within its shard
    dst_local: np.ndarray   # destination offset within its shard
    mask: np.ndarray
    n_shards: int
    rows_per_shard: int
    eb: int                 # max edges per bucket (padded)


def plan_ring(g: Graph, n_shards: int) -> RingPartition:
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    n = max(g.n_src, g.n_dst)
    rows = -(-n // n_shards)
    src_shard = src // rows
    dst_shard = dst // rows
    buckets: List[List[List[Tuple[int, int]]]] = [
        [[] for _ in range(n_shards)] for _ in range(n_shards)]
    for s, d in zip(src, dst):
        buckets[d // rows][s // rows].append((s % rows, d % rows))
    eb = max(1, max(len(b) for row in buckets for b in row))
    SL = np.zeros((n_shards, n_shards, eb), np.int32)
    DL = np.zeros((n_shards, n_shards, eb), np.int32)
    MK = np.zeros((n_shards, n_shards, eb), bool)
    for i in range(n_shards):
        for j in range(n_shards):
            for k, (sl, dl) in enumerate(buckets[i][j]):
                SL[i, j, k] = sl
                DL[i, j, k] = dl
                MK[i, j, k] = True
    return RingPartition(src_local=SL, dst_local=DL, mask=MK,
                         n_shards=n_shards, rows_per_shard=rows, eb=eb)


def ring_copy_reduce(mesh: Mesh, plan: RingPartition, x: jnp.ndarray,
                     axis: str = "data") -> jnp.ndarray:
    """CR-sum over the ring. ``x``: (n_pad, d) with n_pad = shards×rows.

    Returns (n_pad, d) destination sums, sharded like ``x``.
    """
    n_shards, rows, eb = plan.n_shards, plan.rows_per_shard, plan.eb
    d = x.shape[-1]

    def local_fn(xs, sl, dl, mk):
        # xs: (1, rows, d) this device's source shard
        # sl/dl/mk: (1, n_shards, eb) buckets for this DST shard
        xs = xs[0]
        sl, dl, mk = sl[0], dl[0], mk[0]
        me = jax.lax.axis_index(axis)
        out = jnp.zeros((rows, d), x.dtype)
        # mark the accumulator as device-varying so the fori_loop carry
        # type matches after ppermute (shard_map vma typing); pvary only
        # exists on jax versions with explicit vma tracking — elsewhere
        # the carry types already agree and no annotation is needed
        pvary = getattr(jax.lax, "pvary", None)
        if pvary is not None:
            out = pvary(out, (axis,))
        block = xs

        def stage(s, carry):
            out, block = carry
            # shard id currently resident on this device
            shard_id = (me - s) % n_shards
            # kick off the NEXT block transfer (overlaps the reduce below)
            nxt = jax.lax.ppermute(
                block, axis,
                [(i, (i + 1) % n_shards) for i in range(n_shards)])
            # reduce the resident K-block's bucket
            sel = jnp.take(sl, shard_id, axis=0)      # (eb,)
            dls = jnp.take(dl, shard_id, axis=0)
            mks = jnp.take(mk, shard_id, axis=0)
            vals = jnp.take(block, sel, axis=0)       # (eb, d)
            vals = jnp.where(mks[:, None], vals, 0)
            out = out.at[dls].add(vals)
            return out, nxt

        out, _ = jax.lax.fori_loop(0, n_shards, stage, (out, block))
        return out[None]

    from jax.experimental.shard_map import shard_map
    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(P(axis, None, None), P(axis, None, None),
                            P(axis, None, None), P(axis, None, None)),
                  out_specs=P(axis, None, None))
    out = f(x.reshape(n_shards, rows, d),
            jnp.asarray(plan.src_local),
            jnp.asarray(plan.dst_local),
            jnp.asarray(plan.mask))
    return out.reshape(n_shards * rows, d)


def ring_copy_reduce_reference(plan: RingPartition,
                               x: jnp.ndarray) -> jnp.ndarray:
    """Single-device oracle for the ring (same padded layout)."""
    n_shards, rows = plan.n_shards, plan.rows_per_shard
    d = x.shape[-1]
    xs = x.reshape(n_shards, rows, d)
    out = np.zeros((n_shards, rows, d), np.float32)
    for i in range(n_shards):
        for j in range(n_shards):
            sl = plan.src_local[i, j]
            dl = plan.dst_local[i, j]
            mk = plan.mask[i, j]
            vals = np.asarray(xs[j])[sl] * mk[:, None]
            np.add.at(out[i], dl, vals)
    return jnp.asarray(out.reshape(n_shards * rows, d))

"""Edge softmax — GAT's 5-primitive BR chain (paper Table 2, row 8).

DGL executes GAT attention normalization as five separate BR/CR passes:

    m   = e_copy_max_v   (segment max)
    s   = e_sub_v_copy_e (shift)
    x   = exp(s)
    z   = e_copy_add_v   (segment sum)
    out = e_div_v_copy_e (normalize)

``edge_softmax`` composes exactly those primitives (faithful layering);
``edge_softmax_fused`` is the optimized single-pass version that stays in
canonical edge order throughout — one gather in, one gather out, no
intermediate HBM round-trips (beyond-paper fusion; the Pallas kernel in
``repro.kernels.edge_softmax`` is its TPU form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .binary_reduce import gspmm
from .blocks import BlockGraph, block_gspmm
from .graph import Graph

__all__ = ["edge_softmax", "edge_softmax_fused", "block_edge_softmax"]


def edge_softmax(g: Graph, logits: jnp.ndarray,
                 strategy: str = "auto", cache=None) -> jnp.ndarray:
    """Softmax over incoming edges of each destination node.

    ``logits``: (n_edges, H) in the caller's edge order. Returns the same
    shape/order. Composed from the exact BR configs the paper profiles;
    the two node-output reductions route through the planner (pass
    ``cache`` to reuse a per-graph :class:`PlanCache` inside ``jit``).
    """
    maxv = gspmm(g, "e_copy_max_v", e=logits, strategy=strategy,
                 cache=cache)
    shifted = gspmm(g, "e_sub_v_copy_e", e=logits, v=maxv, strategy=strategy)
    ex = jnp.exp(shifted)
    z = gspmm(g, "e_copy_add_v", e=ex, strategy=strategy, cache=cache)
    return gspmm(g, "e_div_v_copy_e", e=ex, v=z, strategy=strategy)


def block_edge_softmax(bg: BlockGraph, logits: jnp.ndarray,
                       strategy: str = "auto",
                       bwd_strategy: str = "auto") -> jnp.ndarray:
    """Edge softmax over one sampled block's real in-edges.

    Same five-primitive chain as :func:`edge_softmax`, with the two
    node-output reductions routed through the shape-keyed block planner
    (``bwd_strategy`` picks their differentiation path — the max
    reduction always stays on autodiff, see planner.block_bwd_supports).
    Pad edges live in the dummy destination row, so real rows' softmax
    sees exactly their real edges; pad edges' output values are garbage
    but masked out of every downstream block aggregation.
    """
    x = logits[:, None] if logits.ndim == 1 else logits
    pad = jnp.zeros((1,) + x.shape[1:], x.dtype)
    maxv = block_gspmm(bg, "e_copy_max_v", e=x, strategy=strategy,
                       bwd_strategy=bwd_strategy)
    shifted = gspmm(bg.g, "e_sub_v_copy_e", e=x,
                    v=jnp.concatenate([maxv, pad], axis=0))
    ex = jnp.exp(shifted)
    z = block_gspmm(bg, "e_copy_add_v", e=ex, strategy=strategy,
                    bwd_strategy=bwd_strategy)
    # dummy row gets z=1 so pad edges divide by a finite value; every
    # real edge's destination has ≥ 1 real edge, so z > 0 on real rows
    zp = jnp.concatenate([z, jnp.ones_like(pad)], axis=0)
    out = gspmm(bg.g, "e_div_v_copy_e", e=ex, v=zp)
    return out[:, 0] if logits.ndim == 1 else out


def edge_softmax_fused(g: Graph, logits: jnp.ndarray) -> jnp.ndarray:
    """Single-pass edge softmax in canonical (dst-sorted) order."""
    x = logits[:, None] if logits.ndim == 1 else logits
    m = jnp.take(x, g.eid, axis=0)                       # canonical order
    kw = dict(num_segments=g.n_dst, indices_are_sorted=True)
    mx = jax.ops.segment_max(m, g.dst, **kw)
    mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), m.dtype))
    ex = jnp.exp(m - jnp.take(mx, g.dst, axis=0))
    z = jax.ops.segment_sum(ex, g.dst, **kw)
    out = ex / jnp.take(z, g.dst, axis=0)
    out = jnp.take(out, g.eid_inv, axis=0)
    return out[:, 0] if logits.ndim == 1 else out

"""Edge softmax — GAT's 5-primitive BR chain (paper Table 2, row 8).

DGL executes GAT attention normalization as five separate BR/CR passes:

    m   = e_copy_max_v   (segment max)
    s   = e_sub_v_copy_e (shift)
    x   = exp(s)
    z   = e_copy_add_v   (segment sum)
    out = e_div_v_copy_e (normalize)

``edge_softmax`` composes exactly those primitives (faithful layering);
``edge_softmax_fused`` is the optimized single-pass version that stays in
canonical edge order throughout — one gather in, one gather out, no
intermediate HBM round-trips (beyond-paper fusion; the Pallas kernel in
``repro.kernels.edge_softmax`` is its TPU form).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import planner
from ..obs.events import timed as _timed
from .binary_reduce import gspmm
from .blocks import BlockGraph, block_gspmm
from .graph import Graph

__all__ = ["edge_softmax", "edge_softmax_fused", "block_edge_softmax",
           "fused_attention", "block_fused_attention",
           "fused_attention_partitioned"]


def edge_softmax(g: Graph, logits: jnp.ndarray,
                 strategy: str = "auto", cache=None) -> jnp.ndarray:
    """Softmax over incoming edges of each destination node.

    ``logits``: (n_edges, H) in the caller's edge order. Returns the same
    shape/order. Composed from the exact BR configs the paper profiles;
    the two node-output reductions route through the planner (pass
    ``cache`` to reuse a per-graph :class:`PlanCache` inside ``jit``).
    """
    maxv = gspmm(g, "e_copy_max_v", e=logits, strategy=strategy,
                 cache=cache)
    # align with edge_softmax_fused: a zero-in-degree node's max is the
    # reduce identity (-inf) on any strategy that skips the degree
    # finalize — never let it reach the subtract
    maxv = jnp.where(jnp.isfinite(maxv), maxv, jnp.zeros((), maxv.dtype))
    shifted = gspmm(g, "e_sub_v_copy_e", e=logits, v=maxv, strategy=strategy)
    ex = jnp.exp(shifted)
    z = gspmm(g, "e_copy_add_v", e=ex, strategy=strategy, cache=cache)
    return gspmm(g, "e_div_v_copy_e", e=ex, v=z, strategy=strategy)


def block_edge_softmax(bg: BlockGraph, logits: jnp.ndarray,
                       strategy: str = "auto",
                       bwd_strategy: str = "auto") -> jnp.ndarray:
    """Edge softmax over one sampled block's real in-edges.

    Same five-primitive chain as :func:`edge_softmax`, with the two
    node-output reductions routed through the shape-keyed block planner
    (``bwd_strategy`` picks their differentiation path — the max
    reduction always stays on autodiff, see planner.block_bwd_supports).
    Pad edges live in the dummy destination row, so real rows' softmax
    sees exactly their real edges; pad edges' output values are garbage
    but masked out of every downstream block aggregation.
    """
    x = logits[:, None] if logits.ndim == 1 else logits
    pad = jnp.zeros((1,) + x.shape[1:], x.dtype)
    maxv = block_gspmm(bg, "e_copy_max_v", e=x, strategy=strategy,
                       bwd_strategy=bwd_strategy)
    shifted = gspmm(bg.g, "e_sub_v_copy_e", e=x,
                    v=jnp.concatenate([maxv, pad], axis=0))
    ex = jnp.exp(shifted)
    z = block_gspmm(bg, "e_copy_add_v", e=ex, strategy=strategy,
                    bwd_strategy=bwd_strategy)
    # dummy row gets z=1 so pad edges divide by a finite value; every
    # real edge's destination has ≥ 1 real edge, so z > 0 on real rows
    zp = jnp.concatenate([z, jnp.ones_like(pad)], axis=0)
    out = gspmm(bg.g, "e_div_v_copy_e", e=ex, v=zp)
    return out[:, 0] if logits.ndim == 1 else out


def edge_softmax_fused(g: Graph, logits: jnp.ndarray) -> jnp.ndarray:
    """Single-pass edge softmax in canonical (dst-sorted) order."""
    x = logits[:, None] if logits.ndim == 1 else logits
    m = jnp.take(x, g.eid, axis=0)                       # canonical order
    kw = dict(num_segments=g.n_dst, indices_are_sorted=True)
    mx = jax.ops.segment_max(m, g.dst, **kw)
    mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), m.dtype))
    ex = jnp.exp(m - jnp.take(mx, g.dst, axis=0))
    z = jax.ops.segment_sum(ex, g.dst, **kw)
    out = ex / jnp.take(z, g.dst, axis=0)
    out = jnp.take(out, g.eid_inv, axis=0)
    return out[:, 0] if logits.ndim == 1 else out


# --------------------------------------------------------------------- #
# fused attention: logits + leaky-relu + softmax + weighted reduce as
# ONE planned pass (DESIGN.md §9)
# --------------------------------------------------------------------- #
def _attention_alpha(g: Graph, el, er, slope):
    """Canonical-order α and the raw logits (for the leaky mask)."""
    m_raw = jnp.take(el, g.src, axis=0) + jnp.take(er, g.dst, axis=0)
    m = jnp.where(m_raw >= 0, m_raw, slope * m_raw)      # (E, H)
    kw = dict(num_segments=g.n_dst, indices_are_sorted=True)
    mx = jax.ops.segment_max(m, g.dst, **kw)
    mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), m.dtype))
    ex = jnp.exp(m - jnp.take(mx, g.dst, axis=0))
    zs = jax.ops.segment_sum(ex, g.dst, **kw)
    alpha = ex / jnp.take(jnp.maximum(zs, 1e-38), g.dst, axis=0)
    return alpha, m_raw


def _attention_execute(g: Graph, pack, el, er, z, slope, chosen):
    if chosen == "pallas":
        from ..kernels.edge_softmax.ops import \
            fused_attention as attention_pallas

        # the ragged pack is resolved OUTSIDE the custom_vjp boundary
        # (g is a tracer in here; plan caches key on concrete graphs)
        return attention_pallas(g, el, er, z, slope=slope, ell=pack)
    alpha, _ = _attention_alpha(g, el, er, slope)
    msg = alpha[..., None] * jnp.take(z, g.src, axis=0)  # (E, H, F)
    return jax.ops.segment_sum(msg, g.dst, num_segments=g.n_dst,
                               indices_are_sorted=True)


def _attention_grads(g: Graph, el, er, z, slope, ct):
    """Scatter-free adjoints of the fused pipeline: recompute α on the
    canonical stream, route source-side grads through the free
    src-sorted view (``perm_src`` + SORTED segment reduce)."""
    alpha, m_raw = _attention_alpha(g, el, er, slope)
    ct_e = jnp.take(ct, g.dst, axis=0)                   # (E, H, F)
    z_src = jnp.take(z, g.src, axis=0)
    g_alpha = jnp.sum(ct_e * z_src, axis=-1)             # (E, H)

    perm = g.perm_src
    src_sorted = jnp.take(g.src, perm)
    skw = dict(num_segments=g.n_src, indices_are_sorted=True)
    dkw = dict(num_segments=g.n_dst, indices_are_sorted=True)

    dz = jax.ops.segment_sum(
        jnp.take(alpha[..., None] * ct_e, perm, axis=0), src_sorted, **skw)

    # softmax adjoint, then the leaky-relu mask (>= matches substrate)
    s_dot = jax.ops.segment_sum(alpha * g_alpha, g.dst, **dkw)
    g_m = alpha * (g_alpha - jnp.take(s_dot, g.dst, axis=0))
    g_m = g_m * jnp.where(m_raw >= 0, jnp.ones((), g_m.dtype),
                          jnp.asarray(slope, g_m.dtype))

    d_el = jax.ops.segment_sum(jnp.take(g_m, perm, axis=0), src_sorted,
                               **skw)
    d_er = jax.ops.segment_sum(g_m, g.dst, **dkw)
    return (d_el.astype(el.dtype), d_er.astype(er.dtype),
            dz.astype(z.dtype))


def _attention_grads_ragged(pack, el, er, z, slope, ct):
    """Adjoints recomputed on the RAGGED ELL stripes: per-class masked
    max/sum over the width axis replaces the whole segment-reduce chain,
    so the backward rides the same pad-tax-free layout as the pallas
    forward. Pad slots carry α = 0 exactly (masked exp), so the src-side
    scatter-adds can index ``chunk_cols`` directly — pads add zeros.
    ∂z and ∂el ride ONE scatter with an (H, F+1) payload: on CPU the
    scatter's per-index overhead dominates its bandwidth, so fusing the
    two source-side adds beats two passes."""
    F = z.shape[-1]
    acc = jnp.zeros(z.shape[:-1] + (F + 1,),
                    jnp.promote_types(z.dtype, ct.dtype))
    d_er = jnp.zeros_like(er)
    one = jnp.ones((), el.dtype)
    sl = jnp.asarray(slope, el.dtype)
    for cls in pack.classes:
        cols, mask, row = cls.chunk_cols, cls.chunk_mask, cls.chunk_row
        el_t = jnp.take(el, cols, axis=0)                  # (C, W, H)
        er_t = jnp.take(er, row, axis=0)[:, None]          # (C, 1, H)
        m_raw = el_t + er_t
        m = jnp.where(m_raw >= 0, m_raw, sl * m_raw)
        mk = mask[..., None]
        mm = jnp.where(mk, m, jnp.asarray(-jnp.inf, m.dtype))
        mx = jnp.max(mm, axis=1, keepdims=True)            # (C, 1, H)
        mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), m.dtype))
        ex = jnp.where(mk, jnp.exp(m - mx), jnp.zeros((), m.dtype))
        zs = jnp.sum(ex, axis=1, keepdims=True)
        alpha = ex / jnp.maximum(zs, 1e-38)                # (C, W, H)

        ct_t = jnp.take(ct, row, axis=0)                   # (C, H, F)
        z_t = jnp.take(z, cols, axis=0)                    # (C, W, H, F)
        g_alpha = jnp.einsum("chf,cwhf->cwh", ct_t, z_t)
        s_dot = jnp.sum(alpha * g_alpha, axis=1, keepdims=True)
        g_m = alpha * (g_alpha - s_dot)
        g_m = g_m * jnp.where(m_raw >= 0, one, sl)

        # rows are disjoint across classes → pure row update; src-side
        # slots repeat → scatter-add (pads contribute exact zeros)
        d_er = d_er.at[row].add(jnp.sum(g_m, axis=1).astype(er.dtype))
        payload = jnp.concatenate(
            [alpha[..., None] * ct_t[:, None], g_m[..., None]], axis=-1)
        acc = acc.at[cols].add(payload.astype(acc.dtype))
    return (acc[..., F].astype(el.dtype), d_er,
            acc[..., :F].astype(z.dtype))


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _attention_rev(chosen: str, slope: float, g: Graph, pack, el, er, z):
    """``_attention_execute`` with the scatter-free manual backward."""
    return _attention_execute(g, pack, el, er, z, slope, chosen)


def _attention_rev_fwd(chosen, slope, g, pack, el, er, z):
    out = _attention_execute(g, pack, el, er, z, slope, chosen)
    return out, (g, pack, el, er, z)


def _attention_rev_bwd(chosen, slope, res, ct):
    g, pack, el, er, z = res
    if chosen == "pallas" and pack is not None:
        d_el, d_er, dz = _attention_grads_ragged(pack, el, er, z,
                                                 slope, ct)
    else:
        d_el, d_er, dz = _attention_grads(g, el, er, z, slope, ct)
    return None, None, d_el, d_er, dz


_attention_rev.defvjp(_attention_rev_fwd, _attention_rev_bwd)


def fused_attention(g: Graph, el: jnp.ndarray, er: jnp.ndarray,
                    z: jnp.ndarray, *, negative_slope: float = 0.2,
                    strategy: str = "auto") -> jnp.ndarray:
    """GAT's attention pipeline — ``u_add_v_copy_e`` logits, leaky-relu,
    edge softmax and ``u_mul_e_add_v`` aggregation — as ONE planned pass.

    ``el``: (n_src, H) or (n_src,) source logit terms; ``er``: (n_dst,
    H) destination terms; ``z``: (n_src, H, F) source features ((n_src,
    F) when ``el`` is 1-D). Returns (n_dst, H, F) aggregated features.

    Stays in canonical dst-sorted order throughout — per-edge α is never
    materialized as a caller-order HBM tensor, and the custom VJP routes
    ∂el/∂z through the graph's free src-sorted view with sorted segment
    reduces (no scatter). ``strategy``: 'auto' | 'fused' (canonical jnp)
    | 'pallas' (ragged row-complete ELL megakernel — one stripe grid
    per degree class) | 'ring' is reserved for the partitioned form.
    Logged as ``attn:fused``.
    """
    squeeze = el.ndim == 1
    if squeeze:
        el, er, z = el[:, None], er[:, None], z[:, None, :]
    H = el.shape[-1]
    F = z.shape[-1]

    pallas_ok = False
    padded_slots = None
    if not planner.graph_is_traced(g) and g.n_edges > 0:
        import numpy as np

        # degrees straight off the stored CSR field: g's arrays are
        # concrete here even mid-trace (closed-over constants), but the
        # in_degrees property would compute through traced slices
        indptr = np.asarray(g.indptr_dst)
        deg = indptr[1:] - indptr[:-1]
        max_deg = int(deg.max()) if deg.size else 0
        if max_deg > 0:
            pallas_ok = True
            # per-class slot estimate of the RAGGED row-complete pack —
            # the same formula the cost model's pallas row prices, so
            # the gate can no longer veto the megakernel with the
            # max_degree × n_rows envelope on power-law degree tails
            padded_slots, _ = planner.ell_rowcomplete_padding(deg)

    chosen = planner.plan_attention((g.n_src, g.n_dst, g.n_edges), H, F,
                                    requested=strategy,
                                    pallas_ok=pallas_ok,
                                    padded_slots=padded_slots,
                                    dtype=str(z.dtype))
    if chosen == "ring":
        raise ValueError("strategy='ring' needs a PartitionedGraph — "
                         "use fused_attention_partitioned")

    slope = float(negative_slope)
    pack = None
    if chosen == "pallas":
        # resolve the ragged pack while g is still concrete (inside the
        # custom_vjp g's arrays are tracers and cache lookup is
        # impossible); requesting pallas on a traced graph raises the
        # plan cache's own "pass the cache in explicitly" error. Build
        # only OUTSIDE an active trace — np→jnp conversions inside one
        # would leak trace-bound arrays into the process-wide memo —
        # else peek, demoting to the canonical jnp pipeline when the
        # pack was never prebuilt (same idiom as hetero's skew packs)
        cache = planner.get_plan_cache(g)
        pack = (cache.ell_ragged() if jax.core.trace_state_clean()
                else cache.peek("ell_ragged"))
        if pack is None:
            chosen = "fused"
    # eager calls are fenced + timed under the attention plan-log key
    if jnp.issubdtype(z.dtype, jnp.floating):
        out = _timed("attn:fused",
                     lambda: _attention_rev(chosen, slope, g, pack,
                                            el, er, z))
    else:
        out = _timed("attn:fused",
                     lambda: _attention_execute(g, pack, el, er, z,
                                                slope, chosen))
    return out[:, 0, :] if squeeze else out


def block_fused_attention(bg: BlockGraph, el: jnp.ndarray,
                          er: jnp.ndarray, z: jnp.ndarray, *,
                          negative_slope: float = 0.2,
                          strategy: str = "auto") -> jnp.ndarray:
    """Fused attention over one sampled block's real in-edges.

    ``er`` spans the padded destination range (n_dst_real + 1 rows, the
    caller's dummy row last, like every block v-operand). Pad edges all
    point at the dummy destination row, so real rows' softmax sees
    exactly their real edges; the dummy row is consumed internally.
    """
    out = fused_attention(bg.g, el, er, z,
                          negative_slope=negative_slope,
                          strategy=strategy)
    return out[: bg.n_dst_real]


def fused_attention_partitioned(pg, el: jnp.ndarray, er: jnp.ndarray,
                                z: jnp.ndarray, *, mesh=None,
                                axis: str = "data",
                                negative_slope: float = 0.2
                                ) -> jnp.ndarray:
    """Fused attention on a partitioned graph: one ring pass assembles
    bucketed logits, leaky-relu + softmax run owner-local, a second ring
    does the α-weighted reduce. Planned/logged as the 'ring' form of
    ``attn:fused``.
    """
    # lazy import: partition pulls in mesh helpers this module's other
    # entry points never need
    from .partition import bucket_softmax, ring_edge_values, ring_gspmm

    H = el.shape[-1]
    F = z.shape[-1]
    n_edges = pg.n_shards * pg.n_shards * pg.eb
    planner.plan_attention((pg.n_pad, pg.n_pad, n_edges), H, F,
                           requested="ring", dtype=str(z.dtype))
    logits = ring_edge_values(pg, el, er, mesh=mesh, axis=axis)
    logits = jnp.where(logits >= 0, logits, negative_slope * logits)
    alpha = bucket_softmax(pg, logits)
    return ring_gspmm(pg, z, alpha, mesh=mesh, axis=axis)

"""Graph containers for aggregation primitives.

The paper's Alg. 3 sorts source indices inside each K-block with a radix
sort so that DRAM accesses stream in ascending order. On TPU (and in a
functional framework) the idiomatic place for that work is a one-time
format conversion: `Graph` canonically sorts the edge list by
``(dst, src)`` at construction, exposing

  * COO views ``(src, dst, eid)`` sorted by destination (pull order),
  * CSR-by-destination ``indptr_dst`` (pull model, paper Alg. 2/3),
  * CSC-by-source ``indptr_src`` + permutation (push model, paper Alg. 1),

``eid`` maps a sorted edge slot back to the caller's original edge-feature
row so edge features never need reordering on the user side.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "from_coo", "reverse", "add_self_loops"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)  # identity hash; jnp fields
class Graph:
    """Directed graph with dual CSR/CSC index structure.

    All index arrays are ``int32`` device arrays; sizes are static Python
    ints so the structure can cross ``jit`` boundaries as a pytree.
    """

    # --- COO, sorted by (dst, src): the canonical pull order -------------
    src: jnp.ndarray  # (nnz,) source node id per edge
    dst: jnp.ndarray  # (nnz,) destination node id per edge (non-decreasing)
    eid: jnp.ndarray  # (nnz,) original edge id for edge-feature lookup

    # --- CSR by destination (pull) ---------------------------------------
    indptr_dst: jnp.ndarray  # (n_dst + 1,)

    # --- CSC by source (push) --------------------------------------------
    indptr_src: jnp.ndarray  # (n_src + 1,)
    perm_src: jnp.ndarray    # (nnz,) permutation: sorted-by-src -> canonical slot

    # --- edge-id inverse: original edge id -> canonical slot ---------------
    eid_inv: jnp.ndarray     # (nnz,)

    # --- static metadata ---------------------------------------------------
    n_src: int = dataclasses.field(metadata={"static": True})
    n_dst: int = dataclasses.field(metadata={"static": True})
    n_edges: int = dataclasses.field(metadata={"static": True})

    # ------------------------------------------------------------------ #
    # pytree protocol
    # ------------------------------------------------------------------ #
    def tree_flatten(self):
        children = (self.src, self.dst, self.eid, self.indptr_dst,
                    self.indptr_src, self.perm_src, self.eid_inv)
        aux = (self.n_src, self.n_dst, self.n_edges)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, eid, indptr_dst, indptr_src, perm_src, eid_inv = children
        n_src, n_dst, n_edges = aux
        return cls(src=src, dst=dst, eid=eid, indptr_dst=indptr_dst,
                   indptr_src=indptr_src, perm_src=perm_src, eid_inv=eid_inv,
                   n_src=n_src, n_dst=n_dst, n_edges=n_edges)

    # ------------------------------------------------------------------ #
    # convenience
    # ------------------------------------------------------------------ #
    @property
    def in_degrees(self) -> jnp.ndarray:
        """(n_dst,) number of incoming edges per destination node."""
        return self.indptr_dst[1:] - self.indptr_dst[:-1]

    @property
    def out_degrees(self) -> jnp.ndarray:
        """(n_src,) number of outgoing edges per source node."""
        return self.indptr_src[1:] - self.indptr_src[:-1]

    def numpy_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return (np.asarray(self.src), np.asarray(self.dst),
                np.asarray(self.eid))

    def __repr__(self):  # keep reprs short in test logs
        return (f"Graph(n_src={self.n_src}, n_dst={self.n_dst}, "
                f"n_edges={self.n_edges})")


def from_coo(src, dst, *, n_src: Optional[int] = None,
             n_dst: Optional[int] = None) -> Graph:
    """Build a :class:`Graph` from COO edge arrays (host-side, numpy).

    Edge ids are assigned in the caller's order: edge features passed to the
    aggregation primitives are always indexed in the order of ``src``/``dst``
    given here.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"src/dst must be equal-length 1-D, got "
                         f"{src.shape} vs {dst.shape}")
    nnz = src.shape[0]
    n_src = int(n_src if n_src is not None else (src.max() + 1 if nnz else 0))
    n_dst = int(n_dst if n_dst is not None else (dst.max() + 1 if nnz else 0))
    if nnz and (src.min() < 0 or src.max() >= n_src):
        raise ValueError("src ids out of range")
    if nnz and (dst.min() < 0 or dst.max() >= n_dst):
        raise ValueError("dst ids out of range")

    # canonical sort by (dst, src) — the paper's radix sort, done once.
    order = np.lexsort((src, dst))
    s_src, s_dst = src[order], dst[order]
    eid = order.astype(np.int32)  # canonical slot -> original edge id

    indptr_dst = np.zeros(n_dst + 1, dtype=np.int32)
    np.add.at(indptr_dst, s_dst + 1, 1)
    np.cumsum(indptr_dst, out=indptr_dst)

    # push-side (CSC by src): permutation from sorted-by-(src,dst) to slot
    order_src = np.lexsort((s_dst, s_src))
    indptr_src = np.zeros(n_src + 1, dtype=np.int32)
    np.add.at(indptr_src, s_src + 1, 1)
    np.cumsum(indptr_src, out=indptr_src)

    eid_inv = np.empty_like(eid)
    eid_inv[eid] = np.arange(nnz, dtype=np.int32)

    return Graph(
        src=jnp.asarray(s_src, dtype=jnp.int32),
        dst=jnp.asarray(s_dst, dtype=jnp.int32),
        eid=jnp.asarray(eid, dtype=jnp.int32),
        indptr_dst=jnp.asarray(indptr_dst),
        indptr_src=jnp.asarray(indptr_src),
        perm_src=jnp.asarray(order_src.astype(np.int32)),
        eid_inv=jnp.asarray(eid_inv),
        n_src=n_src, n_dst=n_dst, n_edges=int(nnz),
    )


def reverse(g: Graph) -> Graph:
    """Reverse every edge (used by backward passes: grad of pull = push)."""
    src, dst, eid = g.numpy_coo()
    # keep the same original edge ids so edge features still line up
    rg = from_coo(dst, src, n_src=g.n_dst, n_dst=g.n_src)
    # from_coo assigned fresh eids by position; remap through g.eid
    remapped = np.asarray(g.eid)[np.asarray(rg.eid)]
    inv = np.empty_like(remapped)
    inv[remapped] = np.arange(len(remapped), dtype=remapped.dtype)
    return dataclasses.replace(rg, eid=jnp.asarray(remapped, jnp.int32),
                               eid_inv=jnp.asarray(inv, jnp.int32))


def add_self_loops(src, dst, n: int):
    """Append one self-loop per node to host COO arrays (GCN-style)."""
    src = np.concatenate([np.asarray(src, np.int64), np.arange(n)])
    dst = np.concatenate([np.asarray(dst, np.int64), np.arange(n)])
    return src, dst

"""Relation-fused heterogeneous execution (DESIGN.md §8).

RGCN (103 relations on BGS), GCMC (one subgraph per rating level),
MoNet (one aggregation per mixture kernel) and LGNN (node graph + line
graph) all compute the same shape of operator:

    out[v] = Σ_r Σ_{(u→v) ∈ E_r}  msg_r(u, e)

The pre-refactor implementation ran a Python loop of R sequential
``gspmm`` calls over per-relation ``Graph``s — exactly the per-type
kernel-launch overhead the DGL heterograph design (Wang et al.,
1909.01315) eliminates by stacking relations. :class:`RelGraph` is that
stacking: all relations' edge sets concatenated into ONE fused graph
(canonically (dst, src)-sorted, so the whole ``Graph``/``PlanCache``
machinery applies wholesale) with a relation id per edge, per-relation
degree norms (RGCN's 1/c_{v,r}), a relation-sorted permutation (the
per-relation-loop view), and a (src, rel)-sorted reverse table (the
gather backward's lookup structure — see §8.4).

:func:`hetero_gspmm` is the fused Σ_r CR:

* gather ``u`` at the fused sources (or the relation-transformed
  features at ``(rel, src)``),
* index ``W`` (or the basis-composed ``W_r``) by edge relation id,
* ONE sorted segment reduce into destinations,

with a custom VJP that mirrors the PR-4 reverse-block backward: the
per-``(src, rel)`` cotangent aggregate is one SORTED segment reduce
over the reverse table — no scatter — and ∂W/∂u follow by two dense
einsums. ``strategy="auto"`` routes through the planner
(:func:`repro.core.planner.plan_hetero`, logged as ``hetero:<op>``):
``fused`` vs the per-relation ``loop`` baseline vs ``ell`` (fused
messages reduced by the fused graph's blocked pull) from
relation-count/size-skew statistics, memoized per signature and
measurable under autotune mode. When relation sizes are materially
skewed, the ``ell`` route splits into per-size-class packs
(ell-per-relation-class, :func:`_build_skew_classes`) so one giant
relation doesn't set the ELL pad width for every tiny one.
"""
from __future__ import annotations

import dataclasses
import weakref
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import planner
from . import strategies as S
from ..obs.events import timed as _timed
from .binary_reduce import parse_op, _execute
from .graph import Graph, from_coo

__all__ = ["RelGraph", "from_typed", "from_rels", "hetero_gspmm",
           "hetero_block_gspmm", "caller_coo"]


# --------------------------------------------------------------------- #
# the fused relational structure
# --------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class RelGraph:
    """All relations' edges stacked into one relation-tagged graph.

    ``g`` is the fused :class:`Graph` in the repo's canonical
    (dst, src)-sorted edge order — its CSR, packs and
    :class:`~repro.core.planner.PlanCache` serve the fused strategies
    unchanged. The remaining arrays are views of the SAME edge set:

    * ``rel``        (E,) relation id per edge, canonical order;
    * ``mean_norm``  (E,) 1/deg_r(dst) per edge, canonical order — the
      per-relation mean weight (RGCN's 1/c_{v,r});
    * ``perm_rel``   (E,) relation-sorted position → canonical slot
      (stable, so each relation's slice stays dst-sorted) — the
      per-relation-loop view; slice boundaries are the static
      ``rel_ptr``;
    * ``rev_perm``/``rev_src``/``rev_dst``/``rev_rel`` — the edges
      sorted by (src, rel): ``rev_src * n_rel + rev_rel`` is
      non-decreasing, so the backward's per-(src, rel) cotangent
      aggregate is ONE sorted segment reduce (no scatter).

    Caller edge order (the order ``e`` operands are indexed in) is the
    relation-concatenated order the constructor received; ``g.eid``
    maps canonical slots back to it, exactly as for plain graphs.
    """
    g: Graph
    rel: jnp.ndarray          # (E,) int32, canonical order
    mean_norm: jnp.ndarray    # (E,) float32, canonical order
    perm_rel: jnp.ndarray     # (E,) int32 rel-sorted pos -> canonical slot
    rev_perm: jnp.ndarray     # (E,) int32 (src,rel)-sorted pos -> canonical
    rev_src: jnp.ndarray      # (E,) int32, non-decreasing
    rev_dst: jnp.ndarray      # (E,) int32
    rev_rel: jnp.ndarray      # (E,) int32
    cache: planner.PlanCache  # the fused graph's plan cache
    n_rel: int = dataclasses.field(metadata={"static": True})
    rel_sizes: Tuple[int, ...] = dataclasses.field(
        metadata={"static": True})

    def tree_flatten(self):
        return ((self.g, self.rel, self.mean_norm, self.perm_rel,
                 self.rev_perm, self.rev_src, self.rev_dst, self.rev_rel,
                 self.cache), (self.n_rel, self.rel_sizes))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_rel=aux[0], rel_sizes=aux[1])

    # -- static views ----------------------------------------------------
    @property
    def n_src(self) -> int:
        return self.g.n_src

    @property
    def n_dst(self) -> int:
        return self.g.n_dst

    @property
    def n_edges(self) -> int:
        return self.g.n_edges

    @property
    def rel_ptr(self) -> Tuple[int, ...]:
        """Static per-relation offsets into the relation-sorted view."""
        ptr = [0]
        for s in self.rel_sizes:
            ptr.append(ptr[-1] + s)
        return tuple(ptr)

    @property
    def signature(self) -> Tuple[int, int, int, int]:
        """Static planner key: (n_src, n_dst, n_edges, n_rel)."""
        return (self.n_src, self.n_dst, self.n_edges, self.n_rel)

    def __repr__(self):
        return (f"RelGraph(n_src={self.n_src}, n_dst={self.n_dst}, "
                f"n_edges={self.n_edges}, n_rel={self.n_rel})")


def from_typed(src, dst, rel, *, n_src: int, n_dst: int,
               n_rel: Optional[int] = None) -> RelGraph:
    """Build a :class:`RelGraph` from one typed COO edge list.

    ``rel[i]`` is the relation id of caller edge ``i``; caller order is
    preserved for ``e`` operands. Host-side (numpy), like ``from_coo``.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    rel = np.asarray(rel, np.int64)
    if not (src.shape == dst.shape == rel.shape) or src.ndim != 1:
        raise ValueError("src/dst/rel must be equal-length 1-D")
    n_rel = int(n_rel if n_rel is not None
                else (rel.max() + 1 if rel.size else 0))
    if rel.size and (rel.min() < 0 or rel.max() >= n_rel):
        raise ValueError("relation ids out of range")

    g = from_coo(src, dst, n_src=n_src, n_dst=n_dst)
    eid = np.asarray(g.eid)
    rel_canon = rel[eid]
    src_canon = np.asarray(g.src)
    dst_canon = np.asarray(g.dst)

    # per-(relation, dst) in-degree -> the per-relation mean weight
    key = rel_canon * n_dst + dst_canon
    cnt = np.bincount(key, minlength=n_rel * max(n_dst, 1)) if rel.size \
        else np.zeros(0, np.int64)
    mean_norm = (1.0 / np.maximum(cnt[key], 1)).astype(np.float32) \
        if rel.size else np.zeros(0, np.float32)

    perm_rel = np.argsort(rel_canon, kind="stable").astype(np.int32)
    rel_sizes = tuple(int(x) for x in
                      np.bincount(rel, minlength=n_rel))

    rev_perm = np.lexsort((rel_canon, src_canon)).astype(np.int32)
    return RelGraph(
        g=g,
        rel=jnp.asarray(rel_canon, jnp.int32),
        mean_norm=jnp.asarray(mean_norm),
        perm_rel=jnp.asarray(perm_rel),
        rev_perm=jnp.asarray(rev_perm),
        rev_src=jnp.asarray(src_canon[rev_perm], jnp.int32),
        rev_dst=jnp.asarray(dst_canon[rev_perm], jnp.int32),
        rev_rel=jnp.asarray(rel_canon[rev_perm], jnp.int32),
        cache=planner.get_plan_cache(g),
        n_rel=n_rel, rel_sizes=rel_sizes)


def from_rels(rels: Sequence[Tuple[np.ndarray, np.ndarray]], *,
              n_src: int, n_dst: int) -> RelGraph:
    """Build a :class:`RelGraph` from per-relation ``(src, dst)`` pairs.

    Caller edge order is the concatenation order: relation 0's edges
    (in their given order), then relation 1's, … — so per-relation edge
    features concatenate the same way.
    """
    srcs = [np.asarray(s, np.int64) for s, _ in rels]
    dsts = [np.asarray(d, np.int64) for _, d in rels]
    rel = np.concatenate(
        [np.full(len(s), r, np.int64) for r, s in enumerate(srcs)]
        or [np.zeros(0, np.int64)])
    src = np.concatenate(srcs or [np.zeros(0, np.int64)])
    dst = np.concatenate(dsts or [np.zeros(0, np.int64)])
    return from_typed(src, dst, rel, n_src=n_src, n_dst=n_dst,
                      n_rel=len(rels))


def caller_coo(g: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side (src, dst) of a concrete graph in CALLER edge order."""
    eid_inv = np.asarray(g.eid_inv)
    return np.asarray(g.src)[eid_inv], np.asarray(g.dst)[eid_inv]


# --------------------------------------------------------------------- #
# message computation (relation-indexed)
# --------------------------------------------------------------------- #
# Per-edge W indexing materializes an (E, d_in, d_out) operand stream;
# beyond this many elements the relation-batched pre-transform
# (H = u @ W for all relations, then one (rel, src) gather) is used
# instead — same math, R·n·d_out memory.
_EDGE_MODE_ELEMS = 2_000_000


def _scale(rg: RelGraph, e, reduce: str) -> Optional[jnp.ndarray]:
    """Combined per-edge scalar weight in canonical order (or None)."""
    s = None
    if e is not None:
        ec = jnp.take(e[:, 0] if e.ndim == 2 else e, rg.g.eid, axis=0)
        s = ec
    if reduce == "mean":
        s = rg.mean_norm if s is None else s * rg.mean_norm
    return s


def _messages(rg: RelGraph, u, w, basis, coeff, s) -> jnp.ndarray:
    """Per-edge relation-indexed messages, canonical order.

    ``u`` 2-D + ``w``: messages are ``u[src] @ w[rel]`` — computed by
    per-edge W indexing when the (E, d_in, d_out) stream is small, and
    by the relation-batched pre-transform + one fused (rel, src) gather
    otherwise. ``u`` 2-D + ``basis``/``coeff``: W stays FACTORED — one
    dense basis transform of all nodes (n·B·d·o flops, below the
    loop's E·d·o once B < avg relation degree), then the
    relation-indexed einsum against ``coeff[rel]`` per edge. ``u`` 3-D
    (n_src, n_rel, d): the caller pre-transformed per relation
    (MoNet's per-kernel features); the gather indexes ``(src, rel)``
    directly.
    """
    g = rg.g
    if u.ndim == 3:
        if w is not None or basis is not None:
            raise ValueError("3-D u is already per-relation; w/basis "
                             "must be None")
        flat = u.reshape(u.shape[0] * rg.n_rel, u.shape[2])
        msg = jnp.take(flat, g.src * rg.n_rel + rg.rel, axis=0)
    elif basis is not None:
        # basis decomposition as a relation-indexed einsum INSIDE the
        # fused op: hb = u @ basis once for all nodes, coeff[rel]
        # contracts the basis axis per edge
        hb = jnp.einsum("nd,bdo->nbo", u, basis)
        msg = jnp.einsum("ebo,eb->eo", jnp.take(hb, g.src, axis=0),
                         jnp.take(coeff, rg.rel, axis=0))
    elif w is None:
        msg = jnp.take(u, g.src, axis=0)
    else:
        d_in, d_out = u.shape[1], w.shape[2]
        if g.n_edges * d_in * d_out <= _EDGE_MODE_ELEMS:
            # the literal fused form: gather h at fused-src, index W by
            # edge relation id, one einsum
            msg = jnp.einsum("ed,edo->eo", jnp.take(u, g.src, axis=0),
                             jnp.take(w, rg.rel, axis=0))
        else:
            # relation-batched pre-transform: R dense matmuls (BLAS),
            # then ONE relation-indexed gather — the sparse side stays
            # a single fused stream
            H = jnp.einsum("nd,rdo->rno", u, w)
            flat = H.reshape(rg.n_rel * u.shape[0], d_out)
            msg = jnp.take(flat, rg.rel * u.shape[0] + g.src, axis=0)
    if s is not None:
        msg = msg * s[:, None]
    return msg


def _reduce_fused(rg: RelGraph, msg, reduce: str,
                  strategy: str) -> jnp.ndarray:
    """One reduction over the fused (dst-sorted) edge stream."""
    g = rg.g
    base = "sum" if reduce in ("sum", "mean") else reduce
    if strategy == "ell":
        spec = parse_op(f"e_copy_{'add' if base == 'sum' else base}_v")
        if base == "sum":
            classes = _skew_classes(rg)
            if classes is not None:
                # size-skew-aware per-relation-class pull: each size
                # class reduces over its OWN sub-graph's ELL pack, so
                # one giant relation's degrees no longer set the pad
                # width for everyone; the class partials sum exactly
                out = None
                for cg, slots in classes:
                    pack = planner.get_plan_cache(cg).peek("ell")
                    plan = planner.Plan(strategy="ell", requested="ell",
                                        reason="hetero-skew", ell=pack)
                    if pack is None:    # never happens: built eagerly
                        plan = planner.Plan(strategy="segment",
                                            requested="ell",
                                            reason="hetero-skew")
                    part = _execute(cg, spec,
                                    jnp.take(msg, slots, axis=0), None,
                                    plan)
                    out = part if out is None else out + part
                return out
        elif base in ("max", "min"):
            classes = _skew_classes(rg)
            if classes is not None:
                # extrema version of the skew-class pull: per-class RAW
                # reductions (±inf kept on per-class-empty rows so a
                # zero fill can't clobber another class's negative
                # extremum), combined with the extremum, finalized once
                comb = jnp.maximum if base == "max" else jnp.minimum
                seg = (jax.ops.segment_max if base == "max"
                       else jax.ops.segment_min)
                out = None
                for cg, slots in classes:
                    sub = jnp.take(msg, slots, axis=0)  # class caller
                    pack = planner.get_plan_cache(cg).peek("ell")
                    if pack is not None:
                        part = S.pull_ell_reduce(
                            pack,
                            lambda cls, sub=sub: jnp.take(
                                sub, cls.chunk_eids, axis=0),
                            base, raw=True)
                    else:               # in-trace, pack never built
                        part = seg(jnp.take(sub, cg.eid, axis=0),
                                   cg.dst, num_segments=cg.n_dst,
                                   indices_are_sorted=True)
                    out = part if out is None else comb(out, part)
                out = jnp.where(jnp.isfinite(out), out,
                                jnp.zeros((), out.dtype))
                return S.finalize_empty_rows(out, g.in_degrees, base)
        # peek only: hetero_gspmm guarantees the pack was built (on an
        # eager call) before routing here — building now could run
        # inside a trace and leak
        plan = planner.Plan(strategy="ell", requested="ell",
                            reason="hetero", ell=rg.cache.peek("ell"))
        if plan.ell is None:        # in-trace, pack never built
            plan = planner.Plan(strategy="segment", requested="ell",
                                reason="hetero-ell-unavailable")
        # _execute's e-target gather indexes caller order
        return _execute(g, spec, jnp.take(msg, g.eid_inv, axis=0), None,
                        plan)
    return S.pull_segment(msg, g.dst, g.n_dst, base, deg=g.in_degrees)


# --------------------------------------------------------------------- #
# size-skew-aware relation classes (ell-per-relation-class)
# --------------------------------------------------------------------- #
# One uniform ELL pack over the fused graph pads every destination row
# to the GLOBAL max degree — and relation sizes in real heterographs are
# wildly skewed (BGS: one relation holds half the edges), so the giant
# relation's hubs set the pad width paid by every tiny relation's rows.
# When the skew is material we bucket relations into log2 size classes,
# split the fused edge set per class, and give each class its own
# sub-graph + ELL pack: same Σ math (the class partials sum), narrow
# pads per class. Class structures build eagerly (host-side) and are
# memoized per fused graph; inside a trace a never-built entry simply
# falls back to the global pack.
_SKEW_RATIO = 8.0       # max relation size / median — below this, skip
_SKEW_MIN_RELS = 3      # fewer relations: bucketing can't help
_MISSING = object()
_SKEW_CLASSES: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _build_skew_classes(rg: RelGraph):
    """Host-side build: relations bucketed by ⌊log2(edge count)⌋.

    Returns ``((class_graph, canonical_slots), ...)`` — slots index the
    fused graph's CANONICAL edge order and double as the class graph's
    caller edge order — or None when the size distribution doesn't
    warrant splitting (skew below ratio, too few relations, or all
    relations land in one size class)."""
    sizes = np.asarray(rg.rel_sizes, np.int64)
    nz = sizes[sizes > 0]
    if nz.size < _SKEW_MIN_RELS:
        return None
    med = max(float(np.median(nz)), 1.0)
    if float(nz.max()) / med < _SKEW_RATIO:
        return None
    band = np.where(sizes > 0,
                    np.floor(np.log2(np.maximum(sizes, 1))), -1.0)
    band = band.astype(np.int64)
    distinct = sorted({int(b) for b in band if b >= 0})
    if len(distinct) < 2:
        return None
    g = rg.g
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    perm = np.asarray(rg.perm_rel)
    ptr = rg.rel_ptr
    classes = []
    for b in distinct:
        slots = np.concatenate([perm[ptr[r]:ptr[r + 1]]
                                for r in range(rg.n_rel)
                                if band[r] == b])
        cg = from_coo(src[slots], dst[slots],
                      n_src=g.n_src, n_dst=g.n_dst)
        planner.get_plan_cache(cg).ell()    # the class's own pad width
        classes.append((cg, jnp.asarray(slots, jnp.int32)))
    return tuple(classes)


def _skew_classes(rg: RelGraph):
    """Memoized class structures for ``rg.g`` (None = use global pack).

    Builds only when no trace is active — in-trace the memo is read-only
    and a miss means the caller stays on the fused graph's single pack."""
    got = _SKEW_CLASSES.get(rg.g, _MISSING)
    if got is not _MISSING:
        return got
    if not jax.core.trace_state_clean() or planner.graph_is_traced(rg.g):
        return None                 # don't build (or memoize) in-trace
    classes = _build_skew_classes(rg)
    _SKEW_CLASSES[rg.g] = classes   # memoize None too: not-skewed is final
    return classes


def _exec_hetero(rg: RelGraph, u, w, basis, coeff, s, reduce: str,
                 strategy: str) -> jnp.ndarray:
    if strategy == "loop" or strategy == "push":
        if basis is not None:       # the pre-refactor form materializes W
            w = jnp.einsum("rb,bdo->rdo", coeff, basis)
        return _exec_loop(rg, u, w, s, reduce,
                          inner="push" if strategy == "push"
                          else "segment")
    return _reduce_fused(rg, _messages(rg, u, w, basis, coeff, s),
                         reduce, strategy)


def _exec_loop(rg: RelGraph, u, w, s, reduce: str,
               inner: str = "segment") -> jnp.ndarray:
    """The pre-refactor baseline: one aggregation call per relation.

    R sequential gathers + reduces over the relation-sorted slices —
    the per-type launch overhead the fused path exists to remove. Kept
    (a) as the planner's small-R candidate and (b) as the measured
    baseline in ``benchmarks/fig_hetero.py``; ``inner='push'`` swaps
    the per-relation reduce for the scatter baseline (fig2's 'push').
    """
    g = rg.g
    base = "sum" if reduce in ("sum", "mean") else reduce
    ptr = rg.rel_ptr
    out = None
    for r in range(rg.n_rel):
        lo, hi = ptr[r], ptr[r + 1]
        if hi == lo:
            continue            # empty relation: no call at all
        slots = jax.lax.slice_in_dim(rg.perm_rel, lo, hi)
        src_r = jnp.take(g.src, slots)
        dst_r = jnp.take(g.dst, slots)
        if u.ndim == 3:
            msg = jnp.take(u[:, r, :], src_r, axis=0)
        else:
            msg = jnp.take(u, src_r, axis=0)
            if w is not None:
                msg = msg @ w[r]
        if s is not None:
            msg = msg * jnp.take(s, slots)[:, None]
        if inner == "push":
            # identity fill preserved (no deg): cross-relation combine
            # below stays correct for negative extrema
            part = S.push_scatter(msg, dst_r, g.n_dst, base)
        elif base == "sum":
            part = jax.ops.segment_sum(msg, dst_r, num_segments=g.n_dst,
                                       indices_are_sorted=True)
        else:
            # raw segment extrema keep ±inf on per-relation-empty rows —
            # pull_segment's zero fill would clobber another relation's
            # negative extremum in the combine
            seg = (jax.ops.segment_max if base == "max"
                   else jax.ops.segment_min)
            part = seg(msg, dst_r, num_segments=g.n_dst,
                       indices_are_sorted=True)
        if out is None:
            out = part
        elif base == "sum":
            out = out + part
        elif base == "max":
            out = jnp.maximum(out, part)
        elif base == "min":
            out = jnp.minimum(out, part)
        else:
            raise ValueError(f"unsupported hetero reducer {reduce!r}")
    d_out = (u.shape[-1] if w is None else w.shape[-1])
    if out is None:
        return jnp.zeros((g.n_dst, d_out), u.dtype)
    if base in ("max", "min"):
        out = jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
        out = S.finalize_empty_rows(out, g.in_degrees, base)
    return out


# --------------------------------------------------------------------- #
# the gather backward (custom VJP — DESIGN.md §8.4)
# --------------------------------------------------------------------- #
def _hetero_grads(rg: RelGraph, u, w, basis, coeff, s, ct):
    """Gather-based adjoints of the fused relational CR.

    Every cotangent derives from ONE sorted segment reduce over the
    (src, rel)-sorted reverse table: C[s, r] = Σ_{e∈E_r: src=s} s_e ·
    ct[dst_e]. Then ∂u = Σ_r C[·,r] Wᵣᵀ and ∂Wᵣ = uᵀ C[·,r] — or, with
    the basis kept factored, the same contractions against Cb =
    C·coeff — are dense einsums: no scatter anywhere, mirroring the
    reverse-block VJP.
    """
    g = rg.g
    ct_rev = jnp.take(ct, rg.rev_dst, axis=0)
    if s is not None:
        ct_rev = ct_rev * jnp.take(s, rg.rev_perm)[:, None]
    if u.ndim == 3:
        key = rg.rev_src * rg.n_rel + rg.rev_rel
        C = jax.ops.segment_sum(ct_rev, key,
                                num_segments=g.n_src * rg.n_rel,
                                indices_are_sorted=True)
        du = C.reshape(u.shape).astype(u.dtype)
        return du, None, None, None
    if w is None and basis is None:
        du = jax.ops.segment_sum(ct_rev, rg.rev_src,
                                 num_segments=g.n_src,
                                 indices_are_sorted=True)
        return du.astype(u.dtype), None, None, None
    key = rg.rev_src * rg.n_rel + rg.rev_rel
    C = jax.ops.segment_sum(ct_rev, key,
                            num_segments=g.n_src * rg.n_rel,
                            indices_are_sorted=True)
    C = C.reshape(g.n_src, rg.n_rel, ct.shape[-1])
    if basis is not None:
        Cb = jnp.einsum("nro,rb->nbo", C, coeff)
        du = jnp.einsum("nbo,bdo->nd", Cb, basis).astype(u.dtype)
        dbasis = jnp.einsum("nbo,nd->bdo", Cb, u).astype(basis.dtype)
        hb = jnp.einsum("nd,bdo->nbo", u, basis)
        dcoeff = jnp.einsum("nro,nbo->rb", C, hb).astype(coeff.dtype)
        return du, None, dbasis, dcoeff
    du = jnp.einsum("nro,rdo->nd", C, w).astype(u.dtype)
    dw = jnp.einsum("nro,nd->rdo", C, u).astype(w.dtype)
    return du, dw, None, None


def _hetero_de(rg: RelGraph, u, w, basis, coeff, norm, ct):
    """∂(e-operand): per-edge <unscaled message, ct[dst]>, caller order."""
    g = rg.g
    base = _messages(rg, u, w, basis, coeff, norm)  # mean folded, e NOT
    ds = jnp.sum(base * jnp.take(ct, g.dst, axis=0), axis=-1)
    return jnp.take(ds, g.eid_inv, axis=0)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _hetero_fused_rev(reduce: str, strategy: str, rg: RelGraph,
                      u, w, basis, coeff, e):
    s = _scale(rg, e, reduce)
    return _exec_hetero(rg, u, w, basis, coeff, s, reduce, strategy)


def _hetero_fused_rev_fwd(reduce, strategy, rg, u, w, basis, coeff, e):
    out = _hetero_fused_rev(reduce, strategy, rg, u, w, basis, coeff, e)
    return out, (rg, u, w, basis, coeff, e)


def _hetero_fused_rev_bwd(reduce, strategy, res, ct):
    rg, u, w, basis, coeff, e = res
    s = _scale(rg, e, reduce)
    du, dw, dbasis, dcoeff = _hetero_grads(rg, u, w, basis, coeff, s, ct)
    de = None
    if e is not None:
        norm = rg.mean_norm if reduce == "mean" else None
        de = _hetero_de(rg, u, w, basis, coeff, norm, ct).astype(e.dtype)
        if e.ndim == 2:
            de = de[:, None]
    return None, du, dw, dbasis, dcoeff, de


_hetero_fused_rev.defvjp(_hetero_fused_rev_fwd, _hetero_fused_rev_bwd)


# --------------------------------------------------------------------- #
# main entry
# --------------------------------------------------------------------- #
def hetero_gspmm(rg: RelGraph, u: jnp.ndarray, *,
                 w: Optional[jnp.ndarray] = None,
                 basis: Optional[jnp.ndarray] = None,
                 coeff: Optional[jnp.ndarray] = None,
                 e: Optional[jnp.ndarray] = None,
                 reduce: str = "sum",
                 strategy: str = "auto") -> jnp.ndarray:
    """Fused heterogeneous aggregation: ``out[v] = ⊕_r Σ_{E_r} msg``.

    Operands:
      * ``u``: (n_src, d) node features, or (n_src, n_rel, d) when the
        caller already holds per-relation features (MoNet's kernels);
      * ``w``: (n_rel, d_in, d_out) per-relation projection — messages
        become ``u[src] @ w[rel]`` (relation-indexed inside the op);
      * ``basis``/``coeff``: RGCN basis decomposition, kept FACTORED
        inside the op — one dense basis transform of all nodes, then a
        relation-indexed ``coeff[rel]`` einsum per edge (cheaper than
        materializing any W once B < the average relation degree); the
        custom VJP emits ∂basis/∂coeff directly;
      * ``e``: (n_edges,) or (n_edges, 1) per-edge scalar weight in
        caller order (MoNet's kernel weights, GCN-style norms).

    ``reduce``: 'sum' | 'mean' (per-RELATION mean, RGCN's 1/c_{v,r}) |
    'max' | 'min' (extrema over the fused edge set). Linear reducers
    run under the gather custom VJP; max/min stay on autodiff.

    ``strategy``: 'auto' (planner, logged ``hetero:<op>``), 'fused',
    'loop' (per-relation baseline), 'ell' (fused messages + the fused
    graph's blocked pull; under material relation-size skew the
    sum/max/min forms split into per-size-class packs), or any plain
    gspmm strategy name — which
    pins the per-relation loop with that inner reduce ('push' is the
    fig2 baseline; the rest run the loop's segment form).
    """
    if reduce not in ("sum", "mean", "max", "min"):
        raise ValueError(f"unknown hetero reducer {reduce!r}")
    if basis is not None or coeff is not None:
        if basis is None or coeff is None:
            raise ValueError("basis and coeff must be given together")
        if w is not None:
            raise ValueError("pass either w or basis/coeff, not both")
    if u.ndim == 3 and u.shape[1] != rg.n_rel:
        raise ValueError(f"3-D u must be (n_src, n_rel={rg.n_rel}, d), "
                         f"got {u.shape}")

    projected = w is not None or basis is not None
    op_name = "u{}{}_{}_v".format("_w" if projected else "",
                                  "_e" if e is not None else "", reduce)
    d_out = int(w.shape[-1] if w is not None
                else basis.shape[-1] if basis is not None
                else u.shape[-1])

    # packs may only be BUILT on fully-eager calls: a concrete graph
    # closed over by a jitted function would otherwise build its pack
    # inside the trace and leak trace-bound constants into the cache
    # "eager" must mean NO trace is active at all — not merely concrete
    # operands: a jitted function that closes over everything still
    # traces, and np→jnp conversions inside it (a pack build, autotune
    # measurement) would leak trace-bound values into the cache
    eager = (jax.core.trace_state_clean()
             and not any(planner._is_traced(x)
                         for x in (rg.g.src, u, w, basis, coeff, e)
                         if x is not None))
    runner = None
    if planner.get_mode() == "autotune" and strategy == "auto" and eager:
        def runner(st):
            if st == "ell":
                rg.cache.ell()
            return _exec_hetero(rg, u, w, basis, coeff,
                                _scale(rg, e, reduce), reduce, st)

    ell_ok = rg.cache.peek("ell") is not None or eager
    chosen = planner.plan_hetero(rg.signature, op_name, d_out,
                                 requested=strategy,
                                 stats=rg.cache.stats, ell_ok=ell_ok,
                                 runner=runner)
    if chosen == "ell":
        pack = rg.cache.ell() if eager else rg.cache.peek("ell")
        if pack is None:
            chosen = "fused"    # in-trace without a prebuilt pack
    # eager calls are fenced + timed under the hetero plan-log key
    if reduce in ("sum", "mean") and chosen in ("fused", "ell"):
        return _timed(f"hetero:{op_name}",
                      lambda: _hetero_fused_rev(reduce, chosen, rg, u, w,
                                                basis, coeff, e))
    return _timed(f"hetero:{op_name}",
                  lambda: _exec_hetero(rg, u, w, basis, coeff,
                                       _scale(rg, e, reduce), reduce,
                                       chosen))


# --------------------------------------------------------------------- #
# relational blocks (sampled RGCN — DESIGN.md §8.5)
# --------------------------------------------------------------------- #
def hetero_block_gspmm(bg, rel: jnp.ndarray, u: jnp.ndarray,
                       w: jnp.ndarray, *,
                       norm: Optional[jnp.ndarray] = None,
                       strategy: str = "auto",
                       bwd_strategy: str = "auto") -> jnp.ndarray:
    """Fused relational aggregation over one sampled block.

    ``bg`` is a reverse-table-carrying
    :class:`~repro.core.blocks.BlockGraph`; ``rel`` (n_edges_pad,) the
    relation id per edge and ``norm`` the per-(dst, relation) mean
    weight, both in caller edge order (the relational sampler emits
    them; pad edges carry norm 0 and point at the dummy destination
    row, so they vanish either way). Messages are ``u[src] @ w[rel]``
    — per-edge W indexing; blocks are small by construction — and the
    reduce stage rides the shape-keyed block planner
    (:func:`~repro.core.planner.plan_block_gspmm`, as an ``e``-operand
    sum). ``bwd_strategy='gather'`` (or 'auto' on large blocks) pulls
    ∂u over the block's reverse table exactly like
    :func:`~repro.core.blocks.block_gspmm`'s custom VJP.
    """
    from .blocks import _block_execute      # local: blocks↔hetero

    spec = parse_op("e_copy_add_v")
    d_out = int(w.shape[-1])
    chosen = planner.plan_block_gspmm(bg.signature, spec, d_out,
                                      requested=strategy,
                                      dtype=str(u.dtype))
    bwd = planner.plan_block_vjp(bg.signature, spec, d_out,
                                 requested=bwd_strategy,
                                 gather_available=bg.has_reverse,
                                 dtype=str(u.dtype))
    if bwd == "gather":
        return _hetero_block_rev(chosen, bg, rel, u, w, norm)
    msg = _block_messages(bg, rel, u, w, norm)
    return _block_execute(bg, spec, msg, None, chosen)


def _block_messages(bg, rel, u, w, norm) -> jnp.ndarray:
    """Per-edge relation-projected messages in CALLER edge order."""
    g = bg.g
    src_caller = jnp.take(g.src, g.eid_inv)
    msg = jnp.einsum("ed,edo->eo", jnp.take(u, src_caller, axis=0),
                     jnp.take(w, rel, axis=0))
    if norm is not None:
        msg = msg * norm[:, None]
    return msg


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _hetero_block_rev(fwd_strategy: str, bg, rel, u, w, norm):
    from .blocks import _block_execute

    msg = _block_messages(bg, rel, u, w, norm)
    return _block_execute(bg, parse_op("e_copy_add_v"), msg, None,
                          fwd_strategy)


def _hetero_block_rev_fwd(fwd_strategy, bg, rel, u, w, norm):
    out = _hetero_block_rev(fwd_strategy, bg, rel, u, w, norm)
    return out, (bg, rel, u, w, norm)


def _hetero_block_rev_bwd(fwd_strategy, res, ct):
    bg, rel, u, w, norm = res
    g = bg.g
    # zero dummy-destination row: pad edges pull exactly zero
    ct_pad = jnp.concatenate(
        [ct, jnp.zeros((1,) + ct.shape[1:], ct.dtype)], axis=0)
    rel_rev = jnp.take(rel, bg.rev_eid)
    ct_rev = jnp.take(ct_pad, bg.rev_dst, axis=0)
    if norm is not None:
        ct_rev = ct_rev * jnp.take(norm, bg.rev_eid)[:, None]
    # ∂u: pull over the src-sorted reverse table — no scatter
    du = jax.ops.segment_sum(
        jnp.einsum("eo,edo->ed", ct_rev, jnp.take(w, rel_rev, axis=0)),
        bg.rev_src, num_segments=g.n_src,
        indices_are_sorted=True).astype(u.dtype)
    # ∂w: per-relation outer products (R segments; blocks are small)
    src_caller = jnp.take(g.src, g.eid_inv)
    dst_caller = jnp.take(g.dst, g.eid_inv)
    ct_e = jnp.take(ct_pad, dst_caller, axis=0)
    if norm is not None:
        ct_e = ct_e * norm[:, None]
    outer = jnp.einsum("ed,eo->edo", jnp.take(u, src_caller, axis=0),
                       ct_e)
    dw = jax.ops.segment_sum(outer, rel,
                             num_segments=w.shape[0]).astype(w.dtype)
    return None, None, du, dw, None


_hetero_block_rev.defvjp(_hetero_block_rev_fwd, _hetero_block_rev_bwd)

"""Partitioned-graph execution: the paper's K-blocking lifted to shards.

The paper's Alg. 2 argument — owner-computes pull aggregation over
bounded K-block working sets — reappears one level up when a graph is
vertex-partitioned across devices (DistGNN, Vasimuddin et al., 2021,
makes exactly this lift for the same Intel DGL kernels). This module is
that level as a first-class subsystem:

* :class:`PartitionedGraph` — a host-planned vertex partition of a
  :class:`Graph`: each of ``n_shards`` shards owns a padded block of
  ``rows`` destination rows, and every edge lives in exactly one
  ``(dst_shard, src_shard)`` bucket (padded to ``eb`` slots). Buckets
  are the cluster-granularity K-blocks: at ring stage ``s`` a device
  holds one remote source block and consumes exactly one bucket.
  Registered as a pytree so it flows through ``jit`` like
  :class:`~repro.models.gnn.common.GraphBundle`.
* :func:`ring_gspmm` — differentiable sharded weighted Copy-Reduce.
  Forward: source blocks rotate around a ``lax.ppermute`` ring while
  each owner reduces its resident bucket (compute overlaps the next
  transfer). Backward (``custom_vjp``): the *transposed ring* — the
  permute direction reverses and the src/dst bucket roles swap, which
  is the cluster-level form of the PR-2 observation that the adjoint of
  Copy-Reduce is Copy-Reduce on the reverse graph.
* :func:`ring_edge_values` / :func:`bucket_softmax` — per-edge operand
  assembly and destination softmax over the bucketed edge layout; with
  :func:`ring_gspmm` they cover GAT-style attention on shards.
* :func:`ring_gspmm_delayed` — DistGNN-style delayed halo: remote
  partial aggregates are refreshed every k-th step and otherwise reused
  stale (gradients flow through the owner-local part only), trading
  exactness for a ring-free step.

Every ring function takes ``mesh=None`` to run an *emulated*
single-device path: the same bucket math and the same custom-VJP
structure with the device loop unrolled in Python. The emulated path is
the differential-test oracle (it joins the cross-strategy equivalence
harness) and makes the partitioned model forwards runnable anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .graph import Graph
from ..obs import metrics as _metrics
from ..optim.compression import compress_payload, wire_bytes

__all__ = ["PartitionStats", "PartitionedGraph", "build_partition",
           "ring_gspmm", "ring_edge_values", "bucket_softmax",
           "local_gspmm", "ring_gspmm_delayed", "ring_reference",
           "PARTITION_MODES", "COMM_MODES"]

PARTITION_MODES = ("contiguous", "hash", "uniform")
COMM_MODES = ("none", "int8")


def _acc_dtype(dtype):
    """Reduce accumulators never drop below fp32: bf16 features sum in
    fp32 and only the final output is cast back (DESIGN.md §12)."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.promote_types(dtype, jnp.float32)
    return dtype


def _count_exchange(pg: "PartitionedGraph", x, comm: str) -> None:
    """Account one full ring exchange in the obs metrics registry.

    A ring pass moves every source block through ``ragged_stages`` hops
    (at most S-1; trailing all-empty bucket diagonals are never
    rotated): S·stages block-sends of ``rows × feat`` elements.
    ``raw_bytes`` is what the uncompressed payload would weigh at
    ``x.dtype``; ``wire_bytes`` is what actually travels under ``comm``
    (int8 + per-block fp32 scales). Both counters bump together, so
    their ratio is the measured compression factor regardless of call
    count. ``pad_slots`` tracks the bucket slots the ragged schedule
    touches beyond the real edges — the residual padding tax.
    """
    if not _metrics.enabled() or pg.n_shards < 2:
        return
    st = pg.stats
    elems = pg.rows * int(np.prod(x.shape[1:], dtype=np.int64))
    raw, wire = wire_bytes(elems, jnp.dtype(x.dtype).itemsize, comm)
    stages = st.ragged_stages if st.ragged_stages >= 0 else pg.n_shards - 1
    hops = pg.n_shards * stages
    _metrics.counter("comm.ring.raw_bytes").inc(hops * raw)
    _metrics.counter("comm.ring.wire_bytes").inc(hops * wire)
    slots = st.ragged_slots if st.ragged_slots > 0 else (
        pg.n_shards * pg.n_shards * pg.eb)
    _metrics.counter("comm.ring.pad_slots").inc(
        max(slots - pg.n_edges, 0))


# --------------------------------------------------------------------- #
# the partition plan
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PartitionStats:
    """Static, hashable features of a partition — the planner's view."""
    n_shards: int
    rows_per_shard: int
    eb: int                 # padded edge slots per (dst, src) bucket
    n_edges: int
    cut_fraction: float     # edges whose endpoints live on different shards
    pad_ratio: float        # S*S*eb / n_edges — bucket padding waste
    balance: float          # max / mean edges owned per dst shard
    # ragged bucket accounting (defaults keep hand-built stats valid):
    # slots the per-diagonal-max schedule touches (S · Σ_s w_s, diagonal
    # included), the last non-empty bucket diagonal (= ring transfers
    # per device; -1 means "unknown, assume dense S-1"), and the ragged
    # slots / n_edges waste ratio.
    ragged_slots: int = 0
    ragged_stages: int = -1
    ragged_pad_ratio: float = 1.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class PartitionedGraph:
    """Host-planned vertex partition + per-(dst,src)-shard edge buckets.

    Vertices are mapped to padded slots ``shard * rows + local``
    (``to_pad`` / ``from_pad``); each edge occupies one slot of bucket
    ``(shard(dst), shard(src))`` with its endpoints stored as *local*
    offsets and its caller-order edge id in ``eid`` (so per-edge
    weights are bucketed with one gather). All bucket arrays are padded
    to the common width ``eb``; pad slots are masked and index 0.
    """
    to_pad: jnp.ndarray      # (n,) vertex id -> padded slot
    from_pad: jnp.ndarray    # (n_pad,) padded slot -> vertex id or -1
    src_local: jnp.ndarray   # (S, S, eb) int32 source offset in its shard
    dst_local: jnp.ndarray   # (S, S, eb) int32 destination offset
    eid: jnp.ndarray         # (S, S, eb) int32 caller-order edge id
    mask: jnp.ndarray        # (S, S, eb) bool

    n_shards: int = dataclasses.field(metadata={"static": True})
    rows: int = dataclasses.field(metadata={"static": True})
    eb: int = dataclasses.field(metadata={"static": True})
    n: int = dataclasses.field(metadata={"static": True})
    n_edges: int = dataclasses.field(metadata={"static": True})
    mode: str = dataclasses.field(metadata={"static": True})
    stats: PartitionStats = dataclasses.field(metadata={"static": True})
    # real (unpadded) slot count of bucket (i, j); the bucket fill is
    # contiguous from slot 0, so a static [:eb_ij[i][j]] slice captures
    # exactly the real edges. Default () means "unknown — dense eb".
    eb_ij: Tuple[Tuple[int, ...], ...] = dataclasses.field(
        default=(), metadata={"static": True})

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return ((self.to_pad, self.from_pad, self.src_local,
                 self.dst_local, self.eid, self.mask),
                (self.n_shards, self.rows, self.eb, self.n, self.n_edges,
                 self.mode, self.stats, self.eb_ij))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_pad(self) -> int:
        return self.n_shards * self.rows

    def bucket_width(self, i: int, j: int) -> int:
        """Real slot count of bucket (i, j) — ``eb`` when unknown."""
        if not self.eb_ij:
            return self.eb
        return self.eb_ij[i][j]

    # -- layout converters ----------------------------------------------
    def scatter_nodes(self, x: jnp.ndarray) -> jnp.ndarray:
        """(n_rows, *feat) vertex-ordered -> (n_pad, *feat) padded."""
        out = jnp.zeros((self.n_pad,) + x.shape[1:], x.dtype)
        return out.at[self.to_pad[: x.shape[0]]].set(x)

    def gather_nodes(self, xp: jnp.ndarray,
                     n_rows: Optional[int] = None) -> jnp.ndarray:
        """(n_pad, *feat) padded -> (n_rows, *feat) vertex-ordered."""
        n_rows = self.n if n_rows is None else n_rows
        return jnp.take(xp, self.to_pad[:n_rows], axis=0)

    def scatter_edges(self, w: jnp.ndarray) -> jnp.ndarray:
        """(n_edges, ...) caller-order edge values -> bucketed
        (S, S, eb, ...) with zeros on pad slots."""
        vals = jnp.take(w, self.eid, axis=0)
        mask = self.mask.reshape(self.mask.shape
                                 + (1,) * (vals.ndim - self.mask.ndim))
        return jnp.where(mask, vals, jnp.zeros((), vals.dtype))

    def gather_edges(self, wb: jnp.ndarray) -> jnp.ndarray:
        """Bucketed (S, S, eb, ...) -> (n_edges, ...) caller order."""
        flat = wb.reshape((-1,) + wb.shape[3:])
        eid = self.eid.reshape(-1)
        mk = self.mask.reshape(-1)
        out = jnp.zeros((self.n_edges,) + wb.shape[3:], wb.dtype)
        sel = jnp.where(mk, eid, self.n_edges)   # drop pads out of range
        return out.at[sel].set(flat, mode="drop")

    def __repr__(self):
        return (f"PartitionedGraph(S={self.n_shards}, rows={self.rows}, "
                f"eb={self.eb}, n={self.n}, mode={self.mode!r})")


def _shard_assignment(g: Graph, n_shards: int, mode: str
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
    """vertex id -> (shard, local offset); returns (shard, local, rows)."""
    n = max(g.n_src, g.n_dst)
    ids = np.arange(n, dtype=np.int64)
    if mode == "hash":
        shard = ids % n_shards
        local = ids // n_shards
    elif mode == "uniform":
        rows = -(-n // n_shards)
        shard = ids // rows
        local = ids % rows
        return shard, local, rows
    elif mode == "contiguous":
        # degree-balanced contiguous ranges: split the cumulative edge
        # mass (in + out degree) into n_shards nearly-equal chunks
        deg = np.zeros(n, np.int64)
        deg[: g.n_dst] += np.asarray(g.in_degrees, np.int64)
        deg[: g.n_src] += np.asarray(g.out_degrees, np.int64)
        cum = np.cumsum(deg + 1)            # +1 keeps empty rows spread
        targets = cum[-1] * (np.arange(1, n_shards) / n_shards)
        bounds = np.searchsorted(cum, targets, side="left")
        shard = np.searchsorted(bounds, ids, side="right")
        starts = np.concatenate([[0], bounds])
        local = ids - starts[shard]
    else:
        raise ValueError(f"unknown partition mode {mode!r}; expected one "
                         f"of {PARTITION_MODES}")
    rows = int(np.bincount(shard, minlength=n_shards).max()) if n else 1
    return shard, local, max(rows, 1)


def build_partition(g: Graph, n_shards: int,
                    mode: str = "contiguous") -> PartitionedGraph:
    """Host-side partition planning — fully vectorized (no per-edge
    Python loop; the bucket fill is one stable sort + one scatter)."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    shard, local, rows = _shard_assignment(g, n_shards, mode)
    n = max(g.n_src, g.n_dst)

    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    eid = np.asarray(g.eid, np.int64)       # canonical slot -> caller id
    E = src.shape[0]

    i = shard[dst] if E else np.zeros(0, np.int64)   # dst (owner) shard
    j = shard[src] if E else np.zeros(0, np.int64)   # src shard
    key = i * n_shards + j
    order = np.argsort(key, kind="stable")
    counts = np.bincount(key, minlength=n_shards * n_shards)
    eb = max(1, int(counts.max())) if E else 1
    offs = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(E) - offs[key[order]]            # slot within bucket

    SL = np.zeros((n_shards * n_shards, eb), np.int32)
    DL = np.zeros((n_shards * n_shards, eb), np.int32)
    EID = np.zeros((n_shards * n_shards, eb), np.int32)
    MK = np.zeros((n_shards * n_shards, eb), bool)
    SL[key[order], pos] = local[src[order]]
    DL[key[order], pos] = local[dst[order]]
    EID[key[order], pos] = eid[order]
    MK[key[order], pos] = True

    to_pad = (shard * rows + local).astype(np.int32)
    from_pad = np.full(n_shards * rows, -1, np.int32)
    from_pad[to_pad] = np.arange(n, dtype=np.int32)

    owned = np.bincount(i, minlength=n_shards) if E else np.zeros(n_shards)
    cut = int((i != j).sum()) if E else 0
    counts2 = counts.reshape(n_shards, n_shards)
    eb_ij = tuple(tuple(int(c) for c in rowc) for rowc in counts2)
    ws = [max(int(counts2[(jj + s) % n_shards, jj])
              for jj in range(n_shards)) for s in range(n_shards)]
    nz = [s for s in range(n_shards) if ws[s] > 0]
    ragged_slots = int(n_shards * sum(ws))
    ragged_stages = nz[-1] if nz else 0
    stats = PartitionStats(
        n_shards=n_shards, rows_per_shard=rows, eb=eb, n_edges=E,
        cut_fraction=float(cut / max(E, 1)),
        pad_ratio=float(n_shards * n_shards * eb / max(E, 1)),
        balance=float(owned.max() / max(owned.mean(), 1e-9)),
        ragged_slots=ragged_slots, ragged_stages=ragged_stages,
        ragged_pad_ratio=float(ragged_slots / max(E, 1)))
    return PartitionedGraph(
        to_pad=jnp.asarray(to_pad), from_pad=jnp.asarray(from_pad),
        src_local=jnp.asarray(SL.reshape(n_shards, n_shards, eb)),
        dst_local=jnp.asarray(DL.reshape(n_shards, n_shards, eb)),
        eid=jnp.asarray(EID.reshape(n_shards, n_shards, eb)),
        mask=jnp.asarray(MK.reshape(n_shards, n_shards, eb)),
        n_shards=n_shards, rows=rows, eb=eb, n=n, n_edges=E, mode=mode,
        stats=stats, eb_ij=eb_ij)


# --------------------------------------------------------------------- #
# the shared per-stage kernel (one K-block)
# --------------------------------------------------------------------- #
def _stage_reduce(block, gather_idx, scatter_idx, mk, wb, out):
    """Consume one bucket: gather from the resident block, weight, mask,
    scatter-add into the accumulator. Forward uses (gather=src,
    scatter=dst); the transposed ring swaps the two index roles."""
    vals = jnp.take(block, gather_idx, axis=0)           # (eb, *feat)
    if wb is not None:
        # the weight (degree norm / attention) stays at ITS dtype —
        # fp32 norms must not be truncated to bf16 before the multiply
        wv = wb.reshape(wb.shape + (1,) * (vals.ndim - wb.ndim))
        vals = vals * wv
    vals = vals.astype(out.dtype)
    mask = mk.reshape(mk.shape + (1,) * (vals.ndim - 1))
    vals = jnp.where(mask, vals, jnp.zeros((), vals.dtype))
    return out.at[scatter_idx].add(vals)


def _edge_dot(xg, cg, mk, head_rank):
    """Per-slot <x, ct> reduced over the trailing feature axes that the
    weight does NOT carry: (eb,) for scalar weights, (eb, H) for
    per-head weights on (H, F) features."""
    acc = _acc_dtype(jnp.promote_types(xg.dtype, cg.dtype))
    prod = xg.astype(acc) * cg.astype(acc)                # (eb, *feat)
    axes = tuple(range(1 + head_rank, prod.ndim))
    dw = prod.sum(axis=axes) if axes else prod
    mask = mk.reshape(mk.shape + (1,) * (dw.ndim - 1))
    return jnp.where(mask, dw, jnp.zeros((), dw.dtype))


def _maybe_pvary(x, axis):
    # mark accumulators device-varying so fori_loop carry types match
    # after ppermute on jax versions with explicit vma tracking
    pvary = getattr(jax.lax, "pvary", None)
    return pvary(x, (axis,)) if pvary is not None else x


def _fwd_perm(S):
    return [(k, (k + 1) % S) for k in range(S)]


def _bwd_perm(S):
    return [(k, (k - 1) % S) for k in range(S)]


def _diag_widths(pg: PartitionedGraph) -> Tuple[int, ...]:
    """Max real bucket width along each ring diagonal.

    At stage ``s`` every device consumes the bucket whose (dst - src)
    shard distance is ``s`` (mod S); under SPMD the stage's slice width
    must be the max over that diagonal. ``ws[0]`` is the owner-local
    diagonal; trailing zero entries are stages the ring can skip
    entirely."""
    S = pg.n_shards
    if not pg.eb_ij:
        return (pg.eb,) * S
    return tuple(max(pg.eb_ij[(j + s) % S][j] for j in range(S))
                 for s in range(S))


def _last_stage(ws: Tuple[int, ...]) -> int:
    """Index of the last non-empty diagonal (0 if all empty)."""
    nz = [s for s in range(len(ws)) if ws[s] > 0]
    return nz[-1] if nz else 0


# --------------------------------------------------------------------- #
# ring_gspmm: differentiable sharded weighted Copy-Reduce
# --------------------------------------------------------------------- #
def _ring_fwd_emu(pg: PartitionedGraph, x, w):
    S, rows = pg.n_shards, pg.rows
    feat = x.shape[1:]
    xs = x.reshape((S, rows) + feat)
    outs = []
    for i in range(S):
        out = jnp.zeros((rows,) + feat, _acc_dtype(x.dtype))
        for j in range(S):
            wij = pg.bucket_width(i, j)      # real slots: exact slice,
            if not wij:                      # empty bucket: no work
                continue
            out = _stage_reduce(xs[j], pg.src_local[i, j][:wij],
                                pg.dst_local[i, j][:wij],
                                pg.mask[i, j][:wij],
                                w[i, j][:wij], out)
        outs.append(out)
    return jnp.stack(outs).reshape((S * rows,) + feat).astype(x.dtype)


def _ring_bwd_emu(pg: PartitionedGraph, x, w, ct):
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = x.shape[1:]
    head_rank = w.ndim - 3
    acc_t = _acc_dtype(jnp.promote_types(x.dtype, ct.dtype))
    xs = x.reshape((S, rows) + feat)
    cts = ct.reshape((S, rows) + feat)
    dxs, dws = [], []
    for j in range(S):           # transposed: iterate SOURCE shards
        dx = jnp.zeros((rows,) + feat, _acc_dtype(x.dtype))
        for i in range(S):       # gather at dst, scatter at src (swap)
            wij = pg.bucket_width(i, j)
            if not wij:
                continue
            dx = _stage_reduce(cts[i], pg.dst_local[i, j][:wij],
                               pg.src_local[i, j][:wij],
                               pg.mask[i, j][:wij],
                               w[i, j][:wij], dx)
        dxs.append(dx)
    for i in range(S):
        dwrow = []
        for j in range(S):
            wij = pg.bucket_width(i, j)
            if wij:
                xg = jnp.take(xs[j], pg.src_local[i, j][:wij], axis=0)
                cg = jnp.take(cts[i], pg.dst_local[i, j][:wij], axis=0)
                d = _edge_dot(xg, cg, pg.mask[i, j][:wij], head_rank)
                d = jnp.pad(d, ((0, eb - wij),)
                            + ((0, 0),) * (d.ndim - 1))
            else:
                d = jnp.zeros(w.shape[2:], acc_t)
            dwrow.append(d)
        dws.append(jnp.stack(dwrow))
    dx = jnp.stack(dxs).reshape((S * rows,) + feat).astype(x.dtype)
    return dx, jnp.stack(dws).astype(w.dtype)


def _node_spec(axis, ndim):
    return P(axis, *([None] * (ndim - 1)))


def _ring_fwd_mesh(pg: PartitionedGraph, mesh, axis, x, w):
    from jax.experimental.shard_map import shard_map
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = x.shape[1:]
    xs = x.reshape((S, rows) + feat)

    ws = _diag_widths(pg)
    s_max = _last_stage(ws)

    def local_fn(xb, sl, dl, mk, wb):
        me = jax.lax.axis_index(axis)
        block = xb[0]
        sl, dl, mk, wb = sl[0], dl[0], mk[0], wb[0]
        out = _maybe_pvary(jnp.zeros((rows,) + feat,
                                     _acc_dtype(x.dtype)), axis)

        # static unroll (S is small): each stage slices its bucket to
        # the diagonal's max real width, and the ring stops after the
        # last non-empty diagonal — trailing stages move no bytes.
        for s in range(s_max + 1):
            shard = (me - s) % S
            # kick off the NEXT block transfer (overlaps the reduce)
            nxt = (jax.lax.ppermute(block, axis, _fwd_perm(S))
                   if s < s_max else block)
            if ws[s]:
                out = _stage_reduce(block,
                                    jnp.take(sl, shard, axis=0)[:ws[s]],
                                    jnp.take(dl, shard, axis=0)[:ws[s]],
                                    jnp.take(mk, shard, axis=0)[:ws[s]],
                                    jnp.take(wb, shard, axis=0)[:ws[s]],
                                    out)
            block = nxt
        return out.astype(x.dtype)[None]

    bucket = P(axis, None, None)
    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(_node_spec(axis, xs.ndim), bucket, bucket,
                            bucket, _node_spec(axis, w.ndim)),
                  out_specs=_node_spec(axis, xs.ndim))
    out = f(xs, pg.src_local, pg.dst_local, pg.mask, w)
    return out.reshape((S * rows,) + feat)


def _ring_bwd_mesh(pg: PartitionedGraph, mesh, axis, x, w, ct):
    """The transposed ring, one pass: cotangent blocks (with their
    weight-bucket rows) rotate BACKWARD for ∂x while source blocks
    rotate forward for ∂w; src/dst bucket roles are swapped for ∂x."""
    from jax.experimental.shard_map import shard_map
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = x.shape[1:]
    head_rank = w.ndim - 3
    xs = x.reshape((S, rows) + feat)
    cts = ct.reshape((S, rows) + feat)
    slT = jnp.swapaxes(pg.src_local, 0, 1)
    dlT = jnp.swapaxes(pg.dst_local, 0, 1)
    mkT = jnp.swapaxes(pg.mask, 0, 1)

    ws = _diag_widths(pg)
    s_max = _last_stage(ws)

    def local_fn(xb, ctb, wb, sl, dl, mk, slt, dlt, mkt):
        me = jax.lax.axis_index(axis)
        xblock = xb[0]
        ct_local = ctb[0]
        wrow = wb[0]                       # (S, eb[, H]) — my dst row
        sl, dl, mk = sl[0], dl[0], mk[0]   # buckets (me, :)
        slt, dlt, mkt = slt[0], dlt[0], mkt[0]   # buckets (:, me)
        dx = _maybe_pvary(jnp.zeros((rows,) + feat,
                                    _acc_dtype(x.dtype)), axis)
        dw = _maybe_pvary(jnp.zeros(wrow.shape, w.dtype), axis)
        ctblock, wblock = ct_local, wrow

        # static unroll mirroring the forward: at stage s both the ∂x
        # bucket (i_ct, me) and the ∂w bucket (me, j_x) sit on the same
        # (dst - src) ≡ s diagonal, so one width ws[s] serves both;
        # trailing empty diagonals skip transfers entirely.
        for s in range(s_max + 1):
            i_ct = (me + s) % S      # dst shard resident via reverse ring
            j_x = (me - s) % S       # src shard resident via forward ring
            if s < s_max:
                x_nxt = jax.lax.ppermute(xblock, axis, _fwd_perm(S))
                ct_nxt = jax.lax.ppermute(ctblock, axis, _bwd_perm(S))
                w_nxt = jax.lax.ppermute(wblock, axis, _bwd_perm(S))
            else:
                x_nxt, ct_nxt, w_nxt = xblock, ctblock, wblock
            if ws[s]:
                # ∂x for MY src shard from bucket (i_ct, me): gather at
                # dst, scatter at src — the swapped-role stage kernel
                dx = _stage_reduce(ctblock,
                                   jnp.take(dlt, i_ct, axis=0)[:ws[s]],
                                   jnp.take(slt, i_ct, axis=0)[:ws[s]],
                                   jnp.take(mkt, i_ct, axis=0)[:ws[s]],
                                   jnp.take(wblock, me, axis=0)[:ws[s]],
                                   dx)
                # ∂w for MY dst bucket (me, j_x): per-edge <x, ct> dot
                xg = jnp.take(xblock,
                              jnp.take(sl, j_x, axis=0)[:ws[s]], axis=0)
                cg = jnp.take(ct_local,
                              jnp.take(dl, j_x, axis=0)[:ws[s]], axis=0)
                de = _edge_dot(xg, cg,
                               jnp.take(mk, j_x, axis=0)[:ws[s]],
                               head_rank).astype(w.dtype)
                de = jnp.pad(de, ((0, eb - ws[s]),)
                             + ((0, 0),) * (de.ndim - 1))
                dw = dw.at[j_x].set(de)
            xblock, ctblock, wblock = x_nxt, ct_nxt, w_nxt
        return dx[None], dw[None]

    bucket = P(axis, None, None)
    nspec = _node_spec(axis, xs.ndim)
    wspec = _node_spec(axis, w.ndim)
    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(nspec, nspec, wspec, bucket, bucket, bucket,
                            bucket, bucket, bucket),
                  out_specs=(nspec, wspec))
    dx, dw = f(xs, cts, w, pg.src_local, pg.dst_local, pg.mask,
               slT, dlT, mkT)
    return dx.reshape((S * rows,) + feat).astype(x.dtype), dw


def _ring_call(pg: PartitionedGraph, x, w, mesh, axis):
    """The raw differentiable ring (custom transposed-ring VJP)."""
    if mesh is None:
        @jax.custom_vjp
        def f(x, w):
            return _ring_fwd_emu(pg, x, w)

        f.defvjp(lambda x, w: (_ring_fwd_emu(pg, x, w), (x, w)),
                 lambda res, ct: _ring_bwd_emu(pg, *res, ct))
        return f(x, w)

    @jax.custom_vjp
    def f(x, w):
        return _ring_fwd_mesh(pg, mesh, axis, x, w)

    f.defvjp(lambda x, w: (_ring_fwd_mesh(pg, mesh, axis, x, w), (x, w)),
             lambda res, ct: _ring_bwd_mesh(pg, mesh, axis, *res, ct))
    return f(x, w)


def ring_gspmm(pg: PartitionedGraph, x: jnp.ndarray, w: jnp.ndarray, *,
               mesh: Optional[Mesh] = None, axis: str = "data",
               comm: str = "none", residual: Optional[jnp.ndarray] = None):
    """Sharded weighted CR-sum: ``out[v] = Σ_{e=(u→v)} w_e · x[u]``.

    ``x``: (n_pad, *feat) in padded layout (see
    :meth:`PartitionedGraph.scatter_nodes`); ``w``: bucketed weights
    (S, S, eb) scalar or (S, S, eb, H) per-head against (H, F) features
    (see :meth:`~PartitionedGraph.scatter_edges`; pass bucketed ones for
    plain CR-sum; fold 1/deg into ``w`` for mean). Returns (n_pad,
    *feat) destination sums. Differentiable w.r.t. both ``x`` and ``w``
    via the transposed ring; with ``mesh=None`` the same math (and the
    same custom VJP) runs emulated on one device.

    ``comm="int8"`` puts the cross-shard payload on the compressed wire
    (DESIGN.md §12): each source block is quantized ONCE at its owner —
    blockwise int8 + per-256-value fp32 scale, with the error-feedback
    ``residual`` (an (n_pad, *feat) fp32 array, required) folded in so
    compression stays unbiased across steps — and the quantized block
    is what circulates the ring. Owner-local (diagonal-bucket) edges
    read the RAW features; only remote consumers see the dequantized
    values. The straight-through estimator makes the wire transparent
    to autodiff. Returns ``(out, new_residual)``.
    """
    if comm not in COMM_MODES:
        raise ValueError(f"comm must be one of {COMM_MODES}: {comm!r}")
    if comm == "none":
        _count_exchange(pg, x, "none")
        return _ring_call(pg, x, w, mesh, axis)
    if residual is None:
        raise ValueError('comm="int8" needs the error-feedback residual '
                         "(init with jnp.zeros((n_pad, *feat), float32))")
    y, new_residual = compress_payload(x, residual)
    _count_exchange(pg, x, "int8")
    out = (local_gspmm(pg, x, w)
           + _ring_call(pg, y, offdiag_weights(pg, w), mesh, axis))
    return out, new_residual


def ring_reference(pg: PartitionedGraph, x: jnp.ndarray,
                   w: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-device oracle (same padded layout, plain loop, no VJP)."""
    if w is None:
        # fp32 weights even for bf16 x: the norm/one weights must not be
        # truncated to the feature dtype (the reduce casts at the end)
        w = jnp.where(pg.mask, 1.0, 0.0)
    return _ring_fwd_emu(pg, x, w)


# --------------------------------------------------------------------- #
# per-edge operand assembly + destination softmax (GAT support)
# --------------------------------------------------------------------- #
def _rev_fwd_emu(pg, el, er):
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = el.shape[1:]
    res_t = jnp.result_type(el, er)
    els = el.reshape((S, rows) + feat)
    ers = er.reshape((S, rows) + feat)
    out = []
    for i in range(S):
        row = []
        for j in range(S):
            wij = pg.bucket_width(i, j)
            if not wij:
                row.append(jnp.zeros((eb,) + feat, res_t))
                continue
            vals = (jnp.take(els[j], pg.src_local[i, j][:wij], axis=0)
                    + jnp.take(ers[i], pg.dst_local[i, j][:wij], axis=0))
            mk = pg.mask[i, j][:wij].reshape((wij,) + (1,) * len(feat))
            vals = jnp.where(mk, vals, jnp.zeros((), vals.dtype))
            row.append(jnp.pad(vals, ((0, eb - wij),)
                               + ((0, 0),) * len(feat)))
        out.append(jnp.stack(row))
    return jnp.stack(out)


def _rev_bwd_emu(pg, ct):
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    dtype = _acc_dtype(ct.dtype)
    feat = ct.shape[3:]
    dels, ders = [], []
    for j in range(S):
        dl_ = jnp.zeros((rows,) + feat, dtype)
        for i in range(S):
            wij = pg.bucket_width(i, j)
            if not wij:
                continue
            dl_ = _stage_reduce(ct[i, j][:wij], jnp.arange(wij),
                                pg.src_local[i, j][:wij],
                                pg.mask[i, j][:wij], None, dl_)
        dels.append(dl_)
    for i in range(S):
        dr = jnp.zeros((rows,) + feat, dtype)
        for j in range(S):
            wij = pg.bucket_width(i, j)
            if not wij:
                continue
            dr = _stage_reduce(ct[i, j][:wij], jnp.arange(wij),
                               pg.dst_local[i, j][:wij],
                               pg.mask[i, j][:wij], None, dr)
        ders.append(dr)
    d_el = jnp.stack(dels).reshape((S * rows,) + feat).astype(ct.dtype)
    d_er = jnp.stack(ders).reshape((S * rows,) + feat).astype(ct.dtype)
    return d_el, d_er


def _rev_fwd_mesh(pg, mesh, axis, el, er):
    from jax.experimental.shard_map import shard_map
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = el.shape[1:]
    els = el.reshape((S, rows) + feat)
    ers = er.reshape((S, rows) + feat)

    def local_fn(elb, erb, sl, dl, mk):
        me = jax.lax.axis_index(axis)
        block = elb[0]
        erloc = erb[0]
        sl, dl, mk = sl[0], dl[0], mk[0]
        acc = _maybe_pvary(jnp.zeros((S, eb) + feat, el.dtype), axis)

        def stage(s, carry):
            acc, block = carry
            shard = (me - s) % S
            nxt = jax.lax.ppermute(block, axis, _fwd_perm(S))
            sls = jnp.take(sl, shard, axis=0)
            dls = jnp.take(dl, shard, axis=0)
            mks = jnp.take(mk, shard, axis=0)
            vals = (jnp.take(block, sls, axis=0)
                    + jnp.take(erloc, dls, axis=0))
            mkr = mks.reshape((eb,) + (1,) * len(feat))
            acc = acc.at[shard].set(
                jnp.where(mkr, vals, jnp.zeros((), vals.dtype)))
            return acc, nxt

        acc, _ = jax.lax.fori_loop(0, S, stage, (acc, block))
        return acc[None]

    bucket = P(axis, None, None)
    nspec = _node_spec(axis, els.ndim)
    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(nspec, nspec, bucket, bucket, bucket),
                  out_specs=P(axis, *([None] * (2 + len(feat)))))
    return f(els, ers, pg.src_local, pg.dst_local, pg.mask)


def _rev_bwd_mesh(pg, mesh, axis, ct):
    from jax.experimental.shard_map import shard_map
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    dtype = _acc_dtype(ct.dtype)
    feat = ct.shape[3:]
    slT = jnp.swapaxes(pg.src_local, 0, 1)
    mkT = jnp.swapaxes(pg.mask, 0, 1)

    def local_fn(ctb, dl, mk, slt, mkt):
        me = jax.lax.axis_index(axis)
        ct_row = ctb[0]                     # (S, eb) + feat — my dst row
        dl, mk = dl[0], mk[0]
        slt, mkt = slt[0], mkt[0]
        # ∂er: fully local — every bucket of my dst row scatters home
        d_er = jnp.zeros((rows,) + feat, dtype)
        for j in range(S):      # static unroll: S is small
            d_er = _stage_reduce(ct_row[j], jnp.arange(eb), dl[j],
                                 mk[j], None, d_er)
        # ∂el: transposed ring — dst rows rotate backward, each device
        # scatters the bucket whose SOURCES it owns
        d_el = _maybe_pvary(jnp.zeros((rows,) + feat, dtype), axis)

        def stage(s, carry):
            d_el, block = carry
            i_ct = (me + s) % S
            nxt = jax.lax.ppermute(block, axis, _bwd_perm(S))
            d_el = _stage_reduce(jnp.take(block, me, axis=0),
                                 jnp.arange(eb),
                                 jnp.take(slt, i_ct, axis=0),
                                 jnp.take(mkt, i_ct, axis=0), None, d_el)
            return d_el, nxt

        d_el, _ = jax.lax.fori_loop(0, S, stage, (d_el, ct_row))
        return d_el.astype(ct.dtype)[None], d_er.astype(ct.dtype)[None]

    bucket = P(axis, None, None)
    cspec = P(axis, *([None] * (2 + len(feat))))
    nspec = P(axis, *([None] * (1 + len(feat))))
    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(cspec, bucket, bucket, bucket, bucket),
                  out_specs=(nspec, nspec))
    d_el, d_er = f(ct, pg.dst_local, pg.mask, slT, mkT)
    return (d_el.reshape((S * rows,) + feat),
            d_er.reshape((S * rows,) + feat))


def ring_edge_values(pg: PartitionedGraph, el: jnp.ndarray,
                     er: jnp.ndarray, *, mesh: Optional[Mesh] = None,
                     axis: str = "data") -> jnp.ndarray:
    """Bucketed per-edge sums ``el[src_e] + er[dst_e]`` — GAT's
    ``u_add_v_copy_e`` on shards.

    ``el``/``er``: (n_pad, *feat) padded node values. Returns
    (S, S, eb, *feat) bucketed edge values, 0 on pad slots. The VJP is
    local for ``er`` (every dst bucket lives with its owner) and a
    transposed ring for ``el``.
    """
    if mesh is None:
        @jax.custom_vjp
        def f(el, er):
            return _rev_fwd_emu(pg, el, er)

        f.defvjp(lambda el, er: (_rev_fwd_emu(pg, el, er), None),
                 lambda res, ct: _rev_bwd_emu(pg, ct))
        return f(el, er)

    @jax.custom_vjp
    def f(el, er):
        return _rev_fwd_mesh(pg, mesh, axis, el, er)

    f.defvjp(lambda el, er: (_rev_fwd_mesh(pg, mesh, axis, el, er), None),
             lambda res, ct: _rev_bwd_mesh(pg, mesh, axis, ct))
    return f(el, er)


def bucket_softmax(pg: PartitionedGraph, logits: jnp.ndarray
                   ) -> jnp.ndarray:
    """Destination softmax over bucketed edge logits (S, S, eb, *feat).

    Every bucket of dst-shard row ``i`` is owner-resident, so the
    softmax needs no communication of its own: under ``jit`` the global
    scatter/gather below stays shard-local (rows of ``gdst`` in block
    ``i`` index only shard ``i``'s padded rows). Pad slots come back 0.
    """
    S, rows, eb = pg.n_shards, pg.rows, pg.eb
    feat = logits.shape[3:]
    gdst = (jnp.arange(S, dtype=jnp.int32)[:, None, None] * rows
            + pg.dst_local)                              # (S, S, eb)
    gf = gdst.reshape(-1)
    flat = logits.reshape((S * S * eb,) + feat)
    mkf = pg.mask.reshape(-1)
    mkr = mkf.reshape((-1,) + (1,) * len(feat))
    neg = jnp.asarray(-jnp.inf, flat.dtype)
    masked = jnp.where(mkr, flat, neg)
    m = jnp.full((pg.n_pad,) + feat, neg, flat.dtype).at[gf].max(masked)
    m = jnp.where(jnp.isfinite(m), m, jnp.zeros((), flat.dtype))
    ex = jnp.exp(flat - jnp.take(m, gf, axis=0))
    ex = jnp.where(mkr, ex, jnp.zeros((), flat.dtype))
    z = jnp.zeros((pg.n_pad,) + feat, flat.dtype).at[gf].add(ex)
    alpha = ex / jnp.maximum(jnp.take(z, gf, axis=0), 1e-20)
    return alpha.reshape((S, S, eb) + feat)


# --------------------------------------------------------------------- #
# delayed halo (DistGNN-style staleness knob)
# --------------------------------------------------------------------- #
def local_gspmm(pg: PartitionedGraph, x: jnp.ndarray,
                w: jnp.ndarray) -> jnp.ndarray:
    """Owner-local part only: the diagonal (d, d) buckets — edges whose
    both endpoints live on one shard. No communication."""
    S, rows = pg.n_shards, pg.rows
    feat0 = x.shape[1:]
    w0 = _diag_widths(pg)[0]                 # max real diagonal width
    if not w0:
        return jnp.zeros((pg.n_pad,) + feat0, x.dtype)
    diag = jnp.arange(S)
    sl = pg.src_local[diag, diag][:, :w0]    # (S, w0)
    dl = pg.dst_local[diag, diag][:, :w0]
    mk = pg.mask[diag, diag][:, :w0]
    wd = w[diag, diag][:, :w0]               # (S, w0[, H])
    base = (jnp.arange(S, dtype=jnp.int32) * rows)[:, None]
    gsrc = (base + sl).reshape(-1)
    gdst = (base + dl).reshape(-1)
    feat = x.shape[1:]
    vals = jnp.take(x, gsrc, axis=0)         # (S*eb, *feat)
    wv = wd.reshape((-1,) + wd.shape[2:])
    wv = wv.reshape(wv.shape + (1,) * (vals.ndim - wv.ndim))
    mkr = mk.reshape((-1,) + (1,) * len(feat))
    vals = (vals * wv).astype(_acc_dtype(x.dtype))
    vals = jnp.where(mkr, vals, jnp.zeros((), vals.dtype))
    acc = jnp.zeros((pg.n_pad,) + feat, _acc_dtype(x.dtype))
    return acc.at[gdst].add(vals).astype(x.dtype)


def offdiag_weights(pg: PartitionedGraph, w: jnp.ndarray) -> jnp.ndarray:
    """Zero the diagonal buckets — the remote-only weight view."""
    S = pg.n_shards
    off = 1.0 - jnp.eye(S, dtype=w.dtype)
    return w * off.reshape((S, S) + (1,) * (w.ndim - 2))


def ring_gspmm_delayed(pg: PartitionedGraph, x: jnp.ndarray,
                       w: jnp.ndarray, stale: jnp.ndarray, refresh: bool,
                       *, mesh: Optional[Mesh] = None, axis: str = "data",
                       comm: str = "none",
                       residual: Optional[jnp.ndarray] = None):
    """Weighted CR with a delayed halo: ``out = local + remote`` where
    the remote partial (all cross-shard buckets) is recomputed only when
    ``refresh`` (a static Python bool) and otherwise reused from
    ``stale``. Gradients always flow through the local part; through
    the remote part only on refresh steps. Returns ``(out, remote)``
    with the returned remote detached — carry it as the next step's
    ``stale``. A refresh step is numerically exact.

    ``comm="int8"`` compresses the refresh exchange exactly like
    :func:`ring_gspmm` (requires ``residual``; the local part still
    reads raw features). Skipped-refresh steps move no bytes, so the
    residual passes through untouched. Returns
    ``(out, remote, new_residual)``.
    """
    if comm not in COMM_MODES:
        raise ValueError(f"comm must be one of {COMM_MODES}: {comm!r}")
    loc = local_gspmm(pg, x, w)
    if comm == "int8":
        if residual is None:
            raise ValueError('comm="int8" needs the error-feedback '
                             "residual")
        if refresh:
            y, residual = compress_payload(x, residual)
            _count_exchange(pg, x, "int8")
            remote = _ring_call(pg, y, offdiag_weights(pg, w), mesh, axis)
        else:
            remote = jax.lax.stop_gradient(stale)
        return loc + remote, jax.lax.stop_gradient(remote), residual
    if refresh:
        _count_exchange(pg, x, "none")
        remote = _ring_call(pg, x, offdiag_weights(pg, w), mesh, axis)
    else:
        remote = jax.lax.stop_gradient(stale)
    return loc + remote, jax.lax.stop_gradient(remote)

"""Unified execution-plan layer for the BR/CR lattice.

The paper's speedups come from *transparently* swapping aggregation
implementations (push → segment → blocked pull → fused kernels) under
one API — DGL users never pick a kernel, the framework does. This module
is that selection layer for the reproduction:

* :class:`GraphStats` — host-side statistics of a :class:`Graph`
  (edge count, degree moments, skew, ELL padding estimate) computed once
  per graph. They are plain Python numbers, so they travel through
  ``jit`` as *static* pytree aux data.
* :class:`PlanCache` — per-graph memoized packs (``ELLPack`` /
  ``TilePack`` / uniform ELL) plus the stats and any autotuned
  decisions. Keyed on the ``Graph`` object in a process-wide weak
  registry (:func:`get_plan_cache`), so each pack is built at most once
  per process per graph. Registered as a pytree: the pack arrays are
  children (traceable through ``jit``), the stats are static aux.
* :func:`plan_gspmm` — the planner proper: given a graph, a parsed
  ``BRSpec`` and operand shapes, it picks an execution strategy via an
  explicit cost model (see :func:`estimate_cost` and DESIGN.md §4), or
  measures candidates once and caches the winner when autotune mode is
  on. Pinned strategies that do not support a spec *fall back* down the
  chain ``pallas → onehot → ell → segment`` (with a one-time warning)
  instead of raising.

Every decision is recorded in a process-wide plan log
(:func:`plan_log`) so benchmarks can report which plan served each op.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
import warnings
import weakref
from collections import Counter
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .tiling import (ELLClass, ELLPack, TilePack, build_ell,
                     build_ell_ragged, build_ell_uniform, build_tiles)
from ..obs import events as _obs_events
from ..obs import metrics as _obs_metrics
from ..obs.events import drift_report, plan_events  # noqa: F401 (re-export)
from ..optim.compression import wire_bytes as _wire_bytes

__all__ = ["GraphStats", "PlanCache", "Plan", "get_plan_cache",
           "compute_stats", "estimate_cost", "ell_rowcomplete_padding",
           "plan_gspmm", "supports",
           "plan_log", "clear_plan_log", "last_plan", "pack_build_totals",
           "set_mode", "get_mode", "STRATEGIES", "FALLBACK_CHAIN",
           "block_stats", "plan_block_gspmm", "clear_block_plans",
           "plan_block_vjp", "block_bwd_supports",
           "BLOCK_BWD_STRATEGIES",
           "HETERO_STRATEGIES", "plan_hetero", "clear_hetero_plans",
           "SDDMM_STRATEGIES", "sddmm_supports", "plan_sddmm",
           "clear_sddmm_plans", "ATTN_STRATEGIES", "plan_attention",
           "SERVE_MODES", "plan_serve", "clear_serve_plans",
           "use_ring", "active_ring", "RingContext",
           "drift_report", "plan_events"]

STRATEGIES = ("push", "segment", "ell", "onehot", "pallas", "ring")

# Soft-fallback order for unsupported specs: most specialized first.
FALLBACK_CHAIN = ("pallas", "onehot", "ell", "segment")

# A pinned ring without a mesh degrades to its single-device analogue:
# each ring stage is one K-block, so blocked pull is the natural stand-in.
_RING_FALLBACK = ("ell", "segment")

# Strategies the auto mode considers (push is the pinned baseline only;
# ring only qualifies inside an active use_ring() context).
_AUTO_CANDIDATES = ("ring", "pallas", "onehot", "ell", "segment")

_DEFAULT_ELL_CAP = 64
_DEFAULT_TILE_GEOM = (128, 128, 256)  # (bm, bk, eb) — build_tiles defaults


def _is_traced(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


def graph_is_traced(g: Graph) -> bool:
    """True when ``g``'s index arrays are jit tracers (inside a trace)."""
    return _is_traced(g.src)


# --------------------------------------------------------------------- #
# graph statistics
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Static, hashable summary of a graph — the planner's features."""
    n_src: int
    n_dst: int
    n_edges: int
    avg_in_deg: float
    max_in_deg: int
    skew: float               # max_in_deg / avg_in_deg
    ell_padded_slots: int     # total (row, slot) cells of the bucketed ELL
    ell_n_classes: int        # number of distinct power-of-two widths
    pad_ratio: float          # ell_padded_slots / n_edges
    # row-complete RAGGED ELL (no row splitting; the fused-attention
    # megakernel's pack — build_ell_ragged). Defaults keep hand-built
    # stats (tests, block_stats) valid without the ragged histogram.
    ragged_padded_slots: int = 0
    ragged_n_classes: int = 0
    ragged_pad_ratio: float = 1.0


def _ell_padding(deg: np.ndarray, cap: int) -> Tuple[int, int]:
    """Padded-slot count + class count of the degree-bucketed ELL,
    estimated from the in-degree histogram without building the pack."""
    deg = deg[deg > 0]
    if deg.size == 0:
        return 0, 0
    full, rem = np.divmod(deg, cap)
    padded = int(full.sum()) * cap
    widths = set()
    rem = rem[rem > 0]
    if rem.size:
        w = np.where(rem > 1,
                     (2 ** np.ceil(np.log2(rem))).astype(np.int64),
                     np.int64(1))
        padded += int(w.sum())
        widths.update(int(x) for x in np.unique(w))
    if full.any():
        widths.add(cap)
    return padded, len(widths)


def ell_rowcomplete_padding(deg) -> Tuple[int, int]:
    """Padded-slot + class count of the ROW-COMPLETE ragged ELL
    (``build_ell_ragged``): every nonzero row padded to the next power
    of two of its own in-degree, no splitting. Estimated from the
    degree histogram without building the pack — the ONE formula shared
    by ``fused_attention``'s pallas gate and the planner's ragged
    attention cost row (a gate priced at ``max_degree × n_rows`` would
    veto the megakernel on exactly the power-law tails it now wins)."""
    deg = np.asarray(deg, dtype=np.int64)
    deg = deg[deg > 0]
    if deg.size == 0:
        return 0, 0
    w = np.where(deg > 1,
                 (2 ** np.ceil(np.log2(deg))).astype(np.int64),
                 np.int64(1))
    return int(w.sum()), int(np.unique(w).size)


def compute_stats(g: Graph, ell_cap: int = _DEFAULT_ELL_CAP) -> GraphStats:
    """Host-side stats; requires a concrete (non-traced) graph."""
    deg = np.asarray(g.in_degrees, dtype=np.int64)
    n_edges = int(g.n_edges)
    avg = n_edges / max(g.n_dst, 1)
    mx = int(deg.max()) if deg.size else 0
    padded, n_cls = _ell_padding(deg, ell_cap)
    rslots, rcls = ell_rowcomplete_padding(deg)
    return GraphStats(
        n_src=int(g.n_src), n_dst=int(g.n_dst), n_edges=n_edges,
        avg_in_deg=float(avg), max_in_deg=mx,
        skew=float(mx / max(avg, 1e-9)),
        ell_padded_slots=int(padded), ell_n_classes=int(n_cls),
        pad_ratio=float(padded / max(n_edges, 1)),
        ragged_padded_slots=int(rslots), ragged_n_classes=int(rcls),
        ragged_pad_ratio=float(rslots / max(n_edges, 1)))


# --------------------------------------------------------------------- #
# per-graph pack cache
# --------------------------------------------------------------------- #
_PACK_BUILDS: Counter = Counter()   # process-wide build counters (tests)


def pack_build_totals() -> Dict[str, int]:
    """How many packs of each kind were *built* (not reused) so far."""
    return dict(_PACK_BUILDS)


def _note_pack_build(kind: str) -> None:
    _PACK_BUILDS[kind] += 1
    _obs_metrics.counter(f"planner.pack_builds.{kind}").inc()


def _ell_pack_slots(pack: ELLPack) -> int:
    """Total padded (chunk, slot) cells of a built ELL pack."""
    return sum(int(c.chunk_mask.shape[0]) * int(c.width)
               for c in pack.classes)


def _note_pad_ratio(kind: str, slots: int, n_edges: int) -> None:
    """``planner.pad_ratio.<kind>`` gauge: padded slots per real edge of
    the most recently built pack of this kind — the pad-tax trajectory
    every BENCH_*.json embeds via its metrics snapshot."""
    _obs_metrics.gauge(f"planner.pad_ratio.{kind}").set(
        slots / max(int(n_edges), 1))


@jax.tree_util.register_pytree_node_class
class PlanCache:
    """Lazily-built, memoized packs + stats for one :class:`Graph`.

    Pack arrays are pytree children so a cache carried by a model bundle
    flows through ``jit``; the stats are static aux, which lets the
    planner run its full cost model inside a trace. Building only
    happens on the concrete (host) side — inside a trace, a pack that
    was never built is simply unavailable and the planner plans around
    it.
    """

    def __init__(self, ell: Optional[ELLPack] = None,
                 tiles: Optional[TilePack] = None,
                 stats: Optional[GraphStats] = None,
                 graph: Optional[Graph] = None,
                 ell_cap: int = _DEFAULT_ELL_CAP,
                 krel: Optional[Any] = None):
        self._ell = ell
        self._tiles = tiles
        self._krel = krel       # K-relation RelGraph (hetero, DESIGN §8)
        self.stats = stats
        self.ell_cap = ell_cap
        self._gref = weakref.ref(graph) if graph is not None else None
        # host-side keyed memos (not part of the pytree)
        self._ell_by_cap: Dict[int, ELLPack] = {}
        self._tiles_by_geom: Dict[Tuple[int, int, int], TilePack] = {}
        self._uniform: Dict[int, ELLClass] = {}
        self._ragged: Optional[ELLPack] = None
        self._autotuned: Dict[Tuple, str] = {}
        self._partitions: Dict[Tuple[int, str], Any] = {}

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return ((self._ell, self._tiles, self._krel),
                (self.stats, self.ell_cap))

    @classmethod
    def tree_unflatten(cls, aux, children):
        ell, tiles, krel = children
        return cls(ell=ell, tiles=tiles, krel=krel, stats=aux[0],
                   ell_cap=aux[1])

    # -- pack access -----------------------------------------------------
    def _graph(self) -> Optional[Graph]:
        g = self._gref() if self._gref is not None else None
        if g is None or graph_is_traced(g):
            return None
        return g

    def peek(self, kind: str):
        """Return an already-built pack or None (never builds)."""
        return {"ell": self._ell, "tiles": self._tiles,
                "ell_ragged": self._ragged}[kind]

    def set_ell_cap(self, cap: int) -> None:
        """Change the default ELL width cap. Re-slots any pack built at
        the old cap into the keyed memo (never hands out a pack with
        the wrong blocking) and recomputes the padding stats so the
        cost model describes the cap actually in use."""
        if cap == self.ell_cap:
            return
        if self._ell is not None:
            self._ell_by_cap[self.ell_cap] = self._ell
            self._ell = self._ell_by_cap.pop(cap, None)
        self.ell_cap = cap
        g = self._graph()
        if g is not None:
            self.stats = compute_stats(g, cap)

    def ell(self, width_cap: Optional[int] = None) -> Optional[ELLPack]:
        cap = self.ell_cap if width_cap is None else width_cap
        if cap == self.ell_cap:
            if self._ell is None:
                g = self._graph()
                if g is None:
                    return None
                self._ell = build_ell(g, cap)
                _note_pack_build("ell")
                _note_pad_ratio("ell", _ell_pack_slots(self._ell),
                                g.n_edges)
            return self._ell
        if cap not in self._ell_by_cap:
            g = self._graph()
            if g is None:
                return None
            self._ell_by_cap[cap] = build_ell(g, cap)
            _note_pack_build("ell")
            _note_pad_ratio("ell", _ell_pack_slots(self._ell_by_cap[cap]),
                            g.n_edges)
        return self._ell_by_cap[cap]

    def tiles(self, bm: int = 128, bk: int = 128, eb: int = 256
              ) -> Optional[TilePack]:
        geom = (bm, bk, eb)
        if geom == _DEFAULT_TILE_GEOM:
            if self._tiles is None:
                g = self._graph()
                if g is None:
                    return None
                self._tiles = build_tiles(g, bm, bk, eb)
                _note_pack_build("tiles")
            return self._tiles
        if geom not in self._tiles_by_geom:
            g = self._graph()
            if g is None:
                return None
            self._tiles_by_geom[geom] = build_tiles(g, bm, bk, eb)
            _note_pack_build("tiles")
        return self._tiles_by_geom[geom]

    def ell_uniform(self, width: int) -> Optional[ELLClass]:
        if width not in self._uniform:
            g = self._graph()
            if g is None:
                return None
            self._uniform[width] = build_ell_uniform(g, width)
            _note_pack_build("ell_uniform")
            cls = self._uniform[width]
            _note_pad_ratio("ell_uniform",
                            int(cls.chunk_mask.shape[0]) * int(cls.width),
                            g.n_edges)
        return self._uniform[width]

    def ell_ragged(self) -> Optional[ELLPack]:
        """Row-complete RAGGED ELL (``build_ell_ragged``): whole rows,
        per-power-of-two class widths — the fused-attention megakernel's
        power-law pack. Host-side memo like :meth:`ell_uniform` (never
        builds inside a trace)."""
        if self._ragged is None:
            g = self._graph()
            if g is None:
                return None
            self._ragged = build_ell_ragged(g)
            _note_pack_build("ell_ragged")
            _note_pad_ratio("ell_ragged", _ell_pack_slots(self._ragged),
                            g.n_edges)
        return self._ragged

    def partition(self, n_shards: int, mode: str = "contiguous"):
        """Memoized :class:`~repro.core.partition.PartitionedGraph` for
        ``(n_shards, mode)`` — the ring strategy's pack. Host-side only
        (a traced graph can't be partitioned); one build per process
        per configuration, shared by direct gspmm calls, partitioned
        model bundles and the benchmarks.

        Like the keyed ``_ell_by_cap``/``_tiles_by_geom`` memos (and
        unlike the default-geometry ell/tiles slots), partitions are
        NOT pytree children — the dict's structure varies per build, so
        a cache that crosses a jit boundary arrives without them and
        ring never qualifies inside a trace. Partitioned *training*
        does not route through gspmm's planner at all: it carries the
        ``PartitionedGraph`` itself through jit (models/gnn/train.py).
        """
        key = (int(n_shards), mode)
        if key not in self._partitions:
            g = self._graph()
            if g is None:
                return None
            from .partition import build_partition  # local: avoids cycle
            pg = build_partition(g, n_shards, mode)
            self._partitions[key] = pg
            _note_pack_build("partition")
            st = pg.stats
            _note_pad_ratio("partition",
                            st.n_shards * st.n_shards * st.eb, st.n_edges)
            _note_pad_ratio("partition_ragged", st.ragged_slots,
                            st.n_edges)
        return self._partitions[key]

    def peek_partition(self, n_shards: int, mode: str = "contiguous"):
        return self._partitions.get((int(n_shards), mode))

    def krel(self, n_rel: int):
        """Memoized K-relation :class:`~repro.core.hetero.RelGraph` of
        this graph: the edge set replicated once per relation (MoNet's
        per-kernel aggregation — DESIGN.md §8). A pytree child, so a
        bundle-carried cache serves the fused path inside jitted train
        steps; host-side build only, like every other pack."""
        if self._krel is not None and self._krel.n_rel == int(n_rel):
            return self._krel
        g = self._graph()
        if g is None or not jax.core.trace_state_clean():
            # never build under an active trace — np→jnp conversions
            # there leak trace-bound arrays into the process-wide cache
            return None
        from .hetero import caller_coo, from_rels  # local: avoids cycle
        src, dst = caller_coo(g)
        self._krel = from_rels([(src, dst)] * int(n_rel),
                               n_src=g.n_src, n_dst=g.n_dst)
        _note_pack_build("krel")
        return self._krel

    # -- planning helpers -------------------------------------------------
    def prefers_ell(self, d: int) -> bool:
        """True when the cost model ranks blocked pull above segment."""
        if self.stats is None:
            return False
        return (estimate_cost("ell", self.stats, d)
                < estimate_cost("segment", self.stats, d))


_CACHES: "weakref.WeakKeyDictionary[Graph, PlanCache]" = \
    weakref.WeakKeyDictionary()


def get_plan_cache(g: Graph) -> PlanCache:
    """Process-wide cache registry: one :class:`PlanCache` per graph."""
    if graph_is_traced(g):
        raise ValueError("get_plan_cache needs a concrete Graph; inside "
                         "jit, pass the cache in explicitly")
    cache = _CACHES.get(g)
    if cache is None:
        cache = PlanCache(stats=compute_stats(g), graph=g)
        _CACHES[g] = cache
    return cache


# --------------------------------------------------------------------- #
# cost model (explicit — see DESIGN.md §4)
# --------------------------------------------------------------------- #
# Relative cost per effective element-op (lower = faster). The numbers
# encode the paper's qualitative ordering, not absolute hardware rates:
# scatter (push) serializes, segment reduce is the vendor baseline,
# blocked pull streams densely, and the MXU formulations only pay off on
# a real TPU (on CPU the Pallas kernels run in interpret mode).
_THROUGHPUT = {
    "cpu": {"push": 6.0, "segment": 1.0, "ell": 0.35,
            "onehot": 64.0, "pallas": 512.0, "ring": 0.5},
    "tpu": {"push": 8.0, "segment": 1.5, "ell": 0.8,
            "onehot": 0.5, "pallas": 0.25, "ring": 0.6},
    # Half precision shifts the table unevenly: the streaming forms
    # (blocked pull, ring stages, segment reduce) are memory-bound, so
    # halving the element footprint buys them more than the
    # scatter/dispatch-bound paths — the ell/segment break-even moves
    # from pad_ratio ≈ 2.9 to ≈ 3.9 at bf16 (DESIGN.md §12).
    "cpu:bf16": {"push": 5.5, "segment": 0.85, "ell": 0.22,
                 "onehot": 64.0, "pallas": 512.0, "ring": 0.35},
    "tpu:bf16": {"push": 7.0, "segment": 1.1, "ell": 0.5,
                 "onehot": 0.35, "pallas": 0.15, "ring": 0.4},
}
# Fixed per-call overhead (dispatch + padding setup), in element-ops.
_FIXED = {"push": 0.0, "segment": 0.0, "ell": 2e4,
          "onehot": 5e4, "pallas": 5e4, "ring": 1e5}
_ELL_CLASS_OVERHEAD = 1.5e3     # per degree class: one segment combine
_TILE_EDGE_BUDGET = 256         # eb — edge slots per tile bucket
_RING_COMM = 0.3   # per fp32-equivalent element moved per ring stage
_RING_DEFAULT_SHARDS = 8        # nominal S when no ring context is live


def _throughput_row(backend: Optional[str], dtype) -> Dict[str, float]:
    """Backend throughput row, refined by element dtype when a
    half-precision row exists (``"<backend>:bf16"``)."""
    backend = backend or jax.default_backend()
    if dtype is not None and jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16):
        row = _THROUGHPUT.get(f"{backend}:bf16")
        if row is None:
            row = _THROUGHPUT.get("cpu:bf16")
        return row
    return _THROUGHPUT.get(backend, _THROUGHPUT["cpu"])


def estimate_cost(strategy: str, stats: GraphStats, d: int,
                  backend: Optional[str] = None,
                  ring_stats=None, dtype=None,
                  comm: Optional[str] = None) -> float:
    """Estimated execution cost of one gspmm call, in element-ops.

    ``ring_stats`` (a :class:`~repro.core.partition.PartitionStats`)
    refines the ``ring`` estimate with the real bucket padding; without
    it the estimate assumes ideal balance over the active (or nominal)
    shard count. ``dtype`` (operand element type, default fp32) selects
    the per-precision throughput row and sizes the ring's communication
    term in bytes; ``comm`` ("none"/"int8", default the active ring
    context's wire mode) prices that term at the compressed payload —
    so auto can flip toward ``ring`` exactly when compression makes the
    exchange cheap enough.
    """
    tp = _throughput_row(backend, dtype)[strategy]
    dd = max(int(d), 1)
    if strategy in ("push", "segment"):
        work = stats.n_edges * dd
    elif strategy == "ell":
        work = stats.ell_padded_slots * dd
    elif strategy == "ring":
        # per-device slot work + per-stage ppermute traffic: the ring
        # wins when the parallel split beats the communication tax —
        # i.e. on big graphs with enough shards (graph size × S).
        ctx = active_ring()
        if ring_stats is not None:
            S = ring_stats.n_shards
            rows = ring_stats.rows_per_shard
            # per-device slot work: ragged per-bucket widths when the
            # partition carries them (slots = S · Σ_s w_s, the per-stage
            # diagonal maxima), else the dense S²·eb envelope — the two
            # coincide exactly when every bucket fills to eb
            slots = ring_stats.ragged_slots
            if slots <= 0:
                slots = S * S * ring_stats.eb
            work = (slots / S) * dd
            stages = ring_stats.ragged_stages
            if stages < 0:
                stages = S - 1
        else:
            S = ctx.n_shards if ctx is not None else _RING_DEFAULT_SHARDS
            rows = -(-max(stats.n_dst, 1) // S)
            work = (stats.n_edges / S) * dd          # ideal balance
            stages = S - 1
        if comm is None:
            comm = ctx.comm if ctx is not None else "none"
        itemsize = jnp.dtype(dtype or jnp.float32).itemsize
        _, wire = _wire_bytes(rows * dd, itemsize, comm)
        # _RING_COMM is calibrated per fp32 element — normalize the
        # wire payload back to fp32-equivalent elements. Ragged buckets
        # also truncate the ring: stages whose whole diagonal is empty
        # are never exchanged, so the comm term scales with the real
        # stage count, not S-1.
        comm_cost = _RING_COMM * stages * (wire / 4.0)
        return tp * work + comm_cost + _FIXED[strategy]
    else:  # onehot / pallas: padded tile-bucket slots (lower bound on T)
        n_buckets = max(1, -(-stats.n_edges // _TILE_EDGE_BUDGET))
        work = n_buckets * _TILE_EDGE_BUDGET * dd
    cost = tp * work + _FIXED[strategy]
    if strategy == "ell":
        cost += _ELL_CLASS_OVERHEAD * stats.ell_n_classes
    return cost


# --------------------------------------------------------------------- #
# ring (partitioned) execution context
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RingContext:
    """An installed device mesh makes ``ring`` a planner candidate.

    ``comm`` declares the cross-shard wire mode ("none"/"int8") so the
    cost model prices the exchange at the payload that actually moves.
    """
    mesh: Any               # jax.sharding.Mesh
    axis: str = "data"
    mode: str = "contiguous"
    comm: str = "none"

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])


_RING_CTX: Optional[RingContext] = None


def active_ring() -> Optional[RingContext]:
    return _RING_CTX


@contextlib.contextmanager
def use_ring(mesh, axis: str = "data", mode: str = "contiguous",
             comm: str = "none"):
    """Enable partitioned (ring) execution for ``gspmm`` while active.

    Without an active context — or when the mesh is gone — ``ring``
    never qualifies: ``strategy="auto"`` plans single-device and a
    pinned ``"ring"`` falls back down the established chain.
    """
    global _RING_CTX
    prev = _RING_CTX
    _RING_CTX = RingContext(mesh=mesh, axis=axis, mode=mode, comm=comm)
    try:
        yield _RING_CTX
    finally:
        _RING_CTX = prev


# --------------------------------------------------------------------- #
# spec support predicates
# --------------------------------------------------------------------- #
# Binary ops the fused Pallas BR kernel implements (kernels/binary_reduce).
_PALLAS_BINOPS = ("add", "sub", "mul", "div")


def supports(strategy: str, spec, lhs_data, rhs_data) -> bool:
    """Can ``strategy`` execute this node-output spec at all?

    ``spec`` is a parsed ``BRSpec`` (duck-typed to avoid a circular
    import). Edge-output specs are planned separately — gspmm delegates
    them to ``gsddmm``, whose strategies live in :func:`plan_sddmm`.
    """
    red = spec.reduce
    if strategy in ("push", "segment"):
        return spec.out in ("u", "v") and red != "none"
    if spec.out != "v" or red == "none":
        return False
    if strategy == "ell":
        return True     # any ⊗, any operand targets, all reducers
    if strategy == "ring":
        # sharded weighted CR: source-node lhs, sum/mean, rank-2, plain
        # copy or a scalar edge weight (mean folds 1/deg into it)
        if red not in ("sum", "mean") or spec.lhs != "u":
            return False
        if lhs_data.ndim != 2:
            return False
        if spec.op == "copy":
            return True
        return (spec.op == "mul" and spec.rhs == "e"
                and rhs_data.ndim == 2 and rhs_data.shape[-1] == 1)
    # MXU formulations: rank-2 operands only, sum/mean only
    rank_ok = (lhs_data.ndim == 2
               and (rhs_data is None or rhs_data.ndim == 2))
    if not rank_ok or red not in ("sum", "mean"):
        return False
    if strategy == "onehot":
        if spec.lhs != "u":
            return False
        if spec.op == "copy":
            return True
        return (spec.op == "mul" and spec.rhs == "e"
                and rhs_data.shape[-1] == 1)
    if strategy == "pallas":
        if spec.op == "copy" and spec.lhs in ("u", "e"):
            return True
        if (spec.lhs == "u" and spec.rhs == "e"
                and spec.op in _PALLAS_BINOPS):
            return True
        return (spec.lhs == "e" and spec.rhs == "u"
                and spec.op in ("add", "mul"))
    raise ValueError(f"unknown strategy {strategy!r}")


# --------------------------------------------------------------------- #
# plan log + fallback warnings
# --------------------------------------------------------------------- #
_PLAN_LOG: Dict[Tuple[str, str], Counter] = {}
_LAST_PLAN: Dict[Tuple[str, str], str] = {}
_WARNED: set = set()


def _record(spec_name: str, requested: str, chosen: str,
            predicted: Optional[float] = None,
            dtype: Optional[str] = None) -> None:
    key = (spec_name, requested)
    _PLAN_LOG.setdefault(key, Counter())[chosen] += 1
    _LAST_PLAN[key] = chosen
    _obs_events.plan_event(spec_name, requested, chosen,
                           predicted_cost=predicted, dtype=dtype)


def plan_log() -> Dict[Tuple[str, str], Dict[str, int]]:
    """(op name, requested strategy) -> {chosen strategy: count}."""
    return {k: dict(v) for k, v in _PLAN_LOG.items()}


def clear_plan_log() -> None:
    _PLAN_LOG.clear()
    _LAST_PLAN.clear()


def last_plan(spec_name: str, requested: str = "auto") -> Optional[str]:
    """Most-recently chosen strategy for (op, requested), or None."""
    return _LAST_PLAN.get((spec_name, requested))


def _warn_fallback(spec_name: str, requested: str, chosen: str) -> None:
    key = (spec_name, requested)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(f"strategy {requested!r} does not support {spec_name!r}"
                  f"; falling back to {chosen!r}", stacklevel=3)


# --------------------------------------------------------------------- #
# planner mode (cost model vs measure-and-cache autotune)
# --------------------------------------------------------------------- #
_MODE = os.environ.get("REPRO_PLANNER_MODE", "cost")


def set_mode(mode: str) -> None:
    """'cost' (default) or 'autotune' (measure candidates once, cache)."""
    global _MODE
    if mode not in ("cost", "autotune"):
        raise ValueError(f"unknown planner mode {mode!r}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def _measure(runner: Callable[[str], Any], strategy: str) -> float:
    jax.block_until_ready(runner(strategy))     # warmup/compile
    t0 = time.perf_counter()
    jax.block_until_ready(runner(strategy))
    return time.perf_counter() - t0


# --------------------------------------------------------------------- #
# the planner
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Plan:
    """Resolved execution plan for one gspmm call."""
    strategy: str
    requested: str
    reason: str                     # 'pinned' | 'cost' | 'autotune' | ...
    ell: Optional[ELLPack] = None
    tiles: Optional[TilePack] = None
    partition: Optional[Any] = None   # PartitionedGraph for 'ring'


def plan_gspmm(g: Graph, spec, lhs_data, rhs_data, *,
               requested: str = "auto",
               cache: Optional[PlanCache] = None,
               ell: Optional[ELLPack] = None,
               tiles: Optional[TilePack] = None,
               runner: Optional[Callable[[str], Any]] = None) -> Plan:
    """Pick the execution strategy (and packs) for one node-output BR.

    ``requested='auto'`` consults the cost model (or the autotune cache);
    an explicitly pinned strategy is honored when it supports the spec
    and falls back down :data:`FALLBACK_CHAIN` otherwise. ``runner``
    (optional) executes the call with a pinned strategy — used by
    autotune mode to measure candidates.
    """
    concrete = not graph_is_traced(g)
    if cache is None and concrete:
        cache = get_plan_cache(g)
    stats = cache.stats if cache is not None else None

    def pack_available(strategy: str) -> bool:
        if strategy in ("push", "segment"):
            return True
        if strategy == "ring":
            # needs a live mesh, one shared vertex space, and a
            # host-buildable partition (ring packs never build in-trace)
            ctx = active_ring()
            if ctx is None or stats is None:
                return False
            if stats.n_src != stats.n_dst:
                return False
            if cache is not None and cache.peek_partition(
                    ctx.n_shards, ctx.mode) is not None:
                return True
            return concrete and cache is not None
        kind = "ell" if strategy == "ell" else "tiles"
        explicit = ell if kind == "ell" else tiles
        if explicit is not None:
            return True
        if cache is not None and cache.peek(kind) is not None:
            return True
        # buildable on the host side only
        return concrete and cache is not None

    def ok(strategy: str) -> bool:
        return (supports(strategy, spec, lhs_data, rhs_data)
                and pack_available(strategy))

    if requested == "auto":
        chosen, reason = _plan_auto(spec, lhs_data, rhs_data, stats, ok,
                                    cache, runner, concrete)
    else:
        if requested not in STRATEGIES:
            raise ValueError(f"unknown strategy {requested!r}; expected "
                             f"one of {STRATEGIES + ('auto',)}")
        if ok(requested):
            chosen, reason = requested, "pinned"
        else:
            if requested == "ring":
                chain = _RING_FALLBACK
            elif requested in FALLBACK_CHAIN:
                chain = FALLBACK_CHAIN[FALLBACK_CHAIN.index(requested) + 1:]
            else:
                chain = ("segment",)
            chosen = next((s for s in chain if ok(s)), "segment")
            reason = f"fallback({requested})"
            _warn_fallback(spec.name, requested, chosen)

    plan = Plan(strategy=chosen, requested=requested, reason=reason)
    if chosen == "ell":
        plan.ell = ell if ell is not None else cache.ell()
    elif chosen in ("onehot", "pallas"):
        plan.tiles = tiles if tiles is not None else cache.tiles()
    elif chosen == "ring":
        ctx = active_ring()
        plan.partition = cache.partition(ctx.n_shards, ctx.mode)
    predicted = None
    if _obs_events.enabled() and stats is not None:
        d = int(np.prod(lhs_data.shape[1:])) if lhs_data.ndim > 1 else 1
        if chosen == "ring":
            ctx = active_ring()
            pgp = (cache.peek_partition(ctx.n_shards, ctx.mode)
                   if ctx is not None and cache is not None else None)
            predicted = estimate_cost(chosen, stats, d,
                                      ring_stats=None if pgp is None
                                      else pgp.stats,
                                      dtype=lhs_data.dtype)
        else:
            predicted = estimate_cost(chosen, stats, d,
                                      dtype=lhs_data.dtype)
    _record(spec.name, requested, chosen, predicted,
            dtype=str(lhs_data.dtype))
    return plan


def _plan_auto(spec, lhs_data, rhs_data, stats, ok, cache, runner,
               concrete) -> Tuple[str, str]:
    if stats is None:
        # traced graph with no cache: only static sizes are known, and
        # no pack can be built — segment is always valid and collision-free
        return "segment", "no-stats(traced)"
    d = int(np.prod(lhs_data.shape[1:])) if lhs_data.ndim > 1 else 1
    candidates = [s for s in _AUTO_CANDIDATES if ok(s)]
    if not candidates:           # out == 'u' etc. → segment path
        return "segment", "only-generic"
    operands_concrete = (not _is_traced(lhs_data)
                         and not _is_traced(rhs_data)
                         if rhs_data is not None else
                         not _is_traced(lhs_data))
    if (_MODE == "autotune" and concrete and operands_concrete
            and runner is not None and cache is not None):
        ring_ctx = active_ring()
        # the ring context is part of the key: a winner measured inside
        # use_ring() must not be replayed once the mesh is gone
        key = (spec.name, d, str(lhs_data.dtype),
               None if rhs_data is None else rhs_data.shape[-1],
               None if ring_ctx is None
               else (ring_ctx.n_shards, ring_ctx.axis, ring_ctx.mode))
        winner = cache._autotuned.get(key)
        if winner is None or winner not in candidates:
            times = {s: _measure(runner, s) for s in candidates}
            winner = min(times, key=times.get)
            _obs_events.measured_event(spec.name, times[winner])
            cache._autotuned[key] = winner
        return winner, "autotune"
    ctx = active_ring()

    def cost(s):
        if s == "ring" and ctx is not None and cache is not None:
            pgp = cache.peek_partition(ctx.n_shards, ctx.mode)
            return estimate_cost(s, stats, d,
                                 ring_stats=None if pgp is None
                                 else pgp.stats, dtype=lhs_data.dtype)
        return estimate_cost(s, stats, d, dtype=lhs_data.dtype)

    chosen = min(candidates, key=cost)
    return chosen, "cost"


# --------------------------------------------------------------------- #
# block (sampled-minibatch) planning — shape-keyed, trace-safe
# --------------------------------------------------------------------- #
# Sampled blocks are padded to static shapes, so their planner features
# depend only on the shape signature (n_src_pad, n_dst_real, n_edges_pad,
# fanout) — not on the particular batch. Decisions are memoized on that
# signature (plus op/width/backend), which makes planning deterministic
# across batches and safe inside a jitted train step: the same compiled
# step serves every minibatch of a sampler configuration.
_BLOCK_PLANS: Dict[Tuple, str] = {}

# Candidates for auto mode on blocks. The uniform pull reuses the 'ell'
# cost entry (it IS a single-class ELL); onehot/pallas need host-built
# tile packs that cannot be rebuilt per batch, so they never qualify.
_BLOCK_AUTO_CANDIDATES = ("ell", "segment")
_BLOCK_FALLBACK = ("ell", "segment")


def block_stats(n_src: int, n_dst_real: int, n_edges: int,
                fanout: int) -> GraphStats:
    """Nominal :class:`GraphStats` of a padded block.

    Every real destination row holds at most ``fanout`` sampled in-edges
    and the neighbor table pads all rows TO ``fanout`` — so the block is
    a uniform single-class ELL by construction: max degree == avg degree
    == fanout, one width class, ``n_dst_real * fanout`` padded slots.
    """
    slots = n_dst_real * fanout
    return GraphStats(
        n_src=int(n_src), n_dst=int(n_dst_real), n_edges=int(n_edges),
        avg_in_deg=float(fanout), max_in_deg=int(fanout), skew=1.0,
        ell_padded_slots=int(slots), ell_n_classes=1,
        pad_ratio=float(slots / max(n_edges, 1)))


def clear_block_plans() -> None:
    _BLOCK_PLANS.clear()
    _BLOCK_BWD_PLANS.clear()


def plan_block_gspmm(signature: Tuple[int, int, int, int], spec, d: int,
                     requested: str = "auto",
                     runner: Optional[Callable[[str], Any]] = None,
                     dtype: Optional[str] = None) -> str:
    """Pick the execution strategy for one block aggregation.

    ``signature`` is :attr:`BlockGraph.signature` — static padded shapes
    only, so this function never touches traced values. The chosen
    strategy is memoized per (signature, op, width, requested, backend)
    and recorded in the plan log under ``block:<op>``.

    In autotune mode (``REPRO_PLANNER_MODE=autotune`` / ``set_mode``),
    ``runner`` — supplied by :func:`~repro.core.blocks.block_gspmm`
    only on *eager* calls with concrete operands — measures the
    candidates once per signature and the winner serves every later
    batch of that configuration, including calls inside the jitted
    train step (same key, already memoized; a traced call with no
    cached decision falls back to the cost model — measuring inside a
    trace is impossible).
    """
    from .blocks import block_supports  # local: blocks imports planner

    backend = jax.default_backend()
    key = (signature, spec.name, int(d), requested, backend, dtype)
    log_name = f"block:{spec.name}"
    chosen = _BLOCK_PLANS.get(key)
    if chosen is None:
        memoize = True
        if requested == "auto":
            candidates = [s for s in _BLOCK_AUTO_CANDIDATES
                          if block_supports(s, spec)]
            if not candidates:
                chosen = "segment"
            elif _MODE == "autotune" and runner is not None:
                times = {s: _measure(runner, s) for s in candidates}
                chosen = min(times, key=times.get)
                _obs_events.measured_event(log_name, times[chosen])
            else:
                stats = block_stats(*signature)
                chosen = min(candidates,
                             key=lambda s: estimate_cost(s, stats, d,
                                                         backend=backend,
                                                         dtype=dtype))
                # in autotune mode a traced call (no runner) can't
                # measure — don't pin its cost-model stand-in, so a
                # later EAGER call of the same signature still gets to
                # autotune (the cost model is deterministic, so the
                # un-memoized answer is stable across traces)
                memoize = _MODE != "autotune"
        elif requested not in STRATEGIES:
            raise ValueError(f"unknown strategy {requested!r}; expected "
                             f"one of {STRATEGIES + ('auto',)}")
        elif block_supports(requested, spec):
            chosen = requested
        else:
            chosen = next((s for s in _BLOCK_FALLBACK
                           if block_supports(s, spec)), "segment")
            _warn_fallback(log_name, requested, chosen)
        if memoize:
            _BLOCK_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled() and chosen in ("push", "segment", "ell"):
        predicted = estimate_cost(chosen, block_stats(*signature), d,
                                  backend=backend, dtype=dtype)
    _record(log_name, requested, chosen, predicted, dtype=dtype)
    return chosen


# --------------------------------------------------------------------- #
# block BACKWARD planning — the reverse-table VJP vs autodiff scatter
# --------------------------------------------------------------------- #
# Autodiff of any forward block strategy computes ∂x with a scatter-add
# (the push pathology, paper §4). 'gather' is the reverse-block custom
# VJP (core/blocks.py): cotangents pulled over the sampler's src-sorted
# reverse table + one sorted segment reduce. For max/min the forward
# records an arg-extrema table on the neighbor grid and the pull masks
# cotangents to the winning slot — same reverse table, one extra
# comparison. 'scatter' is plain autodiff — the baseline, and the only
# option for prod (no scatter transpose at all). Decisions are memoized
# per shape signature
# exactly like the forward block plans and logged as ``block_bwd:<op>``,
# so forward and backward strategies are chosen independently.
BLOCK_BWD_STRATEGIES = ("gather", "scatter")

_BLOCK_BWD_PLANS: Dict[Tuple, str] = {}

# Collision/row-density term of the backward cost rows (ROADMAP PR-4
# follow-up). A scatter-add only serializes where updates collide; on
# small blocks the ∂x working set sits in cache and gather/scatter
# measure near parity (slightly pro-scatter), so the push-rate penalty
# is scaled by the block's row density AND by how much of the
# full-serialization edge-slot scale it reaches — below ~100k edge
# slots the tax vanishes and scatter's lack of reorder work wins. The
# gather path pays its reorder tax (reverse-table gather + permuted
# cotangent reads) unconditionally. Autotune mode still measures the
# truth per signature.
_BWD_COLLISION_SLOTS = 1_000_000   # full-serialization edge-slot scale
_BWD_GATHER_REORDER = 0.45         # gather's extra work vs one segment pass


def _block_bwd_cost(strategy: str, signature: Tuple[int, int, int, int],
                    d: int, backend: str) -> float:
    """Estimated cost of differentiating one block op (element-ops)."""
    n_src, _, slots, _ = signature
    tp = _THROUGHPUT.get(backend, _THROUGHPUT["cpu"])
    dd = max(int(d), 1)
    if strategy == "gather":
        return tp["segment"] * (1.0 + _BWD_GATHER_REORDER) * slots * dd
    rho = min(1.0, slots / max(n_src, 1))
    size = min(1.0, slots / _BWD_COLLISION_SLOTS)
    scatter_tp = tp["segment"] + (tp["push"] - tp["segment"]) * rho * size
    return scatter_tp * slots * dd


def block_bwd_supports(strategy: str, spec) -> bool:
    """Can ``strategy`` differentiate this block spec?

    'scatter' (autodiff) always can. 'gather' needs a node output and a
    sum/mean/max/min reducer: the reverse-table pull is the exact
    adjoint of the linear reducers, and the extrema reducers ride the
    same pull with cotangents masked to the recorded arg-extremum slot.
    Only prod stays on autodiff.
    """
    if strategy == "scatter":
        return True
    if strategy == "gather":
        return spec.out == "v" and spec.reduce in ("sum", "mean",
                                                   "max", "min")
    raise ValueError(f"unknown block backward strategy {strategy!r}")


def plan_block_vjp(signature: Tuple[int, int, int, int], spec, d: int,
                   requested: str = "auto", gather_available: bool = True,
                   runner: Optional[Callable[[str], Any]] = None,
                   dtype: Optional[str] = None) -> str:
    """Pick the backward (differentiation) strategy for one block op.

    Shape-keyed and memoized exactly like :func:`plan_block_gspmm`
    (``gather_available`` — whether the block carries a reverse table —
    is part of the key). The cost comparison pits the reverse pull (a
    sorted segment reduce over the same edge count) against the
    autodiff scatter-add; in autotune mode ``runner`` measures the two
    differentiated calls once per signature.
    """
    backend = jax.default_backend()
    key = (signature, spec.name, int(d), requested,
           bool(gather_available), backend)
    log_name = f"block_bwd:{spec.name}"
    chosen = _BLOCK_BWD_PLANS.get(key)
    if chosen is None:
        memoize = True

        def ok(s):
            return (block_bwd_supports(s, spec)
                    and (s != "gather" or gather_available))

        if requested == "auto":
            if not ok("gather"):
                chosen = "scatter"
            elif _MODE == "autotune" and runner is not None:
                times = {s: _measure(runner, s)
                         for s in BLOCK_BWD_STRATEGIES}
                chosen = min(times, key=times.get)
                _obs_events.measured_event(log_name, times[chosen])
            else:
                chosen = min(BLOCK_BWD_STRATEGIES,
                             key=lambda s: _block_bwd_cost(
                                 s, signature, d, backend))
                # same rule as the forward block plans: a cost-model
                # stand-in computed in autotune mode is not pinned, so a
                # later eager call still gets to measure
                memoize = _MODE != "autotune"
        elif requested not in BLOCK_BWD_STRATEGIES:
            raise ValueError(
                f"unknown block backward strategy {requested!r}; expected "
                f"one of {BLOCK_BWD_STRATEGIES + ('auto',)}")
        elif ok(requested):
            chosen = requested
        else:
            chosen = "scatter"
            _warn_fallback(log_name, requested, chosen)
        if memoize:
            _BLOCK_BWD_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled():
        predicted = _block_bwd_cost(chosen, signature, d, backend)
    _record(log_name, requested, chosen, predicted, dtype=dtype)
    return chosen


# --------------------------------------------------------------------- #
# heterogeneous (relation-fused) planning — DESIGN.md §8
# --------------------------------------------------------------------- #
# A relational aggregation Σ_r CR(g_r) can run as R sequential calls
# ('loop' — the pre-refactor baseline, one gather + one reduce per
# relation), as ONE fused stream over the relation-stacked graph
# ('fused' — a single sorted segment reduce), or as the fused messages
# pushed through the fused graph's blocked pull ('ell'). The trade is
# per-relation dispatch overhead (loop pays R of them) against the
# fused paths' relation-indexing traffic and, for ell, the padding tax
# of the fused degree histogram. Decisions are memoized per static
# RelGraph signature × op × width × backend — trace-safe, like block
# plans — and logged as ``hetero:<op>``.
HETERO_STRATEGIES = ("fused", "loop", "ell")

_HETERO_PLANS: Dict[Tuple, str] = {}

_HETERO_REL_OVERHEAD = 2e4   # per-relation dispatch + reduce setup (elops)
_HETERO_FUSED_TAX = 0.1      # relation-id/W-indexing traffic multiplier
_HETERO_FIXED = 2e4          # one-time fused-stream setup

_HETERO_FALLBACK = ("fused", "loop")


def _hetero_cost(strategy: str, signature: Tuple[int, int, int, int],
                 d: int, backend: str,
                 stats: Optional[GraphStats] = None) -> Optional[float]:
    """Estimated cost of one relational aggregation (element-ops);
    None when the strategy has no model (ell without fused stats)."""
    _, _, n_edges, n_rel = signature
    tp = _THROUGHPUT.get(backend, _THROUGHPUT["cpu"])
    dd = max(int(d), 1)
    if strategy == "loop":
        return (tp["segment"] * n_edges * dd
                + n_rel * _HETERO_REL_OVERHEAD)
    if strategy == "fused":
        return (tp["segment"] * (1 + _HETERO_FUSED_TAX) * n_edges * dd
                + _HETERO_FIXED)
    if strategy == "ell" and stats is not None:
        return ((1 + _HETERO_FUSED_TAX)
                * estimate_cost("ell", stats, dd, backend=backend))
    if strategy == "push":
        return tp["push"] * n_edges * dd + n_rel * _HETERO_REL_OVERHEAD
    return None


def clear_hetero_plans() -> None:
    _HETERO_PLANS.clear()


def plan_hetero(signature: Tuple[int, int, int, int], op_name: str,
                d: int, requested: str = "auto",
                stats: Optional[GraphStats] = None, ell_ok: bool = True,
                runner: Optional[Callable[[str], Any]] = None) -> str:
    """Pick the execution strategy for one relational aggregation.

    ``signature`` is :attr:`RelGraph.signature` — static sizes only
    (n_src, n_dst, n_edges, n_rel). ``stats`` are the FUSED graph's
    :class:`GraphStats` (static aux on the RelGraph's PlanCache, so
    they survive ``jit``); they feed the ell row's padding estimate —
    without them (or with ``ell_ok=False``, e.g. in-trace with no
    prebuilt pack) ell never qualifies. Plain gspmm strategy names pin
    the per-relation loop with that inner reduce (``'push'`` is the
    fig2 baseline). In autotune mode an eager ``runner`` measures the
    candidates once per signature, exactly like block planning.
    """
    backend = jax.default_backend()
    key = (signature, op_name, int(d), requested, backend)
    log_name = f"hetero:{op_name}"
    chosen = _HETERO_PLANS.get(key)
    if chosen is None:
        memoize = True

        def candidates():
            cand = ["fused", "loop"]
            if ell_ok and stats is not None:
                cand.insert(1, "ell")
            return cand

        if requested == "auto":
            cand = candidates()
            if _MODE == "autotune" and runner is not None:
                times = {s: _measure(runner, s) for s in cand}
                chosen = min(times, key=times.get)
                _obs_events.measured_event(log_name, times[chosen])
            else:
                chosen = min(cand, key=lambda s: _hetero_cost(
                    s, signature, d, backend, stats))
                memoize = _MODE != "autotune"
        elif requested in HETERO_STRATEGIES:
            if requested == "ell" and not ell_ok:
                chosen = "fused"
                _warn_fallback(log_name, requested, chosen)
            else:
                chosen = requested
        elif requested in STRATEGIES:
            # plain gspmm pin: the per-relation loop with that inner
            # reduce — 'push' is the scatter baseline, everything else
            # runs the loop's segment form
            chosen = "push" if requested == "push" else "loop"
        else:
            raise ValueError(
                f"unknown hetero strategy {requested!r}; expected one "
                f"of {HETERO_STRATEGIES + STRATEGIES + ('auto',)}")
        if memoize:
            _HETERO_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled():
        predicted = _hetero_cost(chosen, signature, d, backend, stats)
    _record(log_name, requested, chosen, predicted)
    return chosen


# --------------------------------------------------------------------- #
# gSDDMM (edge-output) planning — DESIGN.md §9
# --------------------------------------------------------------------- #
# Edge-output BRs (attention logits, the softmax chain's shift/divide,
# GCMC's bilinear decode) used to be strategy-free gathers. They are now
# planned like every other hot path, logged as ``sddmm:<op>``:
#
#   'gather'    — operands gathered straight into CALLER edge order
#                 (one eid_inv-indirected gather PER operand; the
#                 DGL-style baseline),
#   'canonical' — operands gathered in canonical (dst-sorted) order, ⊗
#                 on the sorted stream, ONE un-permute of the result —
#                 the dst-side reads stream instead of hopping,
#   'pallas'    — the canonical stream's ⊗ computed by the tiled Pallas
#                 kernel (kernels/sddmm) — the TPU form.
#
# Decisions are memoized per static (sizes, op, width, requested,
# backend, pallas-support) key — trace-safe like block plans — and
# autotune mode measures the candidates once per key on eager calls.
SDDMM_STRATEGIES = ("canonical", "gather", "pallas")

_SDDMM_PLANS: Dict[Tuple, str] = {}

# Relative per-element tax between the two universal forms. On
# accelerators the canonical stream wins (dst-side reads stream; the
# single output permute is cheap next to per-operand random gathers),
# so gather pays the tax. On CPU the measured ordering flips — XLA's
# random operand gathers are cheap and the full-width output un-permute
# dominates (benchmarks/fig_sddmm.py: canonical 1.5–4× slower) — so
# canonical pays it there. Autotune mode re-measures either way.
_SDDMM_GATHER_TAX = 1.25

_SDDMM_FALLBACK = ("canonical", "gather")


def clear_sddmm_plans() -> None:
    _SDDMM_PLANS.clear()
    _ATTN_PLANS.clear()


def sddmm_supports(strategy: str, spec, lhs_data, rhs_data) -> bool:
    """Can ``strategy`` execute this EDGE-output spec?

    canonical/gather are universal. The tiled Pallas kernel handles
    rank-2 floating operand streams whose widths match (or broadcast
    from 1) — the shapes the attention/decode ops actually produce.
    """
    if spec.out != "e":
        return False
    if strategy in ("canonical", "gather"):
        return True
    if strategy == "pallas":
        if not jnp.issubdtype(lhs_data.dtype, jnp.floating):
            return False
        if lhs_data.ndim != 2:
            return False
        if rhs_data is not None:
            if rhs_data.ndim != 2:
                return False
            if not jnp.issubdtype(rhs_data.dtype, jnp.floating):
                return False
            dl, dr = lhs_data.shape[-1], rhs_data.shape[-1]
            if dl != dr and 1 not in (dl, dr):
                return False
        return True
    raise ValueError(f"unknown sddmm strategy {strategy!r}")


def _sddmm_cost(strategy: str, n_edges: int, d: int, backend: str) -> float:
    tp = _THROUGHPUT.get(backend, _THROUGHPUT["cpu"])
    work = n_edges * max(int(d), 1)
    if strategy == "canonical":
        tax = _SDDMM_GATHER_TAX if backend == "cpu" else 1.0
        return tp["segment"] * tax * work
    if strategy == "gather":
        tax = 1.0 if backend == "cpu" else _SDDMM_GATHER_TAX
        return tp["segment"] * tax * work
    return tp["pallas"] * work + _FIXED["pallas"]


def plan_sddmm(signature: Tuple[int, int, int], spec, d: int,
               requested: str = "auto",
               lhs_data=None, rhs_data=None,
               runner: Optional[Callable[[str], Any]] = None) -> str:
    """Pick the execution strategy for one edge-output BR (gSDDMM).

    ``signature`` is ``(n_src, n_dst, n_edges)`` — static sizes only,
    so planning is trace-safe. Operand arrays (optional: their absence
    just disqualifies pallas) feed the support predicate; ``runner``
    measures candidates in autotune mode, exactly like block planning.
    Logged as ``sddmm:<op>``.
    """
    backend = jax.default_backend()
    pallas_ok = (lhs_data is not None
                 and sddmm_supports("pallas", spec, lhs_data, rhs_data))
    key = (tuple(signature), spec.name, int(d), requested, backend,
           pallas_ok)
    log_name = f"sddmm:{spec.name}"
    chosen = _SDDMM_PLANS.get(key)
    if chosen is None:
        n_edges = signature[2]
        memoize = True
        if requested == "auto":
            cand = [s for s in SDDMM_STRATEGIES
                    if s != "pallas" or pallas_ok]
            if _MODE == "autotune" and runner is not None:
                times = {s: _measure(runner, s) for s in cand}
                chosen = min(times, key=times.get)
                _obs_events.measured_event(log_name, times[chosen])
            else:
                chosen = min(cand, key=lambda s: _sddmm_cost(
                    s, n_edges, d, backend))
                # cost stand-ins computed in autotune mode are not
                # pinned — a later eager call still gets to measure
                memoize = _MODE != "autotune"
        elif requested not in SDDMM_STRATEGIES:
            raise ValueError(
                f"unknown sddmm strategy {requested!r}; expected one of "
                f"{SDDMM_STRATEGIES + ('auto',)}")
        elif requested != "pallas" or pallas_ok:
            chosen = requested
        else:
            chosen = next(s for s in _SDDMM_FALLBACK
                          if s != "pallas" or pallas_ok)
            _warn_fallback(log_name, requested, chosen)
        if memoize:
            _SDDMM_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled():
        predicted = _sddmm_cost(chosen, signature[2], d, backend)
    _record(log_name, requested, chosen, predicted,
            dtype=None if lhs_data is None else str(lhs_data.dtype))
    return chosen


# --------------------------------------------------------------------- #
# fused-attention planning — logits+softmax+aggregate as ONE pass
# --------------------------------------------------------------------- #
# 'fused'  — the canonical single-pass jnp form (segment max/sum over
#            the dst-sorted stream, α never leaves registers→HBM as a
#            separate caller-order tensor);
# 'pallas' — the row-complete ELL megakernel (kernels/edge_softmax):
#            whole destination rows resident in VMEM, softmax AND the
#            weighted reduce in one kernel launch;
# 'ring'   — the partitioned composition (ring_edge_values →
#            bucket_softmax → ring_gspmm), pinned by the partitioned
#            model path.
# Logged under ONE name, ``attn:fused``, so plan logs show the
# attention pipeline as a single planned op rather than its pieces.
ATTN_STRATEGIES = ("fused", "pallas", "ring")

_ATTN_PLANS: Dict[Tuple, str] = {}

# The megakernel runs over a ROW-COMPLETE pack: whole destination rows
# resident per stripe. With the ragged per-class pack its padded-slot
# count is the degree histogram's pow2 row sum (ell_rowcomplete_padding)
# instead of n_rows × max_degree — the change that makes pallas a live
# candidate on power-law degree tails.
_ATTN_PALLAS_FIXED = 5e4


def _attn_cost(strategy: str, n_edges: int, hf: int, backend: str,
               padded_slots: Optional[int] = None) -> Optional[float]:
    """Estimated cost of one fused-attention pass (element-ops); None
    for ring (the partitioned composition has no single-device model)."""
    tp = _THROUGHPUT.get(backend, _THROUGHPUT["cpu"])
    if strategy == "fused":
        return tp["segment"] * n_edges * hf
    if strategy == "pallas":
        slots = n_edges if padded_slots is None else padded_slots
        # On CPU the megakernel lowers through interpret mode to the
        # same dense blocked pull the ell strategy runs — price its
        # slots at the ell rate; the true pallas rate is a TPU number.
        rate = tp["ell"] if backend == "cpu" else tp["pallas"]
        return rate * slots * hf + _ATTN_PALLAS_FIXED
    return None


def plan_attention(signature: Tuple[int, int, int], heads: int, feat: int,
                   requested: str = "auto", pallas_ok: bool = False,
                   padded_slots: Optional[int] = None,
                   dtype: Optional[str] = None) -> str:
    """Pick the fused-attention execution form; logged ``attn:fused``.

    ``signature`` = (n_src, n_dst, n_edges); ``pallas_ok`` — whether
    the row-complete uniform pack is available (host-side build, or
    prebuilt in the graph's cache); ``padded_slots`` refines the
    megakernel's padded work estimate (n_dst_nonzero * max_deg slots).
    """
    backend = jax.default_backend()
    key = (tuple(signature), int(heads), int(feat), requested, backend,
           bool(pallas_ok), padded_slots)
    chosen = _ATTN_PLANS.get(key)
    if chosen is None:
        n_edges = signature[2]
        hf = max(int(heads), 1) * max(int(feat), 1)
        if requested == "auto":
            cand = ["fused"] + (["pallas"] if pallas_ok else [])
            chosen = min(cand, key=lambda s: _attn_cost(
                s, n_edges, hf, backend, padded_slots))
        elif requested not in ATTN_STRATEGIES:
            raise ValueError(
                f"unknown attention strategy {requested!r}; expected one "
                f"of {ATTN_STRATEGIES + ('auto',)}")
        elif requested == "pallas" and not pallas_ok:
            chosen = "fused"
            _warn_fallback("attn:fused", requested, chosen)
        else:
            chosen = requested
        _ATTN_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled():
        hf = max(int(heads), 1) * max(int(feat), 1)
        predicted = _attn_cost(chosen, signature[2], hf, backend,
                               padded_slots)
    _record("attn:fused", requested, chosen, predicted, dtype=dtype)
    return chosen


# --------------------------------------------------------------------- #
# serving planning — how a micro-batched inference request executes
# --------------------------------------------------------------------- #
# 'layerwise' — each layer computed once for ALL nodes per refresh
#              (the full-graph training forward), requests answered by
#              cached row lookups: per-batch cost is the refresh edge
#              work amortized over the refresh period plus a gather;
# 'fanout'   — per-request full-neighbor L-hop block expansion through
#              forward_blocks: per-batch cost is the (shared-neighbor-
#              re-expanding) padded block edge work, but results are
#              never stale.
# Logged per op as ``serve:<op>`` so plan logs show serving decisions
# alongside kernel-strategy rows.
SERVE_MODES = ("layerwise", "fanout")

_SERVE_PLANS: Dict[Tuple, str] = {}

# Host-side gather + cache bookkeeping per served row, in the same
# edge-work currency as _THROUGHPUT (relative units, CPU-calibrated).
_SERVE_LOOKUP_COST = 8.0


def _serve_cost(mode: str, signature: Tuple[int, int, int, int],
                expansion_edges: int, refresh_batches: int) -> float:
    """Estimated per-batch cost of one serve mode (element-ops)."""
    n_edges, cls, layers = signature[1], signature[2], signature[3]
    if mode == "layerwise":
        per = max(int(refresh_batches), 1)
        return ((n_edges * max(layers, 1)) / per
                + _SERVE_LOOKUP_COST * cls)
    return float(expansion_edges)


def plan_serve(signature: Tuple[int, int, int, int], op_name: str = "infer",
               requested: str = "auto", *, expansion_edges: int,
               refresh_batches: int = 1024) -> str:
    """Pick the serve-time execution mode; logged ``serve:<op_name>``.

    ``signature`` = (n_nodes, n_edges, batch_class, n_layers);
    ``expansion_edges`` is the static padded edge-slot count of ONE
    fan-out batch of this class (sum over its block signatures);
    ``refresh_batches`` amortizes the layer-wise full-graph recompute
    over the expected batches between refreshes.
    """
    backend = jax.default_backend()
    key = (tuple(signature), op_name, requested, backend,
           int(expansion_edges), int(refresh_batches))
    log_name = f"serve:{op_name}"
    chosen = _SERVE_PLANS.get(key)
    if chosen is None:
        if requested == "auto":
            chosen = min(SERVE_MODES, key=lambda m: _serve_cost(
                m, signature, expansion_edges, refresh_batches))
        elif requested not in SERVE_MODES:
            raise ValueError(
                f"unknown serve mode {requested!r}; expected one of "
                f"{SERVE_MODES + ('auto',)}")
        else:
            chosen = requested
        _SERVE_PLANS[key] = chosen
    predicted = None
    if _obs_events.enabled():
        predicted = _serve_cost(chosen, signature, expansion_edges,
                                refresh_batches)
    _record(log_name, requested, chosen, predicted)
    return chosen


def clear_serve_plans() -> None:
    _SERVE_PLANS.clear()

"""GNN inference serving tier — DESIGN.md §10.

Training optimizes epoch time; serving optimizes request latency under
concurrency. Three pieces turn the training-side machinery into a
low-latency inference service:

* :class:`MicroBatcher` — incoming node-id requests are coalesced into
  batches padded onto a small fixed set of *signature classes* (padded
  batch sizes). Every batch of a class has the same static shapes, so
  the block planner's shape-keyed decisions
  (:func:`~repro.core.planner.plan_block_gspmm`) and the jit cache are
  warm after one batch per class: steady state runs ZERO recompiles
  (enforced by :class:`~repro.data.SignatureTracker`).
* **Layer-wise full-neighbor inference** — at serve time there is no
  variance-reduction reason to sample, and per-request L-hop fan-out
  re-expansion recomputes every shared neighbor once per request
  (2210.03900's dominant inference cost). The layer-wise plan computes
  each layer once for ALL nodes per refresh and answers requests with
  row lookups; the fan-out path is kept as the planned alternative (and
  the benchmark baseline), exact because full-neighbor expansion keeps
  every in-edge (``fanout ≥ max in-degree``). Both modes are planner
  rows (:func:`~repro.core.planner.plan_serve`, logged ``serve:<op>``).
* :class:`FeatureCache` — a hot-node feature/embedding cache tier:
  a degree-ordered *pinned* set (never evicted) over an LRU overflow,
  with exact hit/miss/eviction accounting surfaced as a
  :class:`CacheStats` pytree. The layer-wise plan serves output
  embeddings through it; the fan-out plan pulls input features for the
  expanded frontier through it.

:class:`GNNServer` wires the three together for GCN / GraphSAGE / GAT
(homogeneous) and R-GCN (relational), reusing the training-path
forwards unchanged — every serve path is differentially pinned to the
full-graph forward it must reproduce (tests/launch/test_serve_gnn.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import planner
from ..obs import metrics as _obs_metrics
from ..obs.events import measured_event as _measured_event
from ..obs.spans import span as _span

__all__ = ["CacheStats", "FeatureCache", "MicroBatch", "MicroBatcher",
           "GNNServer", "hot_node_ids", "SERVE_APPS"]


# --------------------------------------------------------------------- #
# hot-node feature/embedding cache tier
# --------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Exact cache accounting — a pytree, so stats stack/aggregate with
    ``jax.tree_util`` like every other metrics bundle in the repo."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    pinned_hits: int = 0
    size: int = 0          # resident LRU rows (excludes the pinned set)
    pinned: int = 0
    capacity: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_ratio(self) -> float:
        n = self.lookups
        return float(self.hits) / n if n else 0.0

    def tree_flatten(self):
        return ((self.hits, self.misses, self.evictions, self.pinned_hits,
                 self.size, self.pinned, self.capacity), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def hot_node_ids(degrees, k: int) -> np.ndarray:
    """The ``k`` highest-degree node ids, degree-ordered (descending,
    ties broken by id for determinism) — the pinned hot set. Power-law
    graphs concentrate traffic on exactly these rows."""
    deg = np.asarray(degrees)
    k = min(int(k), deg.shape[0])
    if k <= 0:
        return np.empty(0, np.int64)
    order = np.lexsort((np.arange(deg.shape[0]), -deg))
    return order[:k].astype(np.int64)


class FeatureCache:
    """Hot-row cache over a host-side backing row store.

    ``store`` is the authoritative (n, d) array (features or computed
    embeddings). ``pinned`` rows are resident forever — the
    degree-ordered hot set — and do not count against ``capacity``;
    everything else goes through an LRU of at most ``capacity`` rows.
    Duplicate ids inside one lookup hit on the second occurrence,
    exactly like an oracle dict replay (tests/core/test_serving_cache).

    :meth:`update` writes the backing store AND refreshes any resident
    copy in place, so the cache never serves a stale row (the
    invalidation contract the property tests pin down).
    """

    def __init__(self, store: np.ndarray, capacity: int,
                 pinned: Optional[np.ndarray] = None,
                 name: Optional[str] = None):
        self.store = np.asarray(store)
        if self.store.ndim < 1:
            raise ValueError("store must be at least 1-D (rows)")
        self.capacity = int(capacity)
        if self.capacity < 0:
            raise ValueError("capacity must be ≥ 0")
        # a named cache mirrors its counters into the metrics registry
        # (serve.cache.<name>.*), so snapshots carry CacheStats
        self.name = name
        self._pinned: Dict[int, np.ndarray] = {}
        if pinned is not None:
            for i in np.asarray(pinned).reshape(-1):
                self._pinned[int(i)] = self.store[int(i)].copy()
        self._lru: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.pinned_hits = 0

    @property
    def pinned_ids(self) -> Tuple[int, ...]:
        return tuple(self._pinned)

    def resident(self, i: int) -> bool:
        """Is row ``i`` currently served without touching the store?"""
        return int(i) in self._pinned or int(i) in self._lru

    def lookup(self, ids) -> np.ndarray:
        """Rows for ``ids`` (any order, duplicates fine), with exact
        hit/miss/eviction accounting. Misses read the backing store and
        become LRU-resident (evicting the least recently used row when
        over capacity); hits refresh recency."""
        ids = np.asarray(ids).reshape(-1)
        h0, m0, e0 = self.hits, self.misses, self.evictions
        out = np.empty((ids.shape[0],) + self.store.shape[1:],
                       self.store.dtype)
        for j, raw in enumerate(ids):
            i = int(raw)
            row = self._pinned.get(i)
            if row is not None:
                self.hits += 1
                self.pinned_hits += 1
                out[j] = row
                continue
            row = self._lru.get(i)
            if row is not None:
                self.hits += 1
                self._lru.move_to_end(i)
                out[j] = row
                continue
            self.misses += 1
            row = self.store[i].copy()
            out[j] = row
            if self.capacity > 0:
                self._lru[i] = row
                if len(self._lru) > self.capacity:
                    self._lru.popitem(last=False)
                    self.evictions += 1
        if self.name is not None:
            pre = f"serve.cache.{self.name}"
            _obs_metrics.counter(f"{pre}.hits").inc(self.hits - h0)
            _obs_metrics.counter(f"{pre}.misses").inc(self.misses - m0)
            _obs_metrics.counter(
                f"{pre}.evictions").inc(self.evictions - e0)
        return out

    def update(self, ids, rows) -> None:
        """Write ``rows`` into the backing store and refresh resident
        copies in place — a later lookup NEVER sees the old value."""
        ids = np.asarray(ids).reshape(-1)
        rows = np.asarray(rows, self.store.dtype)
        rows = rows.reshape((ids.shape[0],) + self.store.shape[1:])
        for j, raw in enumerate(ids):
            i = int(raw)
            self.store[i] = rows[j]
            if i in self._pinned:
                self._pinned[i] = rows[j].copy()
            if i in self._lru:      # refresh, keep recency unchanged
                self._lru[i] = rows[j].copy()

    def invalidate(self, ids=None) -> None:
        """Drop LRU residency (all rows when ``ids`` is None); pinned
        rows re-read the store instead of dropping out."""
        if ids is None:
            self._lru.clear()
            for i in self._pinned:
                self._pinned[i] = self.store[i].copy()
            return
        for raw in np.asarray(ids).reshape(-1):
            i = int(raw)
            self._lru.pop(i, None)
            if i in self._pinned:
                self._pinned[i] = self.store[i].copy()

    def replace_store(self, store: np.ndarray) -> None:
        """Swap the backing store (a layer-wise refresh writing new
        embeddings) and refresh every resident row — counters survive,
        staleness does not."""
        store = np.asarray(store)
        if store.shape != self.store.shape:
            raise ValueError(f"replacement store shape {store.shape} != "
                             f"{self.store.shape}")
        self.store = store
        for i in self._pinned:
            self._pinned[i] = store[i].copy()
        for i in self._lru:
            self._lru[i] = store[i].copy()

    def stats(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          evictions=self.evictions,
                          pinned_hits=self.pinned_hits,
                          size=len(self._lru), pinned=len(self._pinned),
                          capacity=self.capacity)


# --------------------------------------------------------------------- #
# request micro-batching onto signature classes
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One padded batch: ``ids[:n_real]`` are request node ids (caller
    order), the tail is pad (-1). ``spans`` maps each member request to
    its ``[start, stop)`` row range — responses are sliced from real
    rows only, so pad rows can never leak into a response."""
    ids: np.ndarray                      # (cls,) int64, -1 past n_real
    n_real: int
    cls: int                             # the padded signature class
    spans: Tuple[Tuple[int, int, int], ...]   # (rid, start, stop)


class MicroBatcher:
    """Coalesce request streams into signature-class batches.

    ``classes`` is the ascending set of padded batch sizes the serving
    tier compiles for — the batch-side analogue of the sampler's static
    shape signatures. Assignment is deterministic: a batch of ``n``
    real rows pads to the smallest class ≥ n; coalescing packs requests
    in arrival order and flushes when the next request would overflow
    the largest class. Requests larger than the largest class split
    into largest-class chunks (each chunk its own span row range).
    """

    def __init__(self, classes: Sequence[int] = (8, 32, 128)):
        cls = sorted(int(c) for c in classes)
        if not cls or cls[0] < 1:
            raise ValueError("classes must be ≥ 1")
        if len(set(cls)) != len(cls):
            raise ValueError("classes must be unique")
        self.classes = tuple(cls)

    def assign_class(self, n: int) -> int:
        """Smallest class that fits ``n`` real rows (the largest class
        for anything bigger — the caller chunks)."""
        if n < 1:
            raise ValueError("empty batch has no class")
        for c in self.classes:
            if n <= c:
                return c
        return self.classes[-1]

    def _emit(self, members: List[Tuple[int, np.ndarray]]) -> MicroBatch:
        n_real = sum(len(ids) for _, ids in members)
        cls = self.assign_class(n_real)
        ids = np.full(cls, -1, np.int64)
        spans = []
        at = 0
        for rid, req_ids in members:
            ids[at:at + len(req_ids)] = req_ids
            spans.append((rid, at, at + len(req_ids)))
            at += len(req_ids)
        return MicroBatch(ids=ids, n_real=n_real, cls=cls,
                          spans=tuple(spans))

    def coalesce(self, requests: Sequence[Tuple[int, Sequence[int]]]
                 ) -> List[MicroBatch]:
        """Pack ``(rid, node_ids)`` requests into padded class batches,
        preserving arrival order within and across batches."""
        cap = self.classes[-1]
        batches: List[MicroBatch] = []
        members: List[Tuple[int, np.ndarray]] = []
        n = 0
        for rid, req_ids in requests:
            req_ids = np.asarray(req_ids, np.int64).reshape(-1)
            if req_ids.size == 0:
                raise ValueError(f"request {rid}: empty node-id list")
            if (req_ids < 0).any():
                raise ValueError(f"request {rid}: negative node id")
            # oversize request: flush, then emit largest-class chunks
            while req_ids.size > cap:
                if members:
                    batches.append(self._emit(members))
                    members, n = [], 0
                batches.append(self._emit([(int(rid), req_ids[:cap])]))
                req_ids = req_ids[cap:]
            if n + req_ids.size > cap and members:
                batches.append(self._emit(members))
                members, n = [], 0
            members.append((int(rid), req_ids))
            n += req_ids.size
        if members:
            batches.append(self._emit(members))
        return batches

    @staticmethod
    def unpack(batch: MicroBatch, values: np.ndarray
               ) -> Dict[int, np.ndarray]:
        """Slice per-request responses out of a batch result. Only rows
        < ``n_real`` are reachable through the spans — pad rows are
        structurally excluded from every response."""
        if values.shape[0] < batch.n_real:
            raise ValueError(f"batch result has {values.shape[0]} rows "
                             f"< {batch.n_real} real requests")
        return {rid: values[start:stop]
                for rid, start, stop in batch.spans}


# --------------------------------------------------------------------- #
# the server
# --------------------------------------------------------------------- #
SERVE_APPS = ("gcn", "sage", "gat", "rgcn")


class GNNServer:
    """Micro-batched GNN inference over one (typed or plain) graph.

    ``app``: 'gcn' | 'sage' | 'gat' (plain graph ``g`` + ``feats``) or
    'rgcn' (pass ``rels`` — per-relation (src, dst) pairs — instead of
    relying on ``g``'s edges alone). Model ``params`` are the training
    pytrees, used unchanged.

    Each signature class resolves to a serve mode once, via
    :func:`repro.core.planner.plan_serve` (logged ``serve:infer``):

    * ``layerwise`` — every layer computed once for ALL nodes per
      :meth:`refresh`; a request is a row lookup through the hot-node
      cache. Exact by construction (it IS the full-graph forward).
    * ``fanout`` — per-request full-neighbor L-hop expansion through
      the training block path (``forward_blocks``); exact because the
      default ``fanout`` is the max in-degree (every in-edge kept, no
      sampling). The benchmark baseline, and the fallback when the
      output table is stale-intolerant.

    Zero steady-state recompiles are enforced: every served batch's
    static signature feeds a :class:`~repro.data.SignatureTracker`
    bounded by ``len(classes)`` per mode.
    """

    def __init__(self, app: str, params, g, feats, *,
                 rels: Optional[Sequence] = None,
                 mode: str = "auto",
                 classes: Sequence[int] = (8, 32, 128),
                 fanout: Optional[int] = None,
                 cache_rows: int = 4096, pin_hot: int = 256,
                 refresh_batches: int = 1024,
                 seed: int = 0):
        if app not in SERVE_APPS:
            raise ValueError(f"unknown serve app {app!r}; expected one "
                             f"of {SERVE_APPS}")
        if mode not in ("auto",) + planner.SERVE_MODES:
            raise ValueError(f"unknown serve mode {mode!r}; expected "
                             f"'auto' or one of {planner.SERVE_MODES}")
        # apps live above core — import lazily (same pattern as the
        # partition/hetero lazy imports) so core/__init__ stays acyclic
        from ..data.sampler import NeighborSampler
        from ..models.gnn import gat, gcn, rgcn, sage
        from ..models.gnn.common import make_bundle

        self.app = app
        self.params = params
        self.mode = mode
        self.batcher = MicroBatcher(classes)
        self.refresh_batches = int(refresh_batches)
        self.seed = int(seed)
        self._sampler_cls = NeighborSampler
        self._edge_rel = None

        if app == "rgcn":
            if rels is None:
                raise ValueError("app='rgcn' needs rels=[(src, dst), ...]")
            n = int(g.n_src) if g is not None else int(max(
                max(np.max(s), np.max(d)) for s, d in rels)) + 1
            self.g, edge_rel = rgcn.merged_graph(rels, n)
            self._edge_rel = np.asarray(edge_rel)
            self._rg = rgcn.build_relgraph(rels, n)
            mod, self._graph_arg = rgcn, self._rg
        else:
            if g is None:
                raise ValueError("plain-graph apps need g")
            self.g = g
            mod = {"gcn": gcn, "sage": sage, "gat": gat}[app]
            self._graph_arg = make_bundle(g)
        self._full_fn = mod.infer
        self._blocks_fn = mod.infer_blocks

        self.feats = np.asarray(feats, np.float32)
        self.n_layers = len(params["layers"])
        deg = np.asarray(self.g.in_degrees)
        max_deg = int(deg.max()) if deg.size else 0
        # full-neighbor default: keep every in-edge ⇒ serve is exact
        self.fanout = int(fanout) if fanout is not None else max(max_deg, 1)
        self.cache_rows = int(cache_rows)
        self._hot = hot_node_ids(deg, pin_hot)

        from ..obs.signatures import SignatureTracker
        # one signature per (class, mode) is the compile budget;
        # anything beyond that is a recompile leak
        self.tracker = SignatureTracker(
            limit=len(self.batcher.classes) * len(planner.SERVE_MODES),
            name="serve")
        self.compiles = 0
        self.served_batches = 0
        self.served_requests = 0

        self._out_cache: Optional[FeatureCache] = None
        self._feat_cache: Optional[FeatureCache] = None
        self._samplers: Dict[int, object] = {}
        self._infer_jit = jax.jit(
            lambda p, blocks, x: self._blocks_fn(p, blocks, x))
        self._mode_by_class: Dict[int, str] = {}

    # -- planning ------------------------------------------------------- #
    def _expansion_edges(self, cls: int) -> int:
        """Static edge-slot count of one fan-out batch of class ``cls``
        (the per-request re-expansion work the layer-wise plan avoids)."""
        from .blocks import serve_block_signature
        return sum(sig[2] for sig in serve_block_signature(
            cls, self.fanout, self.n_layers))

    def mode_for_class(self, cls: int) -> str:
        chosen = self._mode_by_class.get(cls)
        if chosen is None:
            chosen = planner.plan_serve(
                (self.g.n_src, self.g.n_edges, int(cls), self.n_layers),
                "infer", requested=self.mode,
                expansion_edges=self._expansion_edges(cls),
                refresh_batches=self.refresh_batches)
            self._mode_by_class[cls] = chosen
        return chosen

    # -- layer-wise plan ------------------------------------------------ #
    def refresh(self) -> CacheStats:
        """Recompute the layer-wise output table (each layer once, for
        all nodes — the training-path full forward, unchanged) and push
        it through the hot-node cache without dropping counters."""
        with _span("serve.refresh") as sp:
            logits = self._full_fn(self.params, self._graph_arg,
                                   jnp.asarray(self.feats))
            sp.fence(logits)
        store = np.asarray(jax.block_until_ready(logits))
        if self._out_cache is None:
            self._out_cache = FeatureCache(store, self.cache_rows,
                                           pinned=self._hot, name="out")
        else:
            self._out_cache.replace_store(store)
        return self._out_cache.stats()

    def update_features(self, ids, rows) -> None:
        """Feature update: write the input store (through the fan-out
        path's cache so it never serves stale rows) and recompute the
        layer-wise table — a stale output row is a wrong prediction."""
        ids = np.asarray(ids).reshape(-1)
        if self._feat_cache is not None:
            self._feat_cache.update(ids, rows)
        else:
            self.feats[ids] = np.asarray(rows, np.float32)
        if self._out_cache is not None:
            self.refresh()

    # -- fan-out plan --------------------------------------------------- #
    def _sampler(self, cls: int):
        s = self._samplers.get(cls)
        if s is None:
            s = self._sampler_cls(self.g, [self.fanout] * self.n_layers,
                                  batch_size=cls, seed=self.seed,
                                  edge_rel=self._edge_rel)
            self._samplers[cls] = s
        return s

    def _feature_rows(self, ids: np.ndarray) -> jnp.ndarray:
        """Input features for padded global ids, pulled through the
        hot-node cache (-1 pads read as zero rows)."""
        if self._feat_cache is None:
            self._feat_cache = FeatureCache(self.feats, self.cache_rows,
                                            pinned=self._hot, name="feat")
        ids = np.asarray(ids)
        x = np.zeros((ids.shape[0], self.feats.shape[1]), np.float32)
        real = ids >= 0
        if real.any():
            with _span("serve.cache_lookup", args={"cache": "feat"}):
                x[real] = self._feat_cache.lookup(ids[real])
        return jnp.asarray(x)

    def _serve_fanout(self, batch: MicroBatch) -> np.ndarray:
        sampler = self._sampler(batch.cls)
        with _span("serve.sample", args={"cls": batch.cls}):
            mb = sampler.sample(batch.ids[:batch.n_real],
                                np.zeros(batch.n_real, np.int64))
        x = self._feature_rows(np.asarray(mb.input_ids))
        self._observe(("fanout", batch.cls) + mb.shape_signature())
        with _span("serve.infer", args={"cls": batch.cls}) as sp:
            out = self._infer_jit(self.params, mb.blocks, x)
            sp.fence(out)
        return np.asarray(jax.block_until_ready(out))[:batch.n_real]

    # -- serving -------------------------------------------------------- #
    def _observe(self, signature: Tuple) -> None:
        # the shared train/serve accounting path (repro.obs.signatures)
        if self.tracker.observe_checked(signature):
            self.compiles += 1

    def serve_batch(self, batch: MicroBatch) -> np.ndarray:
        """(n_real, n_out) predictions for one coalesced batch."""
        t0 = time.perf_counter()
        mode = self.mode_for_class(batch.cls)
        if mode == "layerwise":
            if self._out_cache is None:
                self.refresh()
            self._observe(("layerwise", batch.cls))
            with _span("serve.cache_lookup", args={"cache": "out",
                                                   "cls": batch.cls}):
                out = self._out_cache.lookup(batch.ids[:batch.n_real])
        else:
            out = self._serve_fanout(batch)
        self.served_batches += 1
        # the measured side of the serve:infer plan row + the batch
        # latency histogram (out is host-side here — nothing in flight)
        dt = time.perf_counter() - t0
        _measured_event("serve:infer", dt)
        _obs_metrics.histogram("serve.batch_seconds").observe(dt)
        return out

    def serve(self, requests: Sequence[Tuple[int, Sequence[int]]]
              ) -> Dict[int, np.ndarray]:
        """Serve ``(rid, node_ids)`` requests; returns rid → (len(ids),
        n_out) predictions, padded rows never included."""
        with _span("serve.batching"):
            batches = self.batcher.coalesce(requests)
        results: Dict[int, List[np.ndarray]] = {}
        for batch in batches:
            vals = self.serve_batch(batch)
            with _span("serve.respond"):
                for rid, rows in self.batcher.unpack(batch,
                                                     vals).items():
                    results.setdefault(rid, []).append(rows)
        self.served_requests += len(results)
        # a request split across largest-class chunks re-assembles here
        return {rid: parts[0] if len(parts) == 1
                else np.concatenate(parts, axis=0)
                for rid, parts in results.items()}

    def serve_requests(self, reqs) -> None:
        """Complete a list of :class:`~repro.data.ServeRequest`s (the
        request-queue protocol): compute, then fulfil each future."""
        try:
            out = self.serve([(r.rid, r.ids) for r in reqs])
        except Exception as e:                     # noqa: BLE001
            for r in reqs:
                r.set_error(e)
            return
        for r in reqs:
            r.set_result(out[r.rid])

    def run(self, request_queue, depth: int = 2) -> None:
        """Drain a :class:`~repro.data.RequestQueue` until it closes,
        with the coalescing window riding the existing
        :class:`~repro.data.Prefetcher` (batch assembly overlaps the
        device step, exactly like sampling overlaps training)."""
        from ..data.pipeline import prefetch
        it = iter(prefetch(request_queue, depth=depth))
        sentinel = object()
        while True:
            # intake (blocking on the coalescing window) and handling
            # are the two top-level spans — together they tile the
            # session wall time, so trace coverage is ~100%
            with _span("serve.intake"):
                reqs = next(it, sentinel)
            if reqs is sentinel:
                break
            with _span("serve.handle"):
                self.serve_requests(reqs)

    def warmup(self) -> None:
        """Trace every signature class once so steady-state request
        latency is a lookup/execute, never a compile."""
        for cls in self.batcher.classes:
            batch = MicroBatch(ids=np.concatenate(
                                   [np.zeros(1, np.int64),
                                    np.full(cls - 1, -1, np.int64)]),
                               n_real=1, cls=cls, spans=((0, 0, 1),))
            self.serve_batch(batch)

    def stats(self) -> Dict:
        """Serving counters + cache stats (a pytree-of-scalars dict)."""
        return {
            "served_batches": self.served_batches,
            "served_requests": self.served_requests,
            "signatures": len(self.tracker.seen),
            "compiles": self.compiles,
            "out_cache": (self._out_cache.stats()
                          if self._out_cache is not None else None),
            "feat_cache": (self._feat_cache.stats()
                           if self._feat_cache is not None else None),
        }

"""Execution strategies for the reduce stage of BR/CR.

Mirrors the paper's progression:

* ``push_scatter``  — paper Alg. 1 (DGL baseline): materialize per-edge
  messages, scatter-reduce into destinations. Lowers to XLA ``scatter``,
  which serializes on both CPU and TPU — deliberately kept as the measured
  baseline.
* ``pull_segment``  — paper Alg. 2: destination-sorted segment reduction
  (owner-computes, no collisions). The "vendor library" analogue.
* ``pull_ell``      — paper Alg. 3: blocked pull. Chunked padded-ELL gather
  with dense masked reduction over the chunk width; second-stage segment
  combine for split rows. Sorted streams + dense vector inner loop.
* ``onehot_spmm``   — TPU adaptation: (M,K)-tile-bucketed edges turned into
  one-hot scatter/gather matrices, reduced with two dense matmuls per
  bucket (MXU-friendly). Sum/mean only.

Every strategy computes the same mathematical object:
``out[j] = ⊕_{edges e: tgt(e)=j} msg[e]`` with empty targets = 0.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .tiling import ELLPack, TilePack

__all__ = ["REDUCE_IDENTITY", "push_scatter", "pull_segment", "pull_ell_reduce",
           "onehot_spmm", "finalize_empty_rows"]

_BIG = float("inf")

REDUCE_IDENTITY = {
    "sum": 0.0,
    "mean": 0.0,
    "max": -_BIG,
    "min": _BIG,
    "prod": 1.0,
}


def finalize_empty_rows(out: jnp.ndarray, deg: jnp.ndarray,
                        reduce_op: str) -> jnp.ndarray:
    """DGL semantics: rows with no incoming edge are 0, for every ⊕."""
    if reduce_op == "sum":
        return out  # segment_sum already yields 0 for empty rows
    has = (deg > 0)
    has = has.reshape(has.shape + (1,) * (out.ndim - 1))
    return jnp.where(has, out, jnp.zeros((), out.dtype))


# --------------------------------------------------------------------- #
# Strategy 1: push-scatter (baseline, paper Alg. 1)
# --------------------------------------------------------------------- #
def push_scatter(msg: jnp.ndarray, tgt: jnp.ndarray, n_tgt: int,
                 reduce_op: str, deg: Optional[jnp.ndarray] = None
                 ) -> jnp.ndarray:
    """Materialized messages + scatter-reduce (the DGL push baseline)."""
    ident = jnp.asarray(REDUCE_IDENTITY[reduce_op], msg.dtype)
    out = jnp.full((n_tgt,) + msg.shape[1:], ident, msg.dtype)
    upd = out.at[tgt]
    if reduce_op in ("sum", "mean"):
        out = upd.add(msg)
    elif reduce_op == "max":
        out = upd.max(msg)
    elif reduce_op == "min":
        out = upd.min(msg)
    elif reduce_op == "prod":
        out = upd.mul(msg)
    else:
        raise ValueError(f"unknown reduce op {reduce_op!r}")
    if reduce_op == "mean":
        d = jnp.maximum(deg, 1).astype(msg.dtype)
        out = out / d.reshape((n_tgt,) + (1,) * (msg.ndim - 1))
    return finalize_empty_rows(out, deg, reduce_op) if deg is not None else out


# --------------------------------------------------------------------- #
# Strategy 2: pull-segment (paper Alg. 2)
# --------------------------------------------------------------------- #
def pull_segment(msg: jnp.ndarray, tgt_sorted: jnp.ndarray, n_tgt: int,
                 reduce_op: str, deg: Optional[jnp.ndarray] = None
                 ) -> jnp.ndarray:
    """Segment reduction over destination-sorted messages."""
    kw = dict(num_segments=n_tgt, indices_are_sorted=True)
    if reduce_op in ("sum", "mean"):
        out = jax.ops.segment_sum(msg, tgt_sorted, **kw)
        if reduce_op == "mean":
            d = jnp.maximum(deg, 1).astype(msg.dtype)
            out = out / d.reshape((n_tgt,) + (1,) * (msg.ndim - 1))
    elif reduce_op == "max":
        out = jax.ops.segment_max(msg, tgt_sorted, **kw)
        out = jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
    elif reduce_op == "min":
        out = jax.ops.segment_min(msg, tgt_sorted, **kw)
        out = jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
    elif reduce_op == "prod":
        out = jax.ops.segment_prod(msg, tgt_sorted, **kw)
    else:
        raise ValueError(f"unknown reduce op {reduce_op!r}")
    return finalize_empty_rows(out, deg, reduce_op) if deg is not None else out


# --------------------------------------------------------------------- #
# Strategy 3: blocked pull over degree-bucketed ELL (paper Alg. 3)
# --------------------------------------------------------------------- #
def pull_ell_reduce(pack: ELLPack,
                    class_msg_fn: Callable,
                    reduce_op: str,
                    deg: Optional[jnp.ndarray] = None,
                    raw: bool = False) -> jnp.ndarray:
    """Blocked pull: dense masked reduce along each width class.

    ``class_msg_fn(cls)`` returns per-edge messages for one
    :class:`ELLClass` as ``(n_chunks, width, *feat)`` — gathers happen
    inside so the edge-ordered message tensor is never materialized
    (XLA fuses gather+mask+reduce per class). Each destination row lives
    in exactly one class (splits share the cap class), so classes
    combine with one segment reduction each.

    ``raw=True`` skips the finalize tail (extrema keep ±inf on empty
    rows, no mean divide, no empty-row zeroing) — for callers that
    combine several partial reductions (hetero skew classes) and must
    finalize exactly once at the end.
    """
    base = "sum" if reduce_op in ("sum", "mean") else reduce_op
    out = None
    for cls in pack.classes:
        msg = class_msg_fn(cls)  # (C, W, *feat)
        mask = cls.chunk_mask.reshape(cls.chunk_mask.shape
                                      + (1,) * (msg.ndim - 2))
        ident = jnp.asarray(REDUCE_IDENTITY[reduce_op], msg.dtype)
        msg = jnp.where(mask, msg, ident)
        if base == "sum":
            part = msg.sum(axis=1)
        elif base == "max":
            part = msg.max(axis=1)
        elif base == "min":
            part = msg.min(axis=1)
        elif base == "prod":
            part = msg.prod(axis=1)
        else:
            raise ValueError(f"unknown reduce op {reduce_op!r}")
        # raw per-class segment reduce (identity fill preserved so the
        # cross-class combine is correct for max/min on negative values)
        kw = dict(num_segments=pack.n_dst, indices_are_sorted=True)
        seg = {"sum": jax.ops.segment_sum, "max": jax.ops.segment_max,
               "min": jax.ops.segment_min, "prod": jax.ops.segment_prod}
        cls_out = seg[base](part, cls.chunk_row, **kw)
        if out is None:
            out = cls_out
        elif base == "sum":
            out = out + cls_out
        elif base == "max":
            out = jnp.maximum(out, cls_out)
        elif base == "min":
            out = jnp.minimum(out, cls_out)
        else:
            out = out * cls_out
    if raw:
        return out
    if base in ("max", "min"):
        out = jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
    if reduce_op == "mean":
        d = jnp.maximum(deg, 1).astype(out.dtype)
        out = out / d.reshape((pack.n_dst,) + (1,) * (out.ndim - 1))
    return finalize_empty_rows(out, deg, reduce_op) if deg is not None else out


# --------------------------------------------------------------------- #
# Strategy 4: one-hot blocked SpMM (TPU/MXU adaptation)
# --------------------------------------------------------------------- #
def onehot_spmm(pack: TilePack, B: jnp.ndarray, reduce_op: str = "sum",
                edge_weight: Optional[jnp.ndarray] = None,
                deg: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """C = A ⊕ B via per-bucket one-hot matmuls.

    For each bucket t with edges (dl, sl):
      G_t[j, :] = onehot(sl_j)           (eb × bk)   gather matrix
      S_t[:, j] = w_j · onehot(dl_j)     (bm × eb)   scatter matrix
      C_tile[tile_m_t] += S_t @ (G_t @ B_block[tile_k_t])

    Two dense matmuls per bucket — MXU-shaped on TPU. Sum/mean only (max is
    not a matmul). Feature dim untouched → natural N-blocking by XLA.
    """
    if reduce_op not in ("sum", "mean"):
        raise ValueError("onehot_spmm supports sum/mean only")
    T, eb = pack.dst_local.shape
    bm, bk = pack.bm, pack.bk
    d = B.shape[-1]

    # pad B to whole K tiles, view as (n_tiles_k, bk, d)
    pad_k = pack.n_tiles_k * bk - B.shape[0]
    Bp = jnp.pad(B, ((0, pad_k), (0, 0)))
    Bt = Bp.reshape(pack.n_tiles_k, bk, d)
    Bsel = Bt[pack.tile_k]                          # (T, bk, d)

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (T, eb, bk), 2)
    G = (pack.src_local[:, :, None] == iota_k)
    G = jnp.where(pack.mask[:, :, None], G, False).astype(B.dtype)

    iota_m = jax.lax.broadcasted_iota(jnp.int32, (T, bm, eb), 1)
    S = (pack.dst_local[:, None, :] == iota_m).astype(B.dtype)
    if edge_weight is not None:
        S = S * edge_weight[:, None, :].astype(B.dtype)
    S = jnp.where(pack.mask[:, None, :], S, jnp.zeros((), B.dtype))

    gathered = jnp.einsum("tek,tkd->ted", G, Bsel)   # (T, eb, d)
    partial = jnp.einsum("tme,ted->tmd", S, gathered)  # (T, bm, d)

    # combine buckets into M tiles (tile_m sorted by construction)
    tiles = jax.ops.segment_sum(partial, pack.tile_m,
                                num_segments=pack.n_tiles_m,
                                indices_are_sorted=True)
    out = tiles.reshape(pack.n_tiles_m * bm, d)[: pack.n_dst]
    if reduce_op == "mean":
        dd = jnp.maximum(deg, 1).astype(out.dtype)
        out = out / dd[:, None]
    return out

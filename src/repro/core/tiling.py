"""Blocked edge formats — the paper's Alg. 3 blocking, as preprocessing.

Two packed formats are built host-side (numpy) from a :class:`Graph`:

* :class:`ELLPack` — degree-bucketed padded ELL. The pull model (paper
  Alg. 2) with dense, vectorizable inner reduction: rows are grouped by
  in-degree class so padding waste is bounded; each bucket reduces a dense
  ``(rows, width, feat)`` gather along ``width``. Rows wider than
  ``width_cap`` are split into chunks and combined by a tiny second-stage
  segment reduce. This is the XLA-native "optimized CPU" path used for the
  paper-reproduction benchmarks.

* :class:`TilePack` — edges bucketed by ``(dst-tile, src-tile)`` pairs and
  sorted within buckets: the direct analogue of the paper's K-blocking +
  radix sort, consumed by the Pallas TPU kernel (VMEM-resident K-blocks)
  and by the one-hot MXU strategy.

Both are registered pytrees so they can be closed over or passed through
``jit``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph

__all__ = ["ELLPack", "ELLClass", "build_ell", "build_ell_uniform",
           "build_ell_ragged", "TilePack", "build_tiles"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class ELLClass:
    """One degree class of the bucketed ELL: all chunks of width ``width``."""
    chunk_cols: jnp.ndarray   # (n_chunks, width) int32 source ids (0 pad)
    chunk_eids: jnp.ndarray   # (n_chunks, width) int32 edge ids   (0 pad)
    chunk_mask: jnp.ndarray   # (n_chunks, width) bool
    chunk_row: jnp.ndarray    # (n_chunks,) int32 destination row
    width: int = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return ((self.chunk_cols, self.chunk_eids, self.chunk_mask,
                 self.chunk_row), (self.width,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        cols, eids, mask, row = children
        return cls(chunk_cols=cols, chunk_eids=eids, chunk_mask=mask,
                   chunk_row=row, width=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class ELLPack:
    """Degree-bucketed padded ELL: tuple of per-width classes."""
    classes: tuple            # of ELLClass
    n_dst: int = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return (self.classes, (self.n_dst,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(classes=tuple(children), n_dst=aux[0])


def build_ell(g: Graph, width_cap: int = 64) -> ELLPack:
    """Pack ``g`` into DEGREE-BUCKETED padded ELL.

    Rows are grouped by power-of-two in-degree class so the pad waste per
    chunk is < 2× (a fixed chunk width pads 1-degree rows of a power-law
    graph ~width×). Rows wider than ``width_cap`` are split into
    ``width_cap``-wide chunks. The canonical (dst,src)-sorted edge order
    of :class:`Graph` keeps each chunk's column ids ascending — the
    paper's sorted-stream property.

    The pack stores chunks CONTIGUOUSLY PER CLASS with per-class extents
    so the reduce path can process each width class densely.
    """
    indptr = np.asarray(g.indptr_dst, dtype=np.int64)
    src = np.asarray(g.src, dtype=np.int64)
    eid = np.asarray(g.eid, dtype=np.int64)
    n_dst = g.n_dst
    deg = indptr[1:] - indptr[:-1]

    # (class_width, row, start, len) — class = next pow2 ≥ len (≤ cap)
    chunks = []
    nz = np.nonzero(deg)[0]
    for r in nz:
        s, e = indptr[r], indptr[r + 1]
        for cs in range(s, e, width_cap):
            ln = min(width_cap, e - cs)
            w = 1 << int(np.ceil(np.log2(ln))) if ln > 1 else 1
            chunks.append((w, r, cs, ln))
    if not chunks:
        chunks = [(1, 0, 0, 0)]
    chunks.sort(key=lambda c: (c[0], c[1]))

    classes = []
    i = 0
    while i < len(chunks):
        w = chunks[i][0]
        j = i
        while j < len(chunks) and chunks[j][0] == w:
            j += 1
        n = j - i
        cols = np.zeros((n, w), np.int32)
        eids = np.zeros((n, w), np.int32)
        mask = np.zeros((n, w), bool)
        rows = np.zeros((n,), np.int32)
        for k, (_, r, s, ln) in enumerate(chunks[i:j]):
            cols[k, :ln] = src[s:s + ln]
            eids[k, :ln] = eid[s:s + ln]
            mask[k, :ln] = True
            rows[k] = r
        classes.append((w, cols, eids, mask, rows))
        i = j

    return ELLPack(
        classes=tuple(
            ELLClass(width=w, chunk_cols=jnp.asarray(c),
                     chunk_eids=jnp.asarray(e), chunk_mask=jnp.asarray(m),
                     chunk_row=jnp.asarray(r))
            for (w, c, e, m, r) in classes),
        n_dst=n_dst)


def build_ell_ragged(g: Graph) -> ELLPack:
    """Row-complete RAGGED ELL: power-of-two degree classes, each class
    padded only to its own width.

    Like :func:`build_ell` but with NO row splitting — every chunk holds
    one whole destination row (width = next pow2 ≥ its in-degree), so
    the fused edge-softmax megakernel can launch one stripe grid per
    class and still see complete rows. Rows are disjoint across classes,
    which makes the per-class scatter-back a pure permutation. Against
    the row-complete uniform pack (every row padded to the global max
    degree) the padded-slot count drops by the degree-tail factor — the
    pad tax this format exists to kill on power-law graphs.
    """
    indptr = np.asarray(g.indptr_dst, dtype=np.int64)
    src = np.asarray(g.src, dtype=np.int64)
    eid = np.asarray(g.eid, dtype=np.int64)
    deg = indptr[1:] - indptr[:-1]

    chunks = []
    nz = np.nonzero(deg)[0]
    for r in nz:
        s, e = indptr[r], indptr[r + 1]
        ln = e - s
        w = 1 << int(np.ceil(np.log2(ln))) if ln > 1 else 1
        chunks.append((w, r, s, ln))
    if not chunks:
        chunks = [(1, 0, 0, 0)]
    chunks.sort(key=lambda c: (c[0], c[1]))

    classes = []
    i = 0
    while i < len(chunks):
        w = chunks[i][0]
        j = i
        while j < len(chunks) and chunks[j][0] == w:
            j += 1
        n = j - i
        cols = np.zeros((n, w), np.int32)
        eids = np.zeros((n, w), np.int32)
        mask = np.zeros((n, w), bool)
        rows = np.zeros((n,), np.int32)
        for k, (_, r, s, ln) in enumerate(chunks[i:j]):
            cols[k, :ln] = src[s:s + ln]
            eids[k, :ln] = eid[s:s + ln]
            mask[k, :ln] = True
            rows[k] = r
        classes.append((w, cols, eids, mask, rows))
        i = j

    return ELLPack(
        classes=tuple(
            ELLClass(width=w, chunk_cols=jnp.asarray(c),
                     chunk_eids=jnp.asarray(e), chunk_mask=jnp.asarray(m),
                     chunk_row=jnp.asarray(r))
            for (w, c, e, m, r) in classes),
        n_dst=g.n_dst)


def build_ell_uniform(g: Graph, width: int) -> ELLClass:
    """Single-class padded ELL with one FULL row per chunk (no splitting;
    ``width`` must be ≥ the max in-degree). Used by the fused edge-softmax
    kernel, which needs whole rows resident."""
    indptr = np.asarray(g.indptr_dst, dtype=np.int64)
    src = np.asarray(g.src, dtype=np.int64)
    eid = np.asarray(g.eid, dtype=np.int64)
    deg = indptr[1:] - indptr[:-1]
    if len(deg) and deg.max() > width:
        raise ValueError(f"width {width} < max degree {deg.max()}")
    nz = np.nonzero(deg)[0]
    n = max(len(nz), 1)
    cols = np.zeros((n, width), np.int32)
    eids = np.zeros((n, width), np.int32)
    mask = np.zeros((n, width), bool)
    rows = np.zeros((n,), np.int32)
    for k, r in enumerate(nz):
        s, e = indptr[r], indptr[r + 1]
        ln = e - s
        cols[k, :ln] = src[s:e]
        eids[k, :ln] = eid[s:e]
        mask[k, :ln] = True
        rows[k] = r
    return ELLClass(chunk_cols=jnp.asarray(cols),
                    chunk_eids=jnp.asarray(eids),
                    chunk_mask=jnp.asarray(mask),
                    chunk_row=jnp.asarray(rows), width=width)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class TilePack:
    """(M-tile, K-tile)-bucketed edge lists, sorted by (mi, ki, dst, src).

    Buckets are padded to ``eb`` edges; a (mi, ki) pair holding more than
    ``eb`` edges is split into several consecutive buckets with the same
    tile coordinates (the consumer accumulates). ``first_of_m[t]`` is 1 iff
    bucket ``t`` is the first bucket touching its M-tile — the Pallas kernel
    uses it to zero-initialize the output tile on first visit.
    """
    tile_m: jnp.ndarray       # (T,) int32 M-tile index per bucket
    tile_k: jnp.ndarray       # (T,) int32 K-tile index per bucket
    first_of_m: jnp.ndarray   # (T,) int32 1/0 flag
    dst_local: jnp.ndarray    # (T, eb) int32 dst offset inside the M-tile
    src_local: jnp.ndarray    # (T, eb) int32 src offset inside the K-tile
    eids: jnp.ndarray         # (T, eb) int32 original edge ids (0 pad)
    mask: jnp.ndarray         # (T, eb) bool
    bm: int = dataclasses.field(metadata={"static": True})
    bk: int = dataclasses.field(metadata={"static": True})
    eb: int = dataclasses.field(metadata={"static": True})
    n_dst: int = dataclasses.field(metadata={"static": True})
    n_src: int = dataclasses.field(metadata={"static": True})
    n_tiles_m: int = dataclasses.field(metadata={"static": True})
    n_tiles_k: int = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return ((self.tile_m, self.tile_k, self.first_of_m, self.dst_local,
                 self.src_local, self.eids, self.mask),
                (self.bm, self.bk, self.eb, self.n_dst, self.n_src,
                 self.n_tiles_m, self.n_tiles_k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        tm, tk, fom, dl, sl, eids, mask = children
        bm, bk, eb, n_dst, n_src, ntm, ntk = aux
        return cls(tile_m=tm, tile_k=tk, first_of_m=fom, dst_local=dl,
                   src_local=sl, eids=eids, mask=mask, bm=bm, bk=bk, eb=eb,
                   n_dst=n_dst, n_src=n_src, n_tiles_m=ntm, n_tiles_k=ntk)

    @property
    def n_buckets(self) -> int:
        return int(self.tile_m.shape[0])


def build_tiles(g: Graph, bm: int = 128, bk: int = 128,
                eb: int = 256) -> TilePack:
    """Bucket edges of ``g`` by (dst//bm, src//bk) tile pair."""
    src = np.asarray(g.src, dtype=np.int64)
    dst = np.asarray(g.dst, dtype=np.int64)
    eid = np.asarray(g.eid, dtype=np.int64)

    n_tiles_m = max(1, -(-g.n_dst // bm))
    n_tiles_k = max(1, -(-g.n_src // bk))

    mi = dst // bm
    ki = src // bk
    # sort edges by (mi, ki, dst, src): groups buckets, keeps the paper's
    # ascending-address stream inside each bucket.
    order = np.lexsort((src, dst, ki, mi))
    src, dst, eid, mi, ki = src[order], dst[order], eid[order], mi[order], ki[order]

    bucket_key = mi * n_tiles_k + ki
    # split points where bucket changes
    if len(bucket_key):
        change = np.nonzero(np.diff(bucket_key))[0] + 1
        seg_starts = np.concatenate([[0], change])
        seg_ends = np.concatenate([change, [len(bucket_key)]])
    else:
        seg_starts = np.array([0])
        seg_ends = np.array([0])

    t_m, t_k, starts, lens = [], [], [], []
    for s, e in zip(seg_starts, seg_ends):
        if e <= s:
            continue
        for cs in range(s, e, eb):
            t_m.append(mi[s])
            t_k.append(ki[s])
            starts.append(cs)
            lens.append(min(eb, e - cs))
    T = max(len(t_m), 1)

    dl = np.zeros((T, eb), np.int32)
    sl = np.zeros((T, eb), np.int32)
    ei = np.zeros((T, eb), np.int32)
    mask = np.zeros((T, eb), bool)
    tm_arr = np.zeros((T,), np.int32)
    tk_arr = np.zeros((T,), np.int32)
    for i, (m, k, s, ln) in enumerate(zip(t_m, t_k, starts, lens)):
        dl[i, :ln] = (dst[s:s + ln] - m * bm)
        sl[i, :ln] = (src[s:s + ln] - k * bk)
        ei[i, :ln] = eid[s:s + ln]
        mask[i, :ln] = True
        tm_arr[i] = m
        tk_arr[i] = k

    first = np.zeros((T,), np.int32)
    seen = set()
    for i in range(T):
        if int(tm_arr[i]) not in seen:
            first[i] = 1
            seen.add(int(tm_arr[i]))

    return TilePack(
        tile_m=jnp.asarray(tm_arr), tile_k=jnp.asarray(tk_arr),
        first_of_m=jnp.asarray(first), dst_local=jnp.asarray(dl),
        src_local=jnp.asarray(sl), eids=jnp.asarray(ei),
        mask=jnp.asarray(mask), bm=bm, bk=bk, eb=eb,
        n_dst=g.n_dst, n_src=g.n_src,
        n_tiles_m=n_tiles_m, n_tiles_k=n_tiles_k)

"""Training-grade aggregation: blocked pull in BOTH directions.

Autodiff of a gather-based pull produces a scatter-add backward — the
push pathology the paper removed from the forward sneaks back into
training. But the adjoint of Copy-Reduce is Copy-Reduce on the REVERSE
graph (the paper makes exactly this observation for Embedding: backward
is scatter-reduce ≡ CR). ``weighted_copy_reduce`` wires it up with a
``custom_vjp``:

  forward:   out[v] = Σ_{e=(u→v)} w_e · x[u]       blocked pull on G
  ∂x:        dx[u]  = Σ_{e=(u→v)} w_e · ct[v]      blocked pull on Gᵀ
  ∂w:        dw[e]  = ⟨x[u_e], ct[v_e]⟩            per-edge dot (gathers)

Both directions use the degree-bucketed ELL packs carried by
:class:`TrainingGraph`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .graph import Graph, from_coo, reverse
from .planner import get_plan_cache
from .tiling import ELLPack
from . import strategies as S

__all__ = ["TrainingGraph", "make_training_graph", "weighted_copy_reduce"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class TrainingGraph:
    """Graph + reverse graph + blocked packs for both directions."""
    g: Graph
    g_rev: Graph
    ell: ELLPack
    ell_rev: ELLPack

    def tree_flatten(self):
        return ((self.g, self.g_rev, self.ell, self.ell_rev), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_training_graph(g: Graph, width_cap: int = 64) -> TrainingGraph:
    """Packs come from the per-graph :class:`PlanCache`, so the forward
    ELL is shared with direct ``gspmm(strategy="auto"/"ell")`` calls and
    built at most once per process."""
    g_rev = reverse(g)
    return TrainingGraph(g=g, g_rev=g_rev,
                         ell=get_plan_cache(g).ell(width_cap),
                         ell_rev=get_plan_cache(g_rev).ell(width_cap))


def _pull_weighted(g: Graph, pack: ELLPack, x, w):
    """Blocked-pull Σ w_e x[src_e] into destinations. w: (n_edges,1)."""
    def msg_fn(cls):
        vals = jnp.take(x, cls.chunk_cols, axis=0)        # (C, W, d)
        ws = jnp.take(w, cls.chunk_eids, axis=0)          # (C, W, 1)
        return vals * ws

    out = S.pull_ell_reduce(pack, msg_fn, "sum", deg=g.in_degrees)
    # bf16 x against fp32 norm weights promotes the message stream (and
    # thus the reduce) to fp32 — keep that accumulation, but hand back
    # the feature dtype so half-precision forwards stay half precision
    if (jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(out.dtype, jnp.floating)
            and out.dtype != x.dtype):
        out = out.astype(x.dtype)
    return out


@partial(jax.custom_vjp, nondiff_argnums=())
def weighted_copy_reduce(tg: TrainingGraph, x: jnp.ndarray,
                         w: jnp.ndarray) -> jnp.ndarray:
    """out[v] = Σ_{(u→v)=e} w[e]·x[u] — blocked pull fwd AND bwd.

    ``x``: (n_src, d); ``w``: (n_edges, 1) caller edge order (pass ones
    for plain CR-sum).
    """
    return _pull_weighted(tg.g, tg.ell, x, w)


def _wcr_fwd(tg, x, w):
    return _pull_weighted(tg.g, tg.ell, x, w), (tg, x, w)


def _wcr_bwd(res, ct):
    tg, x, w = res
    # ∂x: pull over the reverse graph (edge ids preserved by reverse())
    dx = _pull_weighted(tg.g_rev, tg.ell_rev, ct, w).astype(x.dtype)
    # ∂w: per-edge dot in caller edge order
    g = tg.g
    dot = jnp.sum(jnp.take(x, g.src, axis=0)
                  * jnp.take(ct, g.dst, axis=0), axis=-1, keepdims=True)
    dw = jnp.take(dot, g.eid_inv, axis=0).astype(w.dtype)
    return None, dx, dw


weighted_copy_reduce.defvjp(_wcr_fwd, _wcr_bwd)

"""repro.data — synthetic datasets and samplers."""
from .synthetic import (rmat_graph, sbm_graph, bipartite_ratings,
                        planted_node_labels, make_node_dataset, DATASETS,
                        relational_graph)
from .sampler import NeighborSampler

__all__ = [
    "rmat_graph", "sbm_graph", "bipartite_ratings", "planted_node_labels",
    "make_node_dataset", "DATASETS", "relational_graph", "NeighborSampler",
]

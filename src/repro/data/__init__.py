"""repro.data — synthetic datasets and samplers."""
from .synthetic import (rmat_graph, sbm_graph, bipartite_ratings,
                        planted_node_labels, make_node_dataset, DATASETS,
                        relational_graph)
from .sampler import NeighborSampler, SampledBlock, MiniBatch
from .pipeline import (Prefetcher, prefetch, SignatureTracker,
                       ServeRequest, RequestQueue)

__all__ = [
    "rmat_graph", "sbm_graph", "bipartite_ratings", "planted_node_labels",
    "make_node_dataset", "DATASETS", "relational_graph", "NeighborSampler",
    "SampledBlock", "MiniBatch", "Prefetcher", "prefetch",
    "SignatureTracker", "ServeRequest", "RequestQueue",
]

"""Host-side minibatch pipeline: prefetch + compile-cache accounting.

Sampling runs on the host (numpy) while the train step runs on the
device — the classic overlap. :class:`Prefetcher` keeps ``depth``
minibatches in flight on a daemon thread (``depth=2`` is the
double-buffer: one batch being consumed, one being sampled), so the
host sampler hides behind device time instead of serializing with it.

:class:`SignatureTracker` watches the static shape signatures of the
minibatches that reach the jitted step. The sampler pads every batch to
one signature per configuration, so the tracker is both documentation
and a tripwire: if a code change ever lets shapes vary per batch (→ a
recompile per batch), ``assert_bounded`` fails loudly instead of the
run silently crawling.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["Prefetcher", "prefetch", "SignatureTracker",
           "ServeRequest", "RequestQueue"]

_DONE = object()


class Prefetcher:
    """Iterator wrapper that materializes up to ``depth`` items ahead.

    Exceptions raised by the producer are re-raised at the consumer's
    ``next()`` call site; the thread is a daemon, so an abandoned
    prefetcher never blocks interpreter exit.
    """

    def __init__(self, it: Iterable, depth: int = 2):
        if depth < 1:
            raise ValueError("prefetch depth must be ≥ 1")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err = None
        self._closed = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, args=(iter(it),),
                                        daemon=True)
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                # bounded put that notices close(): never leaves the
                # producer blocked (and then hard-killed mid-XLA-call)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:          # propagate to the consumer
            self._err = e
        finally:
            # the sentinel must not be dropped on a full queue (the
            # consumer would block forever) — same stop-aware put
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def close(self) -> None:
        """Stop the producer and drain — call when abandoning the
        iterator early (e.g. a capped batch loop). A closed iterator is
        exhausted: further ``next()`` raises StopIteration."""
        self._closed = True
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self._q.get()
        if item is _DONE:
            # re-queue the sentinel: exhausted iterators must keep
            # raising StopIteration instead of blocking a later next()
            try:
                self._q.put_nowait(_DONE)
            except queue.Full:
                pass
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def prefetch(it: Iterable, depth: int = 2) -> Iterator:
    """Double-buffered (by default) background iteration over ``it``."""
    return Prefetcher(it, depth=depth)


# SignatureTracker lives in repro.obs.signatures (the shared
# train/serve accounting path); re-exported here for compatibility.
from ..obs.signatures import SignatureTracker  # noqa: E402,F401


class ServeRequest:
    """One in-flight inference request: node ids in, a future out.

    Requesters block in :meth:`result`; the serving loop fulfils via
    :meth:`set_result` / :meth:`set_error`. ``t_submit`` lets the
    latency benchmark split queueing delay from compute.
    """

    __slots__ = ("rid", "ids", "t_submit", "_event", "_result", "_error",
                 "_lock")

    def __init__(self, rid: int, ids: np.ndarray):
        self.rid = rid
        self.ids = ids
        self.t_submit = time.perf_counter()
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()

    def set_result(self, value) -> bool:
        """Resolve the future — first caller wins (the serving loop and
        a closing queue may race to settle the same request; the loser
        is a no-op, never an overwrite). Returns whether this call won."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = value
            self._event.set()
            return True

    def set_error(self, err: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = err
            self._event.set()
            return True

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served within "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class RequestQueue:
    """Concurrent request intake, iterable as coalescing windows.

    Requester threads :meth:`submit` node-id lists and block on the
    returned :class:`ServeRequest`. Iteration yields *lists* of
    requests: each ``next()`` blocks for the first request, then keeps
    draining until ``max_nodes`` total node ids are queued or
    ``max_wait`` seconds pass — the batching window. The iterator is
    exactly the shape :class:`Prefetcher` wraps, so window assembly
    overlaps the device step the same way sampling overlaps training
    (``prefetch(request_queue)`` in ``GNNServer.run``).
    """

    def __init__(self, max_nodes: Optional[int] = None,
                 max_wait: float = 0.002):
        self.max_nodes = max_nodes
        self.max_wait = float(max_wait)
        self._q: "queue.Queue" = queue.Queue()
        self._rid = itertools.count()
        self._closed = threading.Event()

    def submit(self, node_ids: Sequence[int]) -> ServeRequest:
        if self._closed.is_set():
            raise RuntimeError("request queue is closed")
        ids = np.asarray(node_ids, np.int64).reshape(-1)
        req = ServeRequest(next(self._rid), ids)
        self._q.put(req)
        return req

    def close(self, cancel_pending: bool = False) -> None:
        """No more submissions; pending requests still drain, then the
        serving loop's iteration ends.

        With ``cancel_pending=True`` queued-but-unserved requests are
        resolved immediately with a "queue closed" error instead of
        drained — their blocked ``result()`` callers wake up right away
        (set_result/set_error are first-wins, so a request the loop
        already served is untouched).
        """
        self._closed.set()
        self._q.put(_DONE)
        if cancel_pending:
            self._drain_error()

    def _drain_error(self) -> None:
        """Error out every queued request and leave one ``_DONE`` behind
        so iteration keeps terminating. Without this, a request that
        raced into the queue behind the shutdown sentinel would never be
        resolved and its ``result()`` caller would hang forever."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _DONE:
                continue
            item.set_error(RuntimeError(
                f"request {item.rid} dropped: queue closed"))
        self._q.put(_DONE)

    def __iter__(self):
        return self

    def __next__(self) -> List[ServeRequest]:
        # block for the window's first request (or shutdown)
        first = self._q.get()
        if first is _DONE:
            # iteration is over: anything still queued (submissions that
            # raced in behind the sentinel) will never be served — fail
            # their futures instead of leaving requesters blocked
            self._drain_error()     # re-queues _DONE for later next()
            raise StopIteration
        window = [first]
        n = len(first.ids)
        deadline = time.perf_counter() + self.max_wait
        while self.max_nodes is None or n < self.max_nodes:
            wait = deadline - time.perf_counter()
            if wait <= 0:
                break
            try:
                req = self._q.get(timeout=wait)
            except queue.Empty:
                break
            if req is _DONE:
                self._q.put(_DONE)  # flush this window, end on the next
                break
            window.append(req)
            n += len(req.ids)
        return window

"""Host-side minibatch pipeline: prefetch + compile-cache accounting.

Sampling runs on the host (numpy) while the train step runs on the
device — the classic overlap. :class:`Prefetcher` keeps ``depth``
minibatches in flight on a daemon thread (``depth=2`` is the
double-buffer: one batch being consumed, one being sampled), so the
host sampler hides behind device time instead of serializing with it.

:class:`SignatureTracker` watches the static shape signatures of the
minibatches that reach the jitted step. The sampler pads every batch to
one signature per configuration, so the tracker is both documentation
and a tripwire: if a code change ever lets shapes vary per batch (→ a
recompile per batch), ``assert_bounded`` fails loudly instead of the
run silently crawling.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterable, Iterator, Set, Tuple

__all__ = ["Prefetcher", "prefetch", "SignatureTracker"]

_DONE = object()


class Prefetcher:
    """Iterator wrapper that materializes up to ``depth`` items ahead.

    Exceptions raised by the producer are re-raised at the consumer's
    ``next()`` call site; the thread is a daemon, so an abandoned
    prefetcher never blocks interpreter exit.
    """

    def __init__(self, it: Iterable, depth: int = 2):
        if depth < 1:
            raise ValueError("prefetch depth must be ≥ 1")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err = None
        self._closed = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, args=(iter(it),),
                                        daemon=True)
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                # bounded put that notices close(): never leaves the
                # producer blocked (and then hard-killed mid-XLA-call)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:          # propagate to the consumer
            self._err = e
        finally:
            # the sentinel must not be dropped on a full queue (the
            # consumer would block forever) — same stop-aware put
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def close(self) -> None:
        """Stop the producer and drain — call when abandoning the
        iterator early (e.g. a capped batch loop). A closed iterator is
        exhausted: further ``next()`` raises StopIteration."""
        self._closed = True
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self._q.get()
        if item is _DONE:
            # re-queue the sentinel: exhausted iterators must keep
            # raising StopIteration instead of blocking a later next()
            try:
                self._q.put_nowait(_DONE)
            except queue.Full:
                pass
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def prefetch(it: Iterable, depth: int = 2) -> Iterator:
    """Double-buffered (by default) background iteration over ``it``."""
    return Prefetcher(it, depth=depth)


class SignatureTracker:
    """Counts distinct static shape signatures seen by a jitted step."""

    def __init__(self, limit: int = 4):
        self.limit = limit
        self.seen: Set[Tuple] = set()

    def observe(self, signature: Tuple) -> bool:
        """Record a signature; True if it is new (⇒ a fresh compile)."""
        new = signature not in self.seen
        self.seen.add(signature)
        return new

    def assert_bounded(self) -> None:
        if len(self.seen) > self.limit:
            raise RuntimeError(
                f"{len(self.seen)} distinct minibatch shape signatures "
                f"(> {self.limit}): static padding is broken, every batch "
                f"recompiles the train step")

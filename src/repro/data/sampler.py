"""Neighbor sampling for batched (sampled) GNN training — paper Fig. 3.

Produces fixed-shape (padded) mini-batch blocks so a single jitted train
step serves every batch: per layer l, a bipartite block graph from sampled
frontier nodes to the previous frontier. Two padding devices keep every
array shape static:

* node pads go into a trailing *dummy source slot* whose features are
  zero (``feats_fn`` maps global id -1 to a zero row);
* edge pads go into a trailing *dummy destination row*, so real rows'
  in-degrees — and therefore mean aggregation — are untouched.

Each block also carries the dense uniform neighbor table of
:class:`repro.core.blocks.BlockGraph` (built here for free from the
per-row sample lists), which is what the planner's blocked-pull strategy
consumes, plus per-edge GCN normalization weights gathered from the
FULL graph's degrees (pad edges get weight 0, so they contribute
exactly zero to weighted aggregation).

Alongside the forward table the sampler emits the block's *reverse
table* — the same edge list sorted (stably) by source slot — which is
what the reverse-block VJP pulls over to compute ∂x without a scatter
(core/blocks.py, DESIGN.md §7). Pad edges sort last (dummy source slot)
and keep pointing at the dummy destination row, so the table is
pad-poison safe by construction: a zero cotangent row masks them out.

Sampling is uniform WITHOUT replacement; a node with in-degree ≤ fanout
keeps all its in-edges — so with ``fanout ≥ max in-degree`` the blocks
reproduce the full graph exactly (tests/data/test_sampler.py holds the
sampled forward to the full-graph forward under that condition).

:class:`SampledBlock` and :class:`MiniBatch` are registered pytrees:
a whole minibatch is passed straight into a jitted train step, and its
static aux (padded sizes, fanout) keys the compilation cache — one
compile per sampler configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.blocks import BlockGraph
from ..core.graph import Graph, from_coo


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class SampledBlock:
    """One bipartite layer of a minibatch (outer hop = larger side).

    ``bg`` holds the padded block graph + uniform neighbor table + the
    src-sorted reverse table (the gather backward's lookup structure);
    ``src_ids`` the global node id per source slot (-1 = pad);
    ``gcn_norm`` per-edge 1/√(deg_out(u)·deg_in(v)) from the FULL
    graph's degrees, caller edge order, 0 on pad edges.

    Relational sampling (``NeighborSampler(..., edge_rel=...)``,
    DESIGN.md §8.5) additionally tags every sampled edge: ``rel`` is
    the relation id (0 on pad edges — harmless, pads point at the
    dummy destination row) and ``rel_norm`` the per-(destination,
    relation) sampled-mean weight 1/|sampled N_r(v)| (0 on pads), both
    in caller edge order — what ``hetero_block_gspmm`` consumes.
    """
    bg: BlockGraph
    src_ids: jnp.ndarray        # (n_src_pad,) int32 global ids, -1 = pad
    gcn_norm: jnp.ndarray       # (n_edges_pad,) float32, 0 on pads
    rel: Optional[jnp.ndarray] = None       # (n_edges_pad,) int32
    rel_norm: Optional[jnp.ndarray] = None  # (n_edges_pad,) float32

    @property
    def graph(self) -> Graph:   # back-compat view
        return self.bg.g

    def tree_flatten(self):
        return ((self.bg, self.src_ids, self.gcn_norm, self.rel,
                 self.rel_norm), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class MiniBatch:
    """Blocks (outermost hop first) + seeds. ``label_mask`` is False on
    pad seeds (short final batch padded up to the static batch size) —
    the train step masks their loss rows out."""
    blocks: Tuple[SampledBlock, ...]
    input_ids: jnp.ndarray      # (n_input_pad,) global node ids, -1 = pad
    seed_ids: jnp.ndarray       # (batch,) global seed ids, -1 = pad
    labels: jnp.ndarray         # (batch,) pad rows hold 0
    label_mask: jnp.ndarray     # (batch,) bool

    def tree_flatten(self):
        return ((self.blocks, self.input_ids, self.seed_ids, self.labels,
                 self.label_mask), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def shape_signature(self) -> Tuple:
        """Static padded-shape signature — identical for every batch of
        one sampler configuration (bounded jit compilations)."""
        return tuple(b.bg.signature for b in self.blocks)


class NeighborSampler:
    """Uniform without-replacement neighbor sampler over incoming edges.

    Sampling is fully vectorized: one batched random-key draw per layer
    (argsorted per row — a uniform without-replacement sample of each
    row's incoming edge slots) instead of a Python loop per destination.
    The stream is deterministic per seed: the same seed replays the
    same batches bit for bit (tests/data/test_sampler.py).
    """

    def __init__(self, g: Graph, fanouts: Sequence[int], batch_size: int,
                 seed: int = 0, edge_rel=None):
        self.indptr = np.asarray(g.indptr_dst, np.int64)
        self.src = np.asarray(g.src, np.int64)
        # relational sampling: per-edge relation ids (caller order) →
        # canonical order, so a sampled edge slot looks its type up
        # directly; blocks then carry rel + per-(dst, rel) mean norms
        if edge_rel is not None:
            edge_rel = np.asarray(edge_rel, np.int64)
            self.rel = edge_rel[np.asarray(g.eid)]
            self.n_rel = int(edge_rel.max()) + 1 if edge_rel.size else 0
        else:
            self.rel = None
            self.n_rel = 0
        self.fanouts = list(fanouts)
        self.batch_size = batch_size
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.n = g.n_dst
        self.n_nodes = max(g.n_src, g.n_dst)
        # persistent generation-stamped slot table: sample() maps global
        # node ids to block-local slots in O(touched) per layer instead
        # of allocating/clearing an O(n_nodes) array per call
        self._slot = np.zeros(self.n_nodes, np.int64)
        self._slot_gen = np.zeros(self.n_nodes, np.int64)
        self._gen = 0
        # full-graph degrees for GCN-style symmetric normalization
        self.deg_in = np.maximum(np.asarray(g.in_degrees, np.float64), 1)
        self.deg_out = np.maximum(np.asarray(g.out_degrees, np.float64), 1)
        # label masks depend only on the real-seed count (at most two
        # values per epoch: full batches + one short tail) — cache the
        # device arrays instead of re-building/re-uploading per batch
        self._mask_cache: dict = {}
        # static padded sizes per layer (innermost = batch itself)
        self.layer_sizes = [batch_size]
        for f in reversed(self.fanouts):
            self.layer_sizes.append(self.layer_sizes[-1] * (f + 1))

    def reset(self, seed: Optional[int] = None) -> None:
        """Re-seed the sampling stream (determinism: same seed ⇒ same
        batches, bit for bit)."""
        self.rng = np.random.default_rng(self.seed if seed is None
                                         else seed)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _sample_layer(indptr, rng, frontier: np.ndarray, fanout: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched uniform without-replacement draw for one layer.

        One random-key matrix per layer: each row gets a key per
        candidate edge slot (∞ past its degree); the argsort's first
        ``min(deg, fanout)`` columns are a uniform without-replacement
        sample of that row's incoming edge slots — all of them when the
        degree fits the fanout. Returns ``(kmask, eslot, take)`` with
        ``kmask``: (n_rows, fanout) valid-sample mask, ``eslot``:
        (n_rows, fanout) global edge slots (garbage where masked).
        """
        n_rows = len(frontier)
        valid = frontier >= 0
        safe = np.where(valid, frontier, 0)
        lo = indptr[safe]
        deg = np.where(valid, indptr[safe + 1] - lo, 0)
        take = np.minimum(deg, fanout)
        # rows whose degree fits keep every in-edge in CSR order — no
        # randomness; only over-fanout rows draw keys, grouped into
        # power-of-two degree classes so the key matrix width tracks
        # each class, not the global max degree (power-law graphs put a
        # handful of huge rows next to thousands of small ones)
        pos = np.broadcast_to(np.arange(fanout, dtype=np.int64),
                              (n_rows, fanout)).copy()
        big = np.nonzero(deg > fanout)[0]
        if len(big):
            cls = np.ceil(np.log2(deg[big])).astype(np.int64)
            for c in np.unique(cls):
                r = big[cls == c]
                K = int(deg[r].max())
                keys = rng.random((len(r), K))
                keys[np.arange(K)[None, :] >= deg[r][:, None]] = np.inf
                pos[r] = np.argpartition(keys, fanout - 1,
                                         axis=1)[:, :fanout]
        kmask = np.arange(fanout)[None, :] < take[:, None]
        return kmask, lo[:, None] + pos, take

    def sample(self, seeds: np.ndarray, labels: np.ndarray,
               rng: Optional[np.random.Generator] = None) -> MiniBatch:
        """Build fully static-shape (node- AND edge-padded) blocks.

        Each block graph has ``n_dst + 1`` destination rows; padded edges
        point at the extra dummy row, so real rows are untouched and a
        single jitted step serves every batch. Consumers slice
        ``[:n_dst]`` (``block_gspmm`` does it internally).
        """
        if rng is None:
            rng = self.rng
        seeds = np.asarray(seeds, np.int64)
        labels = np.asarray(labels, np.int64)
        n_real_seeds = len(seeds)
        if len(seeds) < self.batch_size:     # short final batch: pad seeds
            pad = self.batch_size - len(seeds)
            seeds = np.concatenate([seeds, np.full(pad, -1, np.int64)])
            labels = np.concatenate([labels, np.zeros(pad, np.int64)])
        label_mask = self._mask_cache.get(n_real_seeds)
        if label_mask is None:
            label_mask = jnp.asarray(
                np.arange(self.batch_size) < n_real_seeds)
            self._mask_cache[n_real_seeds] = label_mask

        blocks: List[SampledBlock] = []
        frontier = seeds
        for li, fanout in enumerate(reversed(self.fanouts)):
            n_dst = self.layer_sizes[li]
            n_src_pad = self.layer_sizes[li + 1]
            n_edges_pad = n_dst * fanout
            kmask, eslot, _ = self._sample_layer(self.indptr, rng,
                                                 frontier, fanout)
            # real sampled edges in row-major (canonical) order
            jj, kk = np.nonzero(kmask)
            nbs = self.src[eslot[jj, kk]]
            # dst-first source numbering: src slot j == dst node j, so a
            # layer can read its destinations' own features as h[:n_dst].
            # First-occurrence slot table (reversed writes: first wins);
            # a stamp != current generation means "unassigned".
            self._gen += 1
            slot, gen = self._slot, self._slot_gen
            idxs = np.nonzero(frontier >= 0)[0]
            fv = frontier[idxs][::-1]
            slot[fv] = idxs[::-1]
            gen[fv] = self._gen
            # newly discovered neighbors, in first-occurrence order
            new_vals = nbs[gen[nbs] != self._gen]
            uvals, first = np.unique(new_vals, return_index=True)
            new_unique = uvals[np.argsort(first, kind="stable")]
            slot[new_unique] = n_dst + np.arange(len(new_unique))
            gen[new_unique] = self._gen
            n_real_src = n_dst + len(new_unique)
            # pad sources to static size; dummy source = last slot
            src_ids = np.concatenate([
                frontier, new_unique,
                np.full(n_src_pad - n_real_src, -1, np.int64)])
            srcs = slot[nbs]
            n_real = len(jj)
            nbr = np.full((n_dst, fanout), n_src_pad - 1, np.int32)
            nbr[jj, kk] = srcs
            nbr_eid = np.zeros((n_dst, fanout), np.int32)
            nbr_eid[jj, kk] = np.arange(n_real, dtype=np.int32)
            nbr_mask = kmask
            norms = (1.0 / np.sqrt(self.deg_out[nbs]
                                   * self.deg_in[frontier[jj]]))
            # pad edges into the dummy destination row n_dst (never any
            # real source slot: a pad edge exists only when some row is
            # under fanout, which leaves the dummy source slot free)
            pad = n_edges_pad - n_real
            srcs = np.concatenate([srcs,
                                   np.full(pad, n_src_pad - 1, np.int64)])
            dsts = np.concatenate([jj, np.full(pad, n_dst, np.int64)])
            norms = np.concatenate([norms,
                                    np.zeros(pad)]).astype(np.float32)
            # pad slots of the neighbor table index SOME valid edge id;
            # they are masked, so the value never reaches a reduction
            nbr_eid[~nbr_mask] = min(n_real, n_edges_pad - 1)
            real_deg = nbr_mask.sum(axis=1).astype(np.int32)
            # reverse table: the same edge list stably sorted by source
            # slot — what the gather backward pulls over. Pad edges
            # (dummy source = last slot) sort last; their dst is the
            # dummy row, so a zero cotangent row masks them exactly.
            rev_eid = np.argsort(srcs, kind="stable").astype(np.int32)
            rev_src = srcs[rev_eid].astype(np.int32)
            rev_dst = dsts[rev_eid].astype(np.int32)
            g = from_coo(srcs, dsts, n_src=n_src_pad, n_dst=n_dst + 1)
            bg = BlockGraph(g=g, nbr=jnp.asarray(nbr),
                            nbr_eid=jnp.asarray(nbr_eid),
                            nbr_mask=jnp.asarray(nbr_mask),
                            real_deg=jnp.asarray(real_deg),
                            n_dst_real=n_dst, fanout=fanout,
                            rev_src=jnp.asarray(rev_src),
                            rev_dst=jnp.asarray(rev_dst),
                            rev_eid=jnp.asarray(rev_eid))
            rel_blk = rel_norm = None
            if self.rel is not None:
                # relation id per sampled edge + the per-(dst, relation)
                # sampled-mean weight 1/|sampled N_r(v)|; pad edges get
                # rel 0 / weight 0 (they point at the dummy row anyway)
                rel_e = self.rel[eslot[jj, kk]]
                key = jj * self.n_rel + rel_e
                cnt = np.bincount(key,
                                  minlength=n_dst * max(self.n_rel, 1))
                rel_blk = jnp.asarray(np.concatenate(
                    [rel_e, np.zeros(pad, np.int64)]), jnp.int32)
                rel_norm = jnp.asarray(np.concatenate(
                    [1.0 / cnt[key],
                     np.zeros(pad)]).astype(np.float32))
            blocks.append(SampledBlock(
                bg=bg, src_ids=jnp.asarray(src_ids, jnp.int32),
                gcn_norm=jnp.asarray(norms), rel=rel_blk,
                rel_norm=rel_norm))
            frontier = src_ids
        blocks.reverse()
        return MiniBatch(blocks=tuple(blocks),
                         input_ids=blocks[0].src_ids,
                         seed_ids=jnp.asarray(seeds, jnp.int32),
                         labels=jnp.asarray(labels, jnp.int32),
                         label_mask=jnp.asarray(label_mask))

    def batches(self, node_ids: np.ndarray, labels: np.ndarray,
                drop_last: bool = True) -> Iterator[MiniBatch]:
        """Shuffled minibatches. With ``drop_last=False`` the short final
        batch is padded up to ``batch_size`` (masked via ``label_mask``)
        so even the tail reuses the one compiled step.

        The whole epoch is drawn from a child RNG seeded EAGERLY (one
        draw from the sampler stream per call, before the generator
        runs), so a prefetch thread abandoned mid-epoch can never leave
        the shared stream in a timing-dependent state — epoch k's
        batches depend only on the seed and k, bit for bit.
        """
        node_ids = np.asarray(node_ids)
        labels = np.asarray(labels)
        child = np.random.default_rng(int(self.rng.integers(2 ** 63)))

        def gen() -> Iterator[MiniBatch]:
            order = child.permutation(len(node_ids))
            stop = (len(order) - self.batch_size + 1 if drop_last
                    else len(order))
            for s in range(0, stop, self.batch_size):
                idx = order[s:s + self.batch_size]
                yield self.sample(node_ids[idx], labels[idx], rng=child)

        return gen()

"""Neighbor sampling for batched (sampled) GraphSAGE — paper Fig. 3.

Produces fixed-shape (padded) mini-batch blocks so a single jitted train
step serves every batch: per layer l, a bipartite block graph from sampled
frontier nodes to the previous frontier. Padding uses a dedicated dummy
node whose features are zero, so padded edges contribute nothing to mean
aggregation (mask-corrected degree).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from ..core.graph import Graph, from_coo


@dataclasses.dataclass
class SampledBlock:
    graph: Graph                 # bipartite: src = layer-l nodes, dst = layer-(l+1) seeds
    src_ids: np.ndarray          # (n_src_pad,) global ids (dummy = -1)


@dataclasses.dataclass
class MiniBatch:
    blocks: List[SampledBlock]   # outermost hop first
    input_ids: np.ndarray        # (n_input_pad,) global node ids, -1 = pad
    seed_ids: np.ndarray         # (batch,) global seed ids
    labels: np.ndarray           # (batch,)


class NeighborSampler:
    """Uniform neighbor sampler over CSC (incoming edges per node)."""

    def __init__(self, g: Graph, fanouts: Sequence[int], batch_size: int,
                 seed: int = 0):
        self.indptr = np.asarray(g.indptr_dst, np.int64)
        self.src = np.asarray(g.src, np.int64)
        self.fanouts = list(fanouts)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.n = g.n_dst
        # static padded sizes per layer
        self.layer_sizes = [batch_size]
        for f in reversed(self.fanouts):
            self.layer_sizes.append(self.layer_sizes[-1] * (f + 1))

    def sample(self, seeds: np.ndarray, labels: np.ndarray) -> MiniBatch:
        """Build fully static-shape (node- AND edge-padded) blocks.

        Each block graph has ``n_dst + 1`` destination rows; padded edges
        point at the extra dummy row, so real rows are untouched and a
        single jitted step serves every batch. Consumers slice ``[:n_dst]``.
        """
        blocks: List[SampledBlock] = []
        frontier = seeds
        for li, fanout in enumerate(reversed(self.fanouts)):
            n_dst = self.layer_sizes[li]
            n_src_pad = self.layer_sizes[li + 1]
            n_edges_pad = n_dst * fanout
            srcs, dsts = [], []
            # dst-first source numbering: src slot j == dst node j, so a
            # layer can read its destinations' own features as h[:n_dst]
            src_ids = list(frontier)
            uniq: dict = {int(n): j for j, n in enumerate(frontier)
                          if n >= 0}
            for j, node in enumerate(frontier):
                if node < 0:
                    continue
                lo, hi = self.indptr[node], self.indptr[node + 1]
                deg = hi - lo
                if deg > 0:
                    take = self.rng.integers(lo, hi, size=min(fanout, deg))
                    for t in take:
                        nb = self.src[t]
                        if nb not in uniq:
                            uniq[nb] = len(src_ids)
                            src_ids.append(nb)
                        srcs.append(uniq[nb])
                        dsts.append(j)
            # pad sources to static size; dummy source = last slot
            n_real_src = len(src_ids)
            src_ids = np.asarray(src_ids + [-1] * (n_src_pad - n_real_src),
                                 np.int64)
            # pad edges into the dummy destination row n_dst
            pad = n_edges_pad - len(srcs)
            srcs = np.asarray(srcs + [n_src_pad - 1] * pad, np.int64)
            dsts = np.asarray(dsts + [n_dst] * pad, np.int64)
            g = from_coo(srcs, dsts, n_src=n_src_pad, n_dst=n_dst + 1)
            blocks.append(SampledBlock(graph=g, src_ids=src_ids))
            frontier = src_ids
        blocks.reverse()
        return MiniBatch(blocks=blocks, input_ids=blocks[0].src_ids,
                         seed_ids=seeds, labels=labels)

    def batches(self, node_ids: np.ndarray, labels: np.ndarray):
        order = self.rng.permutation(len(node_ids))
        for s in range(0, len(order) - self.batch_size + 1, self.batch_size):
            idx = order[s:s + self.batch_size]
            yield self.sample(node_ids[idx], labels[idx])

"""Synthetic graph datasets.

The paper benchmarks on Pubmed / Reddit / Amazon OGB-Products / BGS /
MovieLens-1M / SBM. Offline, we generate structurally-similar synthetic
stand-ins (RMAT power-law for the citation/social/product graphs, SBM for
LGNN, random bipartite for GC-MC, random typed edges for R-GCN) at
CPU-tractable scales. ``DATASETS`` maps preset names to (paper dataset,
scale note) — EXPERIMENTS.md reports which preset stands in for which
paper dataset.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..core.graph import Graph, from_coo, add_self_loops


def rmat_graph(n_log2: int, n_edges: int, seed: int = 0,
               a=0.57, b=0.19, c=0.19) -> Tuple[np.ndarray, np.ndarray, int]:
    """Vectorized R-MAT generator (power-law, Graph500-style)."""
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    d = 1.0 - a - b - c
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for level in range(n_log2):
        r = rng.random(n_edges)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(n_edges)
        dst_bit = np.where(src_bit == 0, (r2 >= a / (a + b)),
                           (r2 >= c / (c + d))).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    # dedup + drop self loops to look like a simple graph
    keep = src != dst
    src, dst = src[keep], dst[keep]
    pairs = np.unique(src * n + dst)
    return (pairs // n, pairs % n, n)


def sbm_graph(n: int, k: int, p_in: float, p_out: float, seed: int = 0
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stochastic block model. Returns (src, dst, communities)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, k, n)
    # dense Bernoulli is fine at LGNN scales (n <= few thousand)
    probs = np.where(comm[:, None] == comm[None, :], p_in, p_out)
    adj = rng.random((n, n)) < probs
    np.fill_diagonal(adj, False)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64), comm


def bipartite_ratings(n_users: int, n_items: int, n_ratings: int,
                      levels: int = 5, seed: int = 0):
    """MovieLens-like random bipartite rating graph.

    Ratings are planted from latent user/item factors so the GC-MC decoder
    has learnable structure. Returns (u, i, r) with r in [0, levels).
    """
    rng = np.random.default_rng(seed)
    pairs = rng.choice(n_users * n_items, size=n_ratings, replace=False)
    u, i = pairs // n_items, pairs % n_items
    fu = rng.normal(size=(n_users, 8))
    fi = rng.normal(size=(n_items, 8))
    score = np.einsum("ud,ud->u", fu[u], fi[i])
    edges = np.quantile(score, np.linspace(0, 1, levels + 1)[1:-1])
    r = np.digitize(score, edges)
    return u.astype(np.int64), i.astype(np.int64), r.astype(np.int64)


def relational_graph(n: int, n_rel: int, edges_per_rel: int, seed: int = 0):
    """BGS-like typed multigraph: list of (src, dst) per relation."""
    rng = np.random.default_rng(seed)
    rels = []
    for r in range(n_rel):
        src = rng.integers(0, n, edges_per_rel)
        dst = rng.integers(0, n, edges_per_rel)
        rels.append((src, dst))
    return rels


def planted_node_labels(g: Graph, feats: np.ndarray, n_classes: int,
                        seed: int = 0) -> np.ndarray:
    """Labels = argmax of (one-hop-smoothed features) @ random projection.

    Gives every GNN a learnable signal (features + structure) so training
    losses genuinely decrease in tests/benchmarks.
    """
    import jax.numpy as jnp
    from ..core.binary_reduce import copy_reduce
    rng = np.random.default_rng(seed)
    smooth = np.asarray(copy_reduce(g, jnp.asarray(feats), "mean"))
    w = rng.normal(size=(feats.shape[1], n_classes))
    logits = (feats[: g.n_dst] + smooth) @ w
    return np.argmax(logits, axis=1).astype(np.int64)


# preset -> (n_log2, edges, n_feat, n_classes) | stands in for paper dataset
DATASETS: Dict[str, dict] = {
    "pubmed-like": dict(n_log2=14, edges=45_000, n_feat=500, n_classes=3,
                        stands_for="Pubmed (19.7k nodes / 44k edges)"),
    "reddit-like": dict(n_log2=16, edges=600_000, n_feat=602, n_classes=41,
                        stands_for="Reddit (233k/11.6M, scaled ~16x down)"),
    "products-like": dict(n_log2=17, edges=1_200_000, n_feat=100,
                          n_classes=47,
                          stands_for="OGB-Products (2.4M/124M, scaled)"),
    "tiny": dict(n_log2=9, edges=3_000, n_feat=32, n_classes=5,
                 stands_for="smoke tests"),
}


def make_node_dataset(preset: str, seed: int = 0, self_loops: bool = True):
    """Returns (Graph, feats f32 (n,d), labels (n,), train/val masks)."""
    cfg = DATASETS[preset]
    src, dst, n = rmat_graph(cfg["n_log2"], cfg["edges"], seed=seed)
    if self_loops:
        src, dst = add_self_loops(src, dst, n)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(n, cfg["n_feat"])).astype(np.float32)
    labels = planted_node_labels(g, feats, cfg["n_classes"], seed=seed + 2)
    mask = rng.random(n)
    train_mask = mask < 0.6
    val_mask = (mask >= 0.6) & (mask < 0.8)
    return g, feats, labels, train_mask, val_mask, cfg["n_classes"]

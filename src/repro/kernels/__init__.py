"""Pallas TPU kernels for the paper's aggregation hot spots.

Each kernel lives in a subpackage with ``kernel.py`` (pl.pallas_call +
BlockSpec), ``ops.py`` (jitted wrapper) and ``ref.py`` (pure-jnp oracle).
On non-TPU backends the kernels run in interpret mode (see
``common.should_interpret``).
"""
from .spmm.ops import spmm
from .binary_reduce.ops import binary_reduce
from .edge_softmax.ops import edge_softmax, fused_attention
from .sddmm.ops import sddmm

__all__ = ["spmm", "binary_reduce", "edge_softmax", "fused_attention",
           "sddmm"]

"""Fused Binary-Reduce Pallas kernel: ``u_⊗_e_add_v`` (paper Alg. 4/5 → TPU).

Same bucket geometry as the SpMM kernel, plus an edge-feature block
streamed per bucket. Because buckets are contiguous runs of the
tile-sorted edge array, edge features pre-permuted to tile order arrive
via plain ``BlockSpec`` DMA — no in-kernel gather for the edge operand.
The node operand is gathered on the MXU via the one-hot trick. The ⊗
intermediate lives only in VMEM — this is the fusion the paper gets by
interleaving ⊗ with the reduction loop (its Alg. 4 line 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import onehot_gather_matrix, onehot_scatter_matrix

_BINOPS = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "copy_lhs": lambda a, b: a,
    "copy_rhs": lambda a, b: b,
}


def _br_kernel(tile_m_ref, tile_k_ref, first_ref,
               dst_ref, src_ref, mask_ref, e_ref, b_ref, out_ref,
               *, bm: int, bk: int, binop: str):
    t = pl.program_id(1)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst_local = dst_ref[0]
    src_local = src_ref[0]
    mask = mask_ref[0] != 0
    acc_t = jnp.float32

    G = onehot_gather_matrix(src_local, mask, bk, b_ref.dtype)
    u_vals = jax.lax.dot(G, b_ref[...], preferred_element_type=acc_t)
    e_vals = e_ref[...].astype(acc_t)                       # (eb, nd)
    msg = _BINOPS[binop](u_vals, e_vals)
    # padded slots may hold 0/0 etc. — zero them before the scatter matmul
    msg = jnp.where(mask[:, None], msg, jnp.zeros((), msg.dtype))
    S = onehot_scatter_matrix(dst_local, mask, bm, msg.dtype)
    out_ref[...] += jax.lax.dot(S, msg, preferred_element_type=acc_t
                                ).astype(out_ref.dtype)


def binary_reduce_pallas_call(T: int, eb: int, bm: int, bk: int, nd: int,
                              n_tiles_m: int, n_tiles_k: int, d_pad: int,
                              dtype, *, binop: str, interpret: bool):
    """Inputs: tile_m, tile_k, first (scalar prefetch); dst_local,
    src_local, mask (T,eb) int32; E_tiles (T*eb, d_pad) tile-ordered edge
    features; B (n_tiles_k*bk, d_pad). Output: C (n_tiles_m*bm, d_pad)."""
    n_nd = d_pad // nd
    grid = (n_nd, T)

    edge_map = lambda n, t, tm, tk, first: (t, 0)
    e_map = lambda n, t, tm, tk, first: (t, n)
    b_map = lambda n, t, tm, tk, first: (tk[t], n)
    out_map = lambda n, t, tm, tk, first: (tm[t], n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, eb), edge_map),
            pl.BlockSpec((1, eb), edge_map),
            pl.BlockSpec((1, eb), edge_map),
            pl.BlockSpec((eb, nd), e_map),
            pl.BlockSpec((bk, nd), b_map),
        ],
        out_specs=pl.BlockSpec((bm, nd), out_map),
    )
    kernel = functools.partial(_br_kernel, bm=bm, bk=bk, binop=binop)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles_m * bm, d_pad), dtype),
        interpret=interpret)

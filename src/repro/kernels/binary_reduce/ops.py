"""Jitted public wrapper for the fused Binary-Reduce Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.graph import Graph
from ...core.planner import get_plan_cache
from ...core.tiling import TilePack
from ..common import should_interpret
from .kernel import binary_reduce_pallas_call


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit,
                   static_argnames=("binop", "reduce_op", "nd", "interpret"))
def _br_packed(pack: TilePack, B: jnp.ndarray, E_tiles: jnp.ndarray,
               deg: Optional[jnp.ndarray], binop: str = "mul",
               reduce_op: str = "sum", nd: int = 128,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    T, eb = pack.dst_local.shape
    bm, bk = pack.bm, pack.bk
    d = B.shape[-1]
    nd = min(nd, _round_up(d, 128))
    d_pad = _round_up(d, nd)

    Bp = jnp.pad(B, ((0, pack.n_tiles_k * bk - B.shape[0]), (0, d_pad - d)))
    Ep = jnp.pad(E_tiles, ((0, 0), (0, d_pad - d)))

    call = binary_reduce_pallas_call(
        T=T, eb=eb, bm=bm, bk=bk, nd=nd,
        n_tiles_m=pack.n_tiles_m, n_tiles_k=pack.n_tiles_k, d_pad=d_pad,
        dtype=Bp.dtype, binop=binop,
        interpret=should_interpret() if interpret is None else interpret)

    out = call(pack.tile_m, pack.tile_k, pack.first_of_m,
               pack.dst_local, pack.src_local,
               pack.mask.astype(jnp.int32), Ep, Bp)
    out = out[: pack.n_dst, :d]
    if reduce_op == "mean":
        out = out / jnp.maximum(deg, 1).astype(out.dtype)[:, None]
    return out


def binary_reduce(g: Graph, B: jnp.ndarray, E: jnp.ndarray,
                  binop: str = "mul", reduce_op: str = "sum",
                  tiles: Optional[TilePack] = None, nd: int = 128,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused ``u_⊗_e_add_v``: ``C[v] = Σ_(u→v)=e B[u] ⊗ E[e]``.

    ``E``: (n_edges, d) or (n_edges, 1) or (n_edges,) in the caller's edge
    order; scalar edge features broadcast across the feature dim.
    """
    if reduce_op not in ("sum", "mean"):
        raise ValueError("pallas binary_reduce supports sum/mean")
    pack = tiles if tiles is not None else get_plan_cache(g).tiles()
    d = B.shape[-1]
    E = E.reshape(E.shape[0], -1)
    if E.shape[1] == 1 and d != 1:
        E = jnp.broadcast_to(E, (E.shape[0], d))
    elif E.shape[1] != d:
        raise ValueError(f"edge feature dim {E.shape[1]} != node dim {d}")
    # permute edge features to tile order (contiguous per bucket)
    E_tiles = jnp.take(E, pack.eids.reshape(-1), axis=0)   # (T*eb, d)
    deg = g.in_degrees if reduce_op == "mean" else None
    return _br_packed(pack, B, E_tiles, deg, binop=binop,
                      reduce_op=reduce_op, nd=nd, interpret=interpret)

"""Pure-jnp oracle for the fused Binary-Reduce kernel.

``C[v] = Σ_{(u→v)=e} (B[u] ⊗ E[e])`` with canonical-order COO inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BINOPS = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "copy_lhs": lambda a, b: a,
    "copy_rhs": lambda a, b: b,
}


def binary_reduce_ref(src: jnp.ndarray, dst: jnp.ndarray, B: jnp.ndarray,
                      E: jnp.ndarray, n_dst: int, binop: str = "mul"
                      ) -> jnp.ndarray:
    """``E`` is (nnz, d) in the SAME order as ``src``/``dst``."""
    msg = _BINOPS[binop](jnp.take(B, src, axis=0), E)
    return jax.ops.segment_sum(msg, dst, num_segments=n_dst)

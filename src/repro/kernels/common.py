"""Shared helpers for the aggregation Pallas kernels.

The kernels consume the (M,K)-tile-bucketed edge format of
``repro.core.tiling.TilePack`` — the TPU adaptation of the paper's
K-blocking + radix-sort (DESIGN.md §2). Sparse gather/scatter inside a
bucket is expressed as one-hot matmuls so the MXU does the indexing:

    G[e, k] = 1 iff bucket edge e has source-local index k   (gather)
    S[m, e] = w_e iff bucket edge e has dest-local index m   (scatter)

    C_tile += S @ (G @ B_tile)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def should_interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU."""
    return jax.default_backend() != "tpu"


def onehot_gather_matrix(src_local, mask, bk: int, dtype) -> jnp.ndarray:
    """(eb, bk) one-hot gather matrix; masked-out edges are all-zero rows."""
    eb = src_local.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (eb, bk), 1)
    hot = (src_local[:, None] == iota) & mask[:, None]
    return hot.astype(dtype)


def onehot_scatter_matrix(dst_local, mask, bm: int, dtype,
                          weight=None) -> jnp.ndarray:
    """(bm, eb) one-hot scatter matrix, optionally edge-weighted."""
    eb = dst_local.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, eb), 0)
    hot = ((dst_local[None, :] == iota) & mask[None, :]).astype(dtype)
    if weight is not None:
        hot = hot * weight[None, :].astype(dtype)
    return hot

"""Dispatch from BRSpec (core lattice) onto the Pallas kernels.

Spec support is decided up front by ``repro.core.planner.supports()``
(the planner falls back to onehot/ell/segment for anything not covered
here); the ``NotImplementedError`` at the bottom is a safety net for
callers that bypass the planner."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.binary_reduce import BRSpec
from ..core.tiling import TilePack


def gspmm_pallas(g, spec: BRSpec, lhs_data, rhs_data,
                 tiles: Optional[TilePack] = None):
    """Route a parsed BR config to a Pallas kernel (out target 'v' only)."""
    from .spmm.ops import spmm
    from .binary_reduce.ops import binary_reduce

    if spec.out != "v":
        raise ValueError("pallas strategy reduces to destination nodes")
    red = spec.reduce

    # CR: u_copy_{add,mean}_v
    if spec.op == "copy" and spec.lhs == "u":
        return spmm(g, lhs_data, red, tiles=tiles)

    # CR from edges: e_copy_{add,mean}_v
    if spec.op == "copy" and spec.lhs == "e":
        zeros = jnp.zeros((g.n_src, lhs_data.shape[-1]), lhs_data.dtype)
        return binary_reduce(g, zeros, lhs_data, binop="copy_rhs",
                             reduce_op=red, tiles=tiles)

    # BR: u_⊗_e_{add,mean}_v
    if spec.lhs == "u" and spec.rhs == "e":
        # scalar edge weight + mul → weighted SpMM (cheaper)
        if spec.op == "mul" and rhs_data.shape[-1] == 1:
            return spmm(g, lhs_data, red, weight=rhs_data[:, 0], tiles=tiles)
        return binary_reduce(g, lhs_data, rhs_data, binop=spec.op,
                             reduce_op=red, tiles=tiles)

    # BR: e_⊗_u_{add,mean}_v (flip operands for commutative ⊗)
    if spec.lhs == "e" and spec.rhs == "u" and spec.op in ("add", "mul"):
        return binary_reduce(g, rhs_data, lhs_data, binop=spec.op,
                             reduce_op=red, tiles=tiles)

    raise NotImplementedError(
        f"no pallas kernel for {spec.name}; the planner should have "
        f"fallen back — use strategy='auto' or 'segment'")

"""Fused edge-softmax Pallas kernel (GAT's 5-primitive chain in one pass).

The paper's Table 2 shows GAT issuing five BR/CR passes for attention
normalization (max, sub, exp, sum, div) — five HBM round-trips over
edge data. Here the logits are packed row-major into padded ELL
``(rows, W, H)`` so each destination row's incoming edges are one dense
stripe; the kernel computes the entire masked softmax over the ``W`` axis
in VMEM: one read, one write.

Grid: row blocks of ``br`` destination rows. Block: (br, W, H).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _softmax_kernel(x_ref, mask_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)        # (br, W, H)
    mask = (mask_ref[...] != 0)[:, :, None]   # (br, W, 1)
    x = jnp.where(mask, x, _NEG)
    mx = jnp.max(x, axis=1, keepdims=True)    # (br, 1, H)
    ex = jnp.exp(x - mx)
    ex = jnp.where(mask, ex, 0.0)
    z = jnp.sum(ex, axis=1, keepdims=True)
    out = ex / jnp.maximum(z, 1e-38)
    out_ref[...] = out.astype(out_ref.dtype)


def edge_softmax_pallas_call(n_rows_pad: int, W: int, H: int, br: int,
                             dtype, *, interpret: bool):
    """x: (n_rows_pad, W, H) padded ELL logits; mask: (n_rows_pad, W)."""
    grid = (n_rows_pad // br,)
    return pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, W, H), lambda r: (r, 0, 0)),
            pl.BlockSpec((br, W), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((br, W, H), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows_pad, W, H), dtype),
        interpret=interpret)


def _attention_kernel(slope, el_ref, er_ref, z_ref, mask_ref, out_ref):
    """Whole GAT attention rows in VMEM: logits = el[src]+er[dst] through
    leaky-relu, masked softmax over W, α-weighted feature reduce — one
    read of the stripes, one (br, H, F) write, α never leaves VMEM."""
    el = el_ref[...].astype(jnp.float32)          # (br, W, H)
    er = er_ref[...].astype(jnp.float32)          # (br, H)
    zv = z_ref[...].astype(jnp.float32)           # (br, W, H, F)
    mask = (mask_ref[...] != 0)[:, :, None]       # (br, W, 1)
    s = el + er[:, None, :]
    s = jnp.where(s >= 0, s, slope * s)           # leaky BEFORE the mask
    s = jnp.where(mask, s, _NEG)
    mx = jnp.max(s, axis=1, keepdims=True)        # (br, 1, H)
    ex = jnp.exp(s - mx)
    ex = jnp.where(mask, ex, 0.0)
    z = jnp.sum(ex, axis=1, keepdims=True)
    alpha = ex / jnp.maximum(z, 1e-38)            # (br, W, H)
    out = jnp.einsum("bwh,bwhf->bhf", alpha, zv)
    out_ref[...] = out.astype(out_ref.dtype)


def fused_attention_pallas_call(n_rows_pad: int, W: int, H: int, F: int,
                                br: int, dtype, *, slope: float,
                                interpret: bool):
    """el: (n_rows_pad, W, H) src terms; er: (n_rows_pad, H) dst terms;
    z: (n_rows_pad, W, H, F) source features; mask: (n_rows_pad, W)."""
    grid = (n_rows_pad // br,)
    return pl.pallas_call(
        functools.partial(_attention_kernel, slope),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, W, H), lambda r: (r, 0, 0)),
            pl.BlockSpec((br, H), lambda r: (r, 0)),
            pl.BlockSpec((br, W, H, F), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec((br, W), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((br, H, F), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows_pad, H, F), dtype),
        interpret=interpret)

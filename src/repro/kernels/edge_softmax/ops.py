"""Jitted public wrapper for the fused edge-softmax Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.graph import Graph
from ...core.planner import get_plan_cache
from ...core.tiling import ELLClass
from ..common import should_interpret
from .kernel import edge_softmax_pallas_call, fused_attention_pallas_call


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit,
                   static_argnames=("n_edges", "br", "interpret"))
def _edge_softmax_packed(pack: ELLClass, logits: jnp.ndarray,
                         eid_inv: jnp.ndarray, n_edges: int,
                         br: int = 8, interpret: Optional[bool] = None
                         ) -> jnp.ndarray:
    """Softmax over incoming-edge stripes; returns caller edge order."""
    C, W = pack.chunk_cols.shape
    H = logits.shape[-1]
    C_pad = _round_up(C, br)

    # gather logits (caller order) into the padded ELL stripes
    x = jnp.take(logits, pack.chunk_eids, axis=0)          # (C, W, H)
    x = jnp.pad(x, ((0, C_pad - C), (0, 0), (0, 0)))
    mask = jnp.pad(pack.chunk_mask.astype(jnp.int32),
                   ((0, C_pad - C), (0, 0)))

    call = edge_softmax_pallas_call(
        C_pad, W, H, br, logits.dtype,
        interpret=should_interpret() if interpret is None else interpret)
    out = call(x, mask)                                    # (C_pad, W, H)

    # scatter back to caller edge order: every real edge occupies exactly
    # one (chunk, w) slot, so a masked set is a pure permutation.
    flat_vals = out[:C].reshape(C * W, H)
    flat_eids = pack.chunk_eids.reshape(C * W)
    flat_mask = pack.chunk_mask.reshape(C * W)
    safe_ids = jnp.where(flat_mask, flat_eids, n_edges)    # drop pads
    res = jnp.zeros((n_edges, H), out.dtype)
    return res.at[safe_ids].set(flat_vals, mode="drop")


def edge_softmax(g: Graph, logits: jnp.ndarray,
                 ell: Optional[ELLClass] = None, br: int = 8,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused softmax over incoming edges per destination node.

    ``logits``: (n_edges, H) or (n_edges,) in the caller's edge order.
    The pack must be row-complete (one FULL row per chunk): pass
    ``ell=build_ell_uniform(g, max_in_degree)`` or let this wrapper
    build it.
    """
    squeeze = logits.ndim == 1
    x = logits[:, None] if squeeze else logits
    if ell is None:
        max_deg = int(jnp.max(g.in_degrees)) if g.n_dst else 1
        ell = get_plan_cache(g).ell_uniform(max(max_deg, 1))
    elif int(jnp.max(g.in_degrees)) > ell.width:
        raise ValueError("pack splits rows; edge_softmax needs "
                         "width >= max in-degree")
    out = _edge_softmax_packed(ell, x, g.eid_inv, g.n_edges, br=br,
                               interpret=interpret)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("n_dst", "slope", "br", "interpret"))
def _fused_attention_packed(pack: ELLClass, el: jnp.ndarray,
                            er: jnp.ndarray, z: jnp.ndarray, n_dst: int,
                            slope: float, br: int,
                            interpret: bool) -> jnp.ndarray:
    """Attention megakernel over row-complete stripes → (n_dst, H, F)."""
    C, W = pack.chunk_cols.shape
    H = el.shape[-1]
    F = z.shape[-1]
    C_pad = _round_up(max(C, 1), br)

    el_t = jnp.take(el, pack.chunk_cols, axis=0)           # (C, W, H)
    er_t = jnp.take(er, pack.chunk_row, axis=0)            # (C, H)
    z_t = jnp.take(z, pack.chunk_cols, axis=0)             # (C, W, H, F)
    el_t = jnp.pad(el_t, ((0, C_pad - C), (0, 0), (0, 0)))
    er_t = jnp.pad(er_t, ((0, C_pad - C), (0, 0)))
    z_t = jnp.pad(z_t, ((0, C_pad - C), (0, 0), (0, 0), (0, 0)))
    mask = jnp.pad(pack.chunk_mask.astype(jnp.int32),
                   ((0, C_pad - C), (0, 0)))

    call = fused_attention_pallas_call(C_pad, W, H, F, br, z.dtype,
                                       slope=slope, interpret=interpret)
    out = call(el_t, er_t, z_t, mask)                      # (C_pad, H, F)

    # row-complete pack: each chunk is one whole destination row, so the
    # scatter-back is a pure permutation; zero-degree rows stay 0 (DGL)
    res = jnp.zeros((n_dst, H, F), out.dtype)
    return res.at[pack.chunk_row].set(out[:C])


def fused_attention(g: Graph, el: jnp.ndarray, er: jnp.ndarray,
                    z: jnp.ndarray, slope: float = 0.2,
                    ell: Optional[ELLClass] = None, br: int = 8,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """GAT attention pipeline as ONE kernel pass.

    ``el``: (n_src, H) source logit terms; ``er``: (n_dst, H)
    destination terms; ``z``: (n_src, H, F) source features. Computes
    leaky-relu(el[src]+er[dst]) → per-destination softmax → α-weighted
    feature sum without materializing per-edge α in HBM. Needs a
    row-complete pack, like :func:`edge_softmax`.
    """
    if ell is None:
        max_deg = int(jnp.max(g.in_degrees)) if g.n_dst else 1
        ell = get_plan_cache(g).ell_uniform(max(max_deg, 1))
    elif int(jnp.max(g.in_degrees)) > ell.width:
        raise ValueError("pack splits rows; fused_attention needs "
                         "width >= max in-degree")
    return _fused_attention_packed(
        ell, el, er, z, g.n_dst, float(slope), br,
        should_interpret() if interpret is None else interpret)

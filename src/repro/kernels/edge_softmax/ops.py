"""Jitted public wrapper for the fused edge-softmax Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ...core.graph import Graph
from ...core.planner import get_plan_cache
from ...core.tiling import ELLClass, ELLPack
from ..common import should_interpret
from .kernel import edge_softmax_pallas_call, fused_attention_pallas_call


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Per-grid-step stripe element budget for the ragged per-class launches
# (~64 MB of fp32). Narrow classes take huge row blocks (often the whole
# class in one step), wide hub classes take small ones — so the grid
# stays shallow everywhere, which is what keeps the interpreted CPU
# lowering (one Python dispatch per grid step) fast.
_RAGGED_BLOCK_ELEMS = 1 << 24


def _ragged_br(C: int, W: int, H: int, F: int) -> int:
    """Adaptive row-block size for one ragged class: as many rows per
    grid step as the element budget allows, multiple of 8, ≥ 8, and no
    larger than the class itself padded to 8."""
    per_row = max(W * H * max(F, 1), 1)
    b = min(_RAGGED_BLOCK_ELEMS // per_row, _round_up(max(C, 1), 8))
    return max((b // 8) * 8, 8)


@functools.partial(jax.jit,
                   static_argnames=("n_edges", "br", "interpret"))
def _edge_softmax_packed(pack: ELLClass, logits: jnp.ndarray,
                         eid_inv: jnp.ndarray, n_edges: int,
                         br: int = 8, interpret: Optional[bool] = None
                         ) -> jnp.ndarray:
    """Softmax over incoming-edge stripes; returns caller edge order."""
    C, W = pack.chunk_cols.shape
    H = logits.shape[-1]
    C_pad = _round_up(C, br)

    # gather logits (caller order) into the padded ELL stripes
    x = jnp.take(logits, pack.chunk_eids, axis=0)          # (C, W, H)
    x = jnp.pad(x, ((0, C_pad - C), (0, 0), (0, 0)))
    mask = jnp.pad(pack.chunk_mask.astype(jnp.int32),
                   ((0, C_pad - C), (0, 0)))

    call = edge_softmax_pallas_call(
        C_pad, W, H, br, logits.dtype,
        interpret=should_interpret() if interpret is None else interpret)
    out = call(x, mask)                                    # (C_pad, W, H)

    # scatter back to caller edge order: every real edge occupies exactly
    # one (chunk, w) slot, so a masked set is a pure permutation.
    flat_vals = out[:C].reshape(C * W, H)
    flat_eids = pack.chunk_eids.reshape(C * W)
    flat_mask = pack.chunk_mask.reshape(C * W)
    safe_ids = jnp.where(flat_mask, flat_eids, n_edges)    # drop pads
    res = jnp.zeros((n_edges, H), out.dtype)
    return res.at[safe_ids].set(flat_vals, mode="drop")


def edge_softmax(g: Graph, logits: jnp.ndarray,
                 ell: Optional[ELLClass] = None, br: int = 8,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused softmax over incoming edges per destination node.

    ``logits``: (n_edges, H) or (n_edges,) in the caller's edge order.
    The pack must be row-complete (one FULL row per chunk): pass
    ``ell=build_ell_uniform(g, max_in_degree)`` or let this wrapper
    build it.
    """
    squeeze = logits.ndim == 1
    x = logits[:, None] if squeeze else logits
    if ell is None:
        max_deg = int(jnp.max(g.in_degrees)) if g.n_dst else 1
        ell = get_plan_cache(g).ell_uniform(max(max_deg, 1))
    elif int(jnp.max(g.in_degrees)) > ell.width:
        raise ValueError("pack splits rows; edge_softmax needs "
                         "width >= max in-degree")
    out = _edge_softmax_packed(ell, x, g.eid_inv, g.n_edges, br=br,
                               interpret=interpret)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("n_dst", "slope", "br", "interpret"))
def _fused_attention_packed(pack: ELLClass, el: jnp.ndarray,
                            er: jnp.ndarray, z: jnp.ndarray, n_dst: int,
                            slope: float, br: int,
                            interpret: bool) -> jnp.ndarray:
    """Attention megakernel over row-complete stripes → (n_dst, H, F)."""
    C, W = pack.chunk_cols.shape
    H = el.shape[-1]
    F = z.shape[-1]
    C_pad = _round_up(max(C, 1), br)

    el_t = jnp.take(el, pack.chunk_cols, axis=0)           # (C, W, H)
    er_t = jnp.take(er, pack.chunk_row, axis=0)            # (C, H)
    z_t = jnp.take(z, pack.chunk_cols, axis=0)             # (C, W, H, F)
    el_t = jnp.pad(el_t, ((0, C_pad - C), (0, 0), (0, 0)))
    er_t = jnp.pad(er_t, ((0, C_pad - C), (0, 0)))
    z_t = jnp.pad(z_t, ((0, C_pad - C), (0, 0), (0, 0), (0, 0)))
    mask = jnp.pad(pack.chunk_mask.astype(jnp.int32),
                   ((0, C_pad - C), (0, 0)))

    call = fused_attention_pallas_call(C_pad, W, H, F, br, z.dtype,
                                       slope=slope, interpret=interpret)
    out = call(el_t, er_t, z_t, mask)                      # (C_pad, H, F)

    # row-complete pack: each chunk is one whole destination row, so the
    # scatter-back is a pure permutation; zero-degree rows stay 0 (DGL)
    res = jnp.zeros((n_dst, H, F), out.dtype)
    return res.at[pack.chunk_row].set(out[:C])


@functools.partial(jax.jit,
                   static_argnames=("slope", "interpret"))
def _fused_attention_ragged(pack: ELLPack, el: jnp.ndarray,
                            er: jnp.ndarray, z: jnp.ndarray,
                            slope: float, interpret: bool) -> jnp.ndarray:
    """Attention megakernel over RAGGED per-class stripes.

    One stripe grid per power-of-two degree class, each padded only to
    its own class width — the padded-slot count is the degree
    histogram's pow2 row sum instead of n_rows × max_degree. Classes
    hold disjoint destination rows (build_ell_ragged never splits a
    row), so the per-class scatter-back is a pure permutation and the
    class outputs never overlap.
    """
    H = el.shape[-1]
    F = z.shape[-1]
    res = jnp.zeros((pack.n_dst, H, F), z.dtype)
    for cls in pack.classes:
        C, W = cls.chunk_cols.shape
        b = _ragged_br(C, W, H, F)
        C_pad = _round_up(max(C, 1), b)
        el_t = jnp.take(el, cls.chunk_cols, axis=0)        # (C, W, H)
        er_t = jnp.take(er, cls.chunk_row, axis=0)         # (C, H)
        z_t = jnp.take(z, cls.chunk_cols, axis=0)          # (C, W, H, F)
        el_t = jnp.pad(el_t, ((0, C_pad - C), (0, 0), (0, 0)))
        er_t = jnp.pad(er_t, ((0, C_pad - C), (0, 0)))
        z_t = jnp.pad(z_t, ((0, C_pad - C), (0, 0), (0, 0), (0, 0)))
        mask = jnp.pad(cls.chunk_mask.astype(jnp.int32),
                       ((0, C_pad - C), (0, 0)))
        call = fused_attention_pallas_call(C_pad, W, H, F, b, z.dtype,
                                           slope=slope,
                                           interpret=interpret)
        out = call(el_t, er_t, z_t, mask)                  # (C_pad, H, F)
        res = res.at[cls.chunk_row].set(out[:C])
    return res


def fused_attention(g: Graph, el: jnp.ndarray, er: jnp.ndarray,
                    z: jnp.ndarray, slope: float = 0.2,
                    ell: Optional[Union[ELLClass, ELLPack]] = None,
                    br: int = 8,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """GAT attention pipeline as ONE kernel pass.

    ``el``: (n_src, H) source logit terms; ``er``: (n_dst, H)
    destination terms; ``z``: (n_src, H, F) source features. Computes
    leaky-relu(el[src]+er[dst]) → per-destination softmax → α-weighted
    feature sum without materializing per-edge α in HBM.

    Needs a ROW-COMPLETE pack. By default this is the ragged per-class
    pack (``PlanCache.ell_ragged``), launched as one stripe grid per
    degree class; passing an :class:`ELLClass` (``build_ell_uniform``)
    pins the legacy single-width path instead.
    """
    # degrees straight off the stored CSR field: concrete whenever the
    # graph is (the in_degrees property computes through traced slices
    # inside an active trace); None = graph is traced, skip the checks
    deg = (None if isinstance(g.indptr_dst, jax.core.Tracer)
           else np.diff(np.asarray(g.indptr_dst)))
    if ell is None:
        ell = get_plan_cache(g).ell_ragged()
    if isinstance(ell, ELLPack):
        if deg is not None:     # row-completeness needs concrete degrees
            n_chunks = sum(int(c.chunk_row.shape[0])
                           for c in ell.classes)
            if g.n_edges and n_chunks != int((deg > 0).sum()):
                raise ValueError("pack splits rows; fused_attention "
                                 "needs a row-complete (ragged or "
                                 "uniform) pack")
        return _fused_attention_ragged(
            ell, el, er, z, float(slope),
            should_interpret() if interpret is None else interpret)
    if deg is not None and deg.size and int(deg.max()) > ell.width:
        raise ValueError("pack splits rows; fused_attention needs "
                         "width >= max in-degree")
    return _fused_attention_packed(
        ell, el, er, z, g.n_dst, float(slope), br,
        should_interpret() if interpret is None else interpret)

"""Pure-jnp oracle for the fused edge-softmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_softmax_ref(dst: jnp.ndarray, logits: jnp.ndarray,
                     n_dst: int) -> jnp.ndarray:
    """Softmax of ``logits`` (nnz, H) over edges sharing a destination.

    ``dst`` and ``logits`` are in the same (any) edge order.
    """
    mx = jax.ops.segment_max(logits, dst, num_segments=n_dst)
    mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), logits.dtype))
    ex = jnp.exp(logits - jnp.take(mx, dst, axis=0))
    z = jax.ops.segment_sum(ex, dst, num_segments=n_dst)
    return ex / jnp.take(z, dst, axis=0)

"""Pure-jnp oracle for the fused edge-softmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_softmax_ref(dst: jnp.ndarray, logits: jnp.ndarray,
                     n_dst: int) -> jnp.ndarray:
    """Softmax of ``logits`` (nnz, H) over edges sharing a destination.

    ``dst`` and ``logits`` are in the same (any) edge order.
    """
    mx = jax.ops.segment_max(logits, dst, num_segments=n_dst)
    mx = jnp.where(jnp.isfinite(mx), mx, jnp.zeros((), logits.dtype))
    ex = jnp.exp(logits - jnp.take(mx, dst, axis=0))
    z = jax.ops.segment_sum(ex, dst, num_segments=n_dst)
    return ex / jnp.take(z, dst, axis=0)


def fused_attention_ref(src: jnp.ndarray, dst: jnp.ndarray,
                        el: jnp.ndarray, er: jnp.ndarray, z: jnp.ndarray,
                        n_dst: int, slope: float = 0.2) -> jnp.ndarray:
    """Attention-pipeline oracle: leaky(el[src]+er[dst]) → edge softmax
    → α-weighted source-feature sum; (n_dst, H, F)."""
    m = jnp.take(el, src, axis=0) + jnp.take(er, dst, axis=0)
    m = jnp.where(m >= 0, m, slope * m)
    alpha = edge_softmax_ref(dst, m, n_dst)
    msg = alpha[..., None] * jnp.take(z, src, axis=0)
    return jax.ops.segment_sum(msg, dst, num_segments=n_dst)

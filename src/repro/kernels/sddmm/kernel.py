"""Tiled gSDDMM Pallas kernel: per-edge ⊗ over canonical operand streams.

The operands arrive already gathered into canonical (dst-sorted) edge
order as dense ``(E, d)`` streams, so the kernel is a pure tiled map:
grid over edge blocks of ``be`` rows, each block computing the
element-wise ⊗ (or the feature-dot) entirely in VMEM. The host wrapper
(``ops.py``) pays the one gather in and the one un-permute out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binary_kernel(op: str, lhs_ref, rhs_ref, out_ref):
    a = lhs_ref[...].astype(jnp.float32)      # (be, d)
    b = rhs_ref[...].astype(jnp.float32)      # (be, d)
    if op == "add":
        out = a + b
    elif op == "sub":
        out = a - b
    elif op == "mul":
        out = a * b
    elif op == "div":
        out = a / b
    elif op == "dot":
        out = jnp.sum(a * b, axis=-1, keepdims=True)   # (be, 1)
    else:
        raise ValueError(f"unsupported sddmm kernel op {op!r}")
    out_ref[...] = out.astype(out_ref.dtype)


def _copy_kernel(lhs_ref, out_ref):
    out_ref[...] = lhs_ref[...]


def sddmm_pallas_call(op: str, n_edges_pad: int, d: int, be: int,
                      dtype, *, interpret: bool):
    """⊗ over padded canonical streams; lhs/rhs: (n_edges_pad, d)."""
    grid = (n_edges_pad // be,)
    d_out = 1 if op == "dot" else d
    if op == "copy":
        return pl.pallas_call(
            _copy_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((be, d), lambda r: (r, 0))],
            out_specs=pl.BlockSpec((be, d_out), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((n_edges_pad, d_out), dtype),
            interpret=interpret)
    return pl.pallas_call(
        functools.partial(_binary_kernel, op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, d), lambda r: (r, 0)),
            pl.BlockSpec((be, d), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((be, d_out), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_edges_pad, d_out), dtype),
        interpret=interpret)

"""Jitted public wrapper for the tiled gSDDMM Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import should_interpret
from .kernel import sddmm_pallas_call


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("op", "be", "interpret"))
def _sddmm_padded(lhs_val: jnp.ndarray, rhs_val, op: str,
                  be: int, interpret: bool) -> jnp.ndarray:
    E, d = lhs_val.shape
    E_pad = _round_up(max(E, 1), be)
    # width-1 operands broadcast up so the kernel sees equal widths
    if rhs_val is not None:
        d = max(d, rhs_val.shape[-1])
        lhs_val = jnp.broadcast_to(lhs_val, (E, d))
        rhs_val = jnp.broadcast_to(rhs_val, (E, d))
        # pad rhs with ones: keeps div's pad rows finite (sliced off)
        rhs_val = jnp.pad(rhs_val, ((0, E_pad - E), (0, 0)),
                          constant_values=1)
    lhs_val = jnp.pad(lhs_val, ((0, E_pad - E), (0, 0)))

    call = sddmm_pallas_call(op, E_pad, d, be, lhs_val.dtype,
                             interpret=interpret)
    out = call(lhs_val) if rhs_val is None else call(lhs_val, rhs_val)
    return out[:E]


def sddmm(lhs_val: jnp.ndarray, rhs_val, op: str, be: int = 128,
          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-edge ⊗ of canonical operand streams.

    ``lhs_val``/``rhs_val``: (n_edges, d) streams already gathered into
    canonical edge order (``rhs_val`` None for copy). Returns the
    per-edge result in the same order; ``dot`` reduces the feature
    axis to width 1.
    """
    return _sddmm_padded(
        lhs_val, rhs_val, op, be,
        should_interpret() if interpret is None else interpret)

"""Reference oracle for the gSDDMM kernel: plain jnp over the streams."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.binary_reduce import BINARY_OPS


def sddmm_ref(lhs_val: jnp.ndarray, rhs_val, op: str) -> jnp.ndarray:
    """⊗ applied to pre-gathered per-edge operand streams."""
    return BINARY_OPS[op](lhs_val, rhs_val)

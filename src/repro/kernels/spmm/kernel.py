"""Copy-Reduce as a blocked SpMM Pallas TPU kernel (paper Alg. 3 → TPU).

Grid: ``(n_feature_tiles, n_buckets)`` — buckets (the paper's K-blocks,
pre-sorted by destination tile) iterate fastest, so every output tile
``C[tile_m, n]`` is visited by *consecutive* grid steps and accumulates in
VMEM; it is written back to HBM exactly once per feature tile (the paper's
"C panel stays in LLC until completely processed", with VMEM playing LLC).

Per grid step:
  * ``BlockSpec`` DMAs the K-block of source features ``B[tile_k]``
    (bk × nd) into VMEM — the paper's "B block stays in L2";
  * bucket edge indices (eb) arrive as int32 VMEM blocks;
  * gather/scatter run as one-hot matmuls on the MXU (DESIGN.md §2) —
    the TPU replacement for sorted scalar streams.

Reductions: sum (optionally edge-weighted). Mean is sum + a post-scale in
``ops.py``. Max/min intentionally stay on the segment path (see DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import onehot_gather_matrix, onehot_scatter_matrix


def _spmm_kernel(# scalar-prefetch refs
                 tile_m_ref, tile_k_ref, first_ref,
                 # tensor refs
                 dst_ref, src_ref, mask_ref, wgt_ref, b_ref,
                 # output
                 out_ref, *, bm: int, bk: int, weighted: bool):
    t = pl.program_id(1)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst_local = dst_ref[0]          # (eb,) int32
    src_local = src_ref[0]
    mask = mask_ref[0] != 0         # int32 block -> bool
    acc_t = jnp.float32

    G = onehot_gather_matrix(src_local, mask, bk, b_ref.dtype)
    gathered = jax.lax.dot(G, b_ref[...],
                           preferred_element_type=acc_t)     # (eb, nd)
    w = wgt_ref[0] if weighted else None
    S = onehot_scatter_matrix(dst_local, mask, bm, gathered.dtype, weight=w)
    out_ref[...] += jax.lax.dot(S, gathered,
                                preferred_element_type=acc_t
                                ).astype(out_ref.dtype)


def spmm_pallas_call(T: int, eb: int, bm: int, bk: int, nd: int,
                     n_tiles_m: int, n_tiles_k: int, d_pad: int,
                     dtype, *, weighted: bool, interpret: bool):
    """Build the pallas_call for given static geometry.

    Inputs (in order): tile_m (T,), tile_k (T,), first_of_m (T,)  [scalar
    prefetch]; dst_local (T,eb), src_local (T,eb), mask (T,eb) int32,
    weight (T,eb), B (n_tiles_k*bk, d_pad).
    Output: C (n_tiles_m*bm, d_pad).
    """
    n_nd = d_pad // nd

    grid = (n_nd, T)

    def edge_map(n, t, tm, tk, first):
        return (t, 0)

    def b_map(n, t, tm, tk, first):
        return (tk[t], n)

    def out_map(n, t, tm, tk, first):
        return (tm[t], n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, eb), edge_map),   # dst_local
            pl.BlockSpec((1, eb), edge_map),   # src_local
            pl.BlockSpec((1, eb), edge_map),   # mask
            pl.BlockSpec((1, eb), edge_map),   # weight
            pl.BlockSpec((bk, nd), b_map),     # B k-block
        ],
        out_specs=pl.BlockSpec((bm, nd), out_map),
    )

    kernel = functools.partial(_spmm_kernel, bm=bm, bk=bk, weighted=weighted)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles_m * bm, d_pad), dtype),
        interpret=interpret,
    )

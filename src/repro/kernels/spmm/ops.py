"""Jitted public wrapper for the Copy-Reduce SpMM Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.graph import Graph
from ...core.planner import get_plan_cache
from ...core.tiling import TilePack
from ..common import should_interpret
from .kernel import spmm_pallas_call


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("reduce_op", "nd", "interpret"))
def _spmm_packed(pack: TilePack, B: jnp.ndarray,
                 weight_tiles: Optional[jnp.ndarray],
                 deg: Optional[jnp.ndarray],
                 reduce_op: str = "sum", nd: int = 128,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    T, eb = pack.dst_local.shape
    bm, bk = pack.bm, pack.bk
    d = B.shape[-1]
    nd = min(nd, _round_up(d, 128))
    d_pad = _round_up(d, nd)

    Bp = jnp.pad(B, ((0, pack.n_tiles_k * bk - B.shape[0]), (0, d_pad - d)))
    weighted = weight_tiles is not None
    w = weight_tiles if weighted else jnp.ones((T, eb), Bp.dtype)

    call = spmm_pallas_call(
        T=T, eb=eb, bm=bm, bk=bk, nd=nd,
        n_tiles_m=pack.n_tiles_m, n_tiles_k=pack.n_tiles_k, d_pad=d_pad,
        dtype=Bp.dtype, weighted=weighted,
        interpret=should_interpret() if interpret is None else interpret)

    out = call(pack.tile_m, pack.tile_k, pack.first_of_m,
               pack.dst_local, pack.src_local,
               pack.mask.astype(jnp.int32), w.astype(Bp.dtype), Bp)
    out = out[: pack.n_dst, :d]
    if reduce_op == "mean":
        out = out / jnp.maximum(deg, 1).astype(out.dtype)[:, None]
    return out


def spmm(g: Graph, B: jnp.ndarray, reduce_op: str = "sum",
         weight: Optional[jnp.ndarray] = None,
         tiles: Optional[TilePack] = None, nd: int = 128,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Copy-Reduce ``C[v] = ⊕_(u→v) w·B[u]`` via the Pallas kernel.

    ``weight``: optional (n_edges,) per-edge scalar in the caller's edge
    order (covers ``u_mul_e_add_v`` with scalar gates).
    """
    if reduce_op not in ("sum", "mean"):
        raise ValueError("pallas spmm supports sum/mean (see DESIGN.md)")
    pack = tiles if tiles is not None else get_plan_cache(g).tiles()
    wt = None
    if weight is not None:
        wt = jnp.take(weight.reshape(-1), pack.eids, axis=0)  # (T, eb)
    deg = g.in_degrees if reduce_op == "mean" else None
    return _spmm_packed(pack, B, wt, deg, reduce_op=reduce_op, nd=nd,
                        interpret=interpret)

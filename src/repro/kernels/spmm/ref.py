"""Pure-jnp oracle for the Copy-Reduce SpMM kernel.

Computes ``C[v] = ⊕_{(u→v) ∈ E} w_uv · B[u]`` from raw COO arrays — no
blocking, no packing, no Pallas. This is the ground truth every kernel
variant is tested against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def spmm_ref(src: jnp.ndarray, dst: jnp.ndarray, B: jnp.ndarray,
             n_dst: int, reduce_op: str = "sum",
             weight: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """COO gather → (weighted) segment reduce. ``weight``: (nnz,) or None."""
    msg = jnp.take(B, src, axis=0)
    if weight is not None:
        msg = msg * weight[:, None].astype(msg.dtype)
    if reduce_op in ("sum", "mean"):
        out = jax.ops.segment_sum(msg, dst, num_segments=n_dst)
        if reduce_op == "mean":
            deg = jax.ops.segment_sum(jnp.ones_like(dst, msg.dtype), dst,
                                      num_segments=n_dst)
            out = out / jnp.maximum(deg, 1)[:, None]
        return out
    if reduce_op == "max":
        out = jax.ops.segment_max(msg, dst, num_segments=n_dst)
        return jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
    if reduce_op == "min":
        out = jax.ops.segment_min(msg, dst, num_segments=n_dst)
        return jnp.where(jnp.isfinite(out), out, jnp.zeros((), out.dtype))
    raise ValueError(reduce_op)

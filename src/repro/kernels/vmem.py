"""VMEM footprint model for the aggregation kernels.

TPU v5e has ~16 MiB of VMEM per core. BlockSpec geometry must keep every
live tile resident: the dry-run can't execute the kernels, so this model
is the structural check (and the block-shape autotuner's cost function)
— pick the largest K-block (``bk``) whose working set fits, exactly the
paper's "B block stays in L2" sizing rule mapped to VMEM.
"""
from __future__ import annotations

from typing import Dict

VMEM_BYTES = 16 * 1024 * 1024


def spmm_vmem_bytes(bm: int, bk: int, eb: int, nd: int,
                    dtype_bytes: int = 4) -> int:
    """Live VMEM for one grid step of the SpMM kernel."""
    b_tile = bk * nd * dtype_bytes             # source K-block
    out_tile = bm * nd * 4                     # f32 accumulator
    onehots = (eb * bk + bm * eb) * 4          # G and S matrices
    gathered = eb * nd * 4                     # G @ B intermediate
    idx = 4 * eb * 4                           # dst/src/mask/weight rows
    return b_tile + out_tile + onehots + gathered + idx


def br_vmem_bytes(bm: int, bk: int, eb: int, nd: int,
                  dtype_bytes: int = 4) -> int:
    """Fused binary-reduce adds the streamed edge-feature block."""
    return (spmm_vmem_bytes(bm, bk, eb, nd, dtype_bytes)
            + eb * nd * dtype_bytes)


def edge_softmax_vmem_bytes(br_rows: int, width: int, heads: int) -> int:
    x = br_rows * width * heads * 4
    mask = br_rows * width * 4
    return 2 * x + mask                        # in + out + mask


def pick_spmm_geometry(d: int, dtype_bytes: int = 4,
                       budget: int = VMEM_BYTES) -> Dict[str, int]:
    """Largest MXU-aligned K-block that fits the VMEM budget."""
    nd = min(128 * max(1, d // 128), 512)
    best = dict(bm=128, bk=128, eb=256, nd=nd)
    for bk in (1024, 512, 256, 128):
        for eb in (512, 256, 128):
            if spmm_vmem_bytes(128, bk, eb, nd, dtype_bytes) <= budget // 2:
                return dict(bm=128, bk=bk, eb=eb, nd=nd)
    return best

"""repro.launch — mesh construction, sharding rules, train/serve steps,
multi-pod dry-run."""

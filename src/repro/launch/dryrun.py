"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the placeholder device count before ANY other import (jax locks
device count on first init). Do not import this module from tests/benches
— they need the single real CPU device.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, cells
from ..models.lm.config import ModelConfig
from ..pjit_utils import ambient_mesh
from . import shardings as SR
from .input_specs import input_specs
from .mesh import make_production_mesh
from .steps import (TrainState, make_train_step, make_prefill_step,
                    make_decode_step, state_specs, eval_param_shapes)

# --------------------------------------------------------------------- #
# HLO collective parsing
# --------------------------------------------------------------------- #
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_TYPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                      r"pred|c64|c128)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_collective_bytes(hlo: str) -> Dict[str, int]:
    """Sum OPERAND bytes of every collective op (per-device program).

    ``-done`` ops are skipped so async pairs aren't double counted.
    Operand types are parsed from inside the call parens when present;
    otherwise the result type is used, corrected by the replica-group size
    for all-gather (result = operand × group) and reduce-scatter
    (operand = result × group).
    """
    out: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        opstart = m.end()
        depth = 1
        i = opstart
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[opstart:i - 1]
        types = _TYPE_RE.findall(operands)
        if types:
            nbytes = sum(_type_bytes(d, dims) for d, dims in types)
        else:
            # result type(s) live between '=' and the op name
            res_types = _TYPE_RE.findall(line[m.start():opstart])
            nbytes = sum(_type_bytes(d, dims) for d, dims in res_types)
            gm = _GROUPS_RE.search(line)
            group = int(gm.group(2)) if gm else 1
            if kind == "all-gather" and group:
                nbytes //= group          # result = operand × group
            elif kind == "reduce-scatter":
                nbytes *= group           # operand = result × group
        out[kind] = out.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = counts
    return out


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"unavailable": True}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes",
                 "host_argument_size_in_bytes",
                 "host_output_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ca is None:
        return {"unavailable": True}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


# --------------------------------------------------------------------- #
# cell runner
# --------------------------------------------------------------------- #
def build_lowered(arch: str, shape: str, mesh, *, microbatch: int = 1,
                  fsdp: bool = True, attn_block: int = 512):
    """Lower the cell's step function under the mesh. Returns lowered."""
    spec = input_specs(arch, shape)
    cfg: ModelConfig = spec["cfg"]
    kind = spec["kind"]
    max_seq = spec["S"] + 8 if cfg.family == "encdec" else 0
    pshapes = eval_param_shapes(cfg, max_seq=max_seq)
    pspecs = SR.param_specs(pshapes, cfg, mesh, fsdp=fsdp)

    if kind == "train":
        sspec = TrainState(params=pspecs, mu=pspecs, nu=pspecs,
                           step=jax.sharding.PartitionSpec())
        state_sds = TrainState(
            params=pshapes,
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32), pshapes),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32), pshapes),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        bspec = SR.batch_specs(cfg, "train", mesh, batch_size=spec["B"])
        step = make_train_step(cfg, microbatch=microbatch)
        jitted = jax.jit(
            step,
            in_shardings=(SR.to_named(sspec, mesh),
                          SR.to_named(bspec, mesh)),
            out_shardings=(SR.to_named(sspec, mesh), None),
            donate_argnums=(0,))
        return jitted.lower(state_sds, spec["batch"]), cfg, kind

    B = spec["B"]
    cspec = SR.cache_specs(cfg, mesh, batch_size=B, seq_len=spec["S"],
                           kind=kind)
    P = jax.sharding.PartitionSpec
    bspec = SR.batch_specs(cfg, kind, mesh, batch_size=B)
    ex_spec = {}
    if cfg.family == "encdec" and kind == "prefill":
        # decode reads cross-attention K/V from the cache, not memory
        ex_spec["memory"] = SR._to_spec(
            mesh, (SR._data_if_divisible(mesh, B), None, None))
    if cfg.family == "vlm" and kind == "prefill":
        ex_spec["positions"] = bspec["positions"]

    if kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(SR.to_named(pspecs, mesh),
                          SR.to_named(bspec["tokens"], mesh),
                          SR.to_named(cspec, mesh),
                          SR.to_named(ex_spec, mesh)),
            out_shardings=(None, SR.to_named(cspec, mesh)),
            donate_argnums=(2,))
        return jitted.lower(eval_param_shapes(cfg, max_seq=max_seq),
                            spec["tokens"], spec["cache"],
                            spec["extras"]), cfg, kind

    step = make_decode_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(SR.to_named(pspecs, mesh),
                      SR.to_named(bspec["tokens"], mesh),
                      SR.to_named(cspec, mesh),
                      SR.to_named(P(), mesh),
                      SR.to_named(ex_spec, mesh)),
        out_shardings=(None, SR.to_named(cspec, mesh)),
        donate_argnums=(2,))
    return jitted.lower(eval_param_shapes(cfg, max_seq=max_seq),
                        spec["token"], spec["cache"], spec["pos"],
                        spec["extras"]), cfg, kind


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_path: Optional[str] = None, *, microbatch: int = 1,
             fsdp: bool = True, attn_block: int = 512) -> Dict[str, Any]:
    mesh_env = os.environ.get("REPRO_DRYRUN_MESH")  # e.g. "2x4" (debug)
    if mesh_env:
        from .mesh import make_mesh
        dims = tuple(int(x) for x in mesh_env.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    with mesh, ambient_mesh(mesh):
        lowered, cfg, kind = build_lowered(arch, shape, mesh,
                                           microbatch=microbatch,
                                           fsdp=fsdp,
                                           attn_block=attn_block)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = _memory_analysis_dict(compiled)
        cost = _cost_analysis_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = parse_collective_bytes(hlo)
        from . import hlo_analysis
        try:
            tripaware = hlo_analysis.analyze(hlo)
        except Exception as e:  # keep the dry-run result even if parse fails
            tripaware = {"error": repr(e)}

    sh = SHAPES[shape]
    tokens_global = sh["global_batch"] * (sh["seq_len"] if kind != "decode"
                                          else 1)
    mesh_label = ("debug-" + mesh_env if mesh_env
                  else ("multipod-2x16x16" if multi_pod else "pod-16x16"))
    result = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": mesh_label,
        "n_chips": n_chips,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens_global": tokens_global,
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collective_bytes": coll,
        "tripaware": tripaware,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "microbatch": microbatch,
        "fsdp": fsdp,
        "ok": True,
    }
    print(f"[dryrun] {arch} × {shape} × {result['mesh']}: "
          f"flops/dev(raw)={cost.get('flops', float('nan')):.3e} "
          f"flops/dev(trip-aware)={tripaware.get('flops_hlo', 0):.3e} "
          f"coll/dev(trip-aware)={tripaware.get('collective_total', 0):.3e} "
          f"compile={t_compile:.0f}s")
    print("memory_analysis:", json.dumps(mem))
    print("cost_analysis:", {k: v for k, v in sorted(cost.items())
                             if k in ("flops", "bytes accessed",
                                      "transcendentals")})
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--attn-block", type=int, default=512)
    args = ap.parse_args()
    run_cell(args.arch, args.shape, args.multi_pod, args.out,
             microbatch=args.microbatch, fsdp=not args.no_fsdp,
             attn_block=args.attn_block)


if __name__ == "__main__":
    main()

"""Run the full dry-run grid: every live (arch × shape) cell × both meshes.

Each cell runs in a fresh subprocess (device-count env is per-process and
compile memory is reclaimed). Results are cached as JSON under
``experiments/dryrun/`` — re-runs skip completed cells.

Usage: PYTHONPATH=src python -m repro.launch.dryrun_all [--only arch]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# NOTE: safe to import configs here — this runner never initializes jax
from ..configs import cells

OUT_DIR = os.environ.get("REPRO_DRYRUN_OUT", os.path.join("experiments", "dryrun"))


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "multipod" if multi_pod else "pod"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}.json")


def cell_done(path: str) -> bool:
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("ok", False)
    except Exception:
        return False


def run_one(arch: str, shape: str, multi_pod: bool,
            timeout: int = 3600) -> bool:
    path = cell_path(arch, shape, multi_pod)
    if cell_done(path):
        print(f"[skip] {os.path.basename(path)}")
        return True
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", path]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.pop("REPRO_DRYRUN_MESH", None)
    env.pop("REPRO_DRYRUN_DEVICES", None)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, env=env, timeout=timeout,
                              capture_output=True, text=True)
        ok = proc.returncode == 0 and cell_done(path)
    except subprocess.TimeoutExpired:
        ok = False
        proc = None
    dt = time.time() - t0
    status = "ok" if ok else "FAIL"
    print(f"[{status}] {os.path.basename(path)} ({dt:.0f}s)")
    if not ok:
        err = {"arch": arch, "shape": shape,
               "mesh": "multipod" if multi_pod else "pod", "ok": False,
               "stderr": (proc.stderr[-4000:] if proc else "timeout")}
        with open(path, "w") as f:
            json.dump(err, f, indent=1)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="restrict to one arch")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    todo = [(a, s) for a, s in cells()
            if args.only is None or a == args.only]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            if run_one(arch, shape, mp, timeout=args.timeout):
                n_ok += 1
            else:
                n_fail += 1
    print(f"done: {n_ok} ok, {n_fail} failed")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

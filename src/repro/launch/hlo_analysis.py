"""Trip-count-aware HLO text analysis for the roofline.

``compiled.cost_analysis()`` visits each op ONCE — a ``jax.lax.scan`` over
56 layers contributes its body a single time, undercounting FLOPs,
bytes and collective traffic by ~L×. This module re-derives the three
roofline inputs from ``compiled.as_text()`` with loop awareness:

  * parse every computation into a symbol table (op name -> shape/dtype),
  * extract while-loop trip counts from the loop-condition constant,
  * walk the call graph (while / call / conditional / fusion) multiplying
    by trip counts,
  * count matmul FLOPs from dot shapes + contracting dims,
  * count collective operand bytes per kind,
  * approximate HBM traffic as Σ top-level (operand + result) bytes
    (each top-level HLO op is one kernel launch's worth of traffic —
    fusion internals excluded, matching the TPU execution model).

This is structural dry-run profiling (no wall clock): exactly the
"profile" the §Perf hillclimb iterates on.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TYPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPCODE = re.compile(r"^\s*(?:\(.*?\)|[a-z0-9\[\],{}<=\s]*?)\s*([a-z][\w\-]*)\(")
_CALLED = re.compile(r"(?:condition|body|to_apply|calls|branch_computations)="
                     r"({[^}]*}|%?[\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_COMPARE = re.compile(r"compare\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(ty: str) -> int:
    total = 0
    for dt, dims in _TYPE_TOK.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(ty: str) -> List[List[int]]:
    out = []
    for dt, dims in _TYPE_TOK.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclasses.dataclass
class Op:
    name: str
    rest: str        # full RHS text
    opcode: str
    result_ty: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation],
                                          Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            m = _COMP_HDR.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = Computation(m.group(1), {}, [])
                if s.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OPCODE.match(" " + rest)
        opcode = om.group(1) if om else ""
        # result type = leading type tokens before the opcode
        idx = rest.find(opcode + "(") if opcode else -1
        result_ty = rest[:idx] if idx > 0 else rest
        cur.ops[name] = Op(name, rest, opcode, result_ty)
        cur.order.append(name)
    return comps, entry


def _called_computations(op: Op) -> List[str]:
    out = []
    for m in _CALLED.finditer(op.rest):
        blob = m.group(1)
        for name in re.findall(r"%?([\w.\-]+)", blob):
            out.append(name)
    return out


def _operand_names(op: Op) -> List[str]:
    # operands inside the top-level parens of opcode(...)
    i = op.rest.find(op.opcode + "(")
    if i < 0:
        return []
    i += len(op.opcode) + 1
    depth = 1
    j = i
    while j < len(op.rest) and depth:
        if op.rest[j] == "(":
            depth += 1
        elif op.rest[j] == ")":
            depth -= 1
        j += 1
    seg = op.rest[i:j - 1]
    return re.findall(r"%([\w.\-]+)", seg)


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop condition is `compare(iv, constant(K))` — take the max int
    constant in the condition computation as the trip count."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops.values():
        for c in _CONST_INT.findall(op.rest):
            best = max(best, int(c))
    return best


def _dot_flops(comp: Computation, op: Op) -> int:
    """2 × prod(result dims) × prod(contracting dims of lhs)."""
    res_dims = _shape_dims(op.result_ty)
    if not res_dims:
        return 0
    out_elems = 1
    for d in res_dims[0]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rest)
    contract = 1
    if m:
        idxs = [int(x) for x in m.group(1).split(",") if x]
        operands = _operand_names(op)
        if operands:
            lhs = comp.ops.get(operands[0])
            if lhs is not None:
                lhs_dims = _shape_dims(lhs.result_ty)
                if lhs_dims:
                    for i in idxs:
                        if i < len(lhs_dims[0]):
                            contract *= lhs_dims[0][i]
    return 2 * out_elems * contract


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0

    def add(self, other: "Totals", mult: float = 1.0,
            include_hbm: bool = True):
        self.flops += other.flops * mult
        if include_hbm:
            self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult


def analyze(hlo: str, entry: Optional[str] = None) -> Dict[str, float]:
    comps, parsed_entry = parse_computations(hlo)
    if entry is None:
        entry = parsed_entry
    memo: Dict[str, Totals] = {}

    def comp_totals(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()   # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        t = Totals()
        for op_name in comp.order:
            op = comp.ops[op_name]
            oc = op.opcode
            if oc == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cond = cm.group(1) if cm else None
                body = bm.group(1) if bm else None
                # prefer the compiler-annotated trip count
                tm = _TRIP_CFG.search(op.rest)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(comps, cond) if cond else 1
                if body:
                    t.add(comp_totals(body), trips)
                continue
            if oc in ("call", "custom-call"):
                for c in _called_computations(op):
                    t.add(comp_totals(c))
            if oc == "conditional":
                subs = _called_computations(op)
                if subs:   # worst case branch? use max flops branch
                    branch_ts = [comp_totals(c) for c in subs]
                    best = max(branch_ts, key=lambda x: x.flops)
                    t.add(best)
                continue
            if oc == "fusion":
                # count internal FLOPs/collectives; HBM traffic of a
                # fusion is its own operands+result (counted below)
                for c in _called_computations(op):
                    t.add(comp_totals(c), include_hbm=False)
            if oc == "dot":
                t.flops += _dot_flops(comp, op)
            base = oc.replace("-start", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                nbytes = 0
                ops = _operand_names(op)
                for o in ops:
                    src = comp.ops.get(o)
                    if src is not None:
                        nbytes += _shape_bytes(src.result_ty)
                if nbytes == 0:
                    nbytes = _shape_bytes(op.result_ty)
                t.coll_bytes[base] = t.coll_bytes.get(base, 0) + nbytes
            # HBM traffic approximation: top-level ops only, skip
            # shape-only ops
            if oc not in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "while", "call",
                          "conditional"):
                nb = _shape_bytes(op.result_ty)
                for o in _operand_names(op):
                    src = comp.ops.get(o)
                    if src is not None:
                        nb += _shape_bytes(src.result_ty)
                t.hbm_bytes += nb
        memo[name] = t
        return t

    # entry computation: the one marked ENTRY — rely on caller or pick the
    # computation that is not referenced by others
    if entry is None:
        referenced = set()
        for c in comps.values():
            for op in c.ops.values():
                referenced.update(_called_computations(op))
        candidates = [n for n in comps if n not in referenced]
        entry = candidates[-1] if candidates else next(iter(comps))
    t = comp_totals(entry)
    out = {"flops_hlo": t.flops, "hbm_bytes_est": t.hbm_bytes,
           "collective_bytes": dict(t.coll_bytes),
           "collective_total": sum(t.coll_bytes.values()),
           "entry": entry}
    return out


def top_collectives(hlo: str, k: int = 12):
    """Largest collective sites (trip-weighted), with op metadata — the
    §Perf drill-down tool."""
    comps, entry = parse_computations(hlo)

    # computation -> cumulative trip multiplier (entry = 1)
    mult = {entry: 1.0}
    changed = True
    while changed:
        changed = False
        for cname, comp in comps.items():
            if cname not in mult:
                continue
            for op in comp.ops.values():
                if op.opcode == "while":
                    bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                    tm = _TRIP_CFG.search(op.rest)
                    trips = int(tm.group(1)) if tm else 1
                    if bm:
                        v = mult[cname] * trips
                        if mult.get(bm.group(1), 0) < v:
                            mult[bm.group(1)] = v
                            changed = True
                elif op.opcode in ("call", "fusion", "conditional",
                                   "custom-call"):
                    for c in _called_computations(op):
                        if mult.get(c, 0) < mult[cname]:
                            mult[c] = mult[cname]
                            changed = True

    sites = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0)
        if m <= 0:
            continue
        for op in comp.ops.values():
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                nbytes = 0
                for o in _operand_names(op):
                    src = comp.ops.get(o)
                    if src is not None:
                        nbytes += _shape_bytes(src.result_ty)
                if nbytes == 0:
                    nbytes = _shape_bytes(op.result_ty)
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                sites.append({
                    "kind": base, "bytes_each": nbytes, "trips": m,
                    "bytes_total": nbytes * m,
                    "result": op.result_ty.strip()[:60],
                    "op_name": meta.group(1)[-120:] if meta else "",
                })
    sites.sort(key=lambda s: -s["bytes_total"])
    return sites[:k]

"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(arch, shape)`` returns the exact abstract inputs the step
function for that cell is lowered with: weak-type-correct, shardable via
launch.shardings, zero device memory.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config
from ..models.lm import init_cache
from ..models.lm.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _model_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, SDS]:
    batch = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = SDS((B, cfg.enc_seq, cfg.d_model),
                              _model_dtype(cfg))
    if cfg.family == "vlm":
        batch["positions"] = SDS((3, B, S), jnp.int32)
    return batch


def cache_shapes(cfg: ModelConfig, B: int, S: int):
    return jax.eval_shape(
        lambda: init_cache(cfg, B, S, _model_dtype(cfg)))


def serve_extras_specs(cfg: ModelConfig, B: int, S: int,
                       kind: str) -> Dict[str, SDS]:
    ex: Dict[str, SDS] = {}
    if cfg.family == "encdec" and kind == "prefill":
        # decode takes NO memory: cross-attention K/V live in the cache
        # (projected once at prefill)
        ex["memory"] = SDS((B, cfg.enc_seq, cfg.d_model), _model_dtype(cfg))
    if cfg.family == "vlm" and kind == "prefill":
        ex["positions"] = SDS((3, B, S), jnp.int32)
    return ex


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """Abstract inputs for one (arch, shape) cell.

    Returns {"kind", "cfg", and kind-specific SDS trees}."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    out: Dict[str, Any] = {"kind": kind, "cfg": cfg, "B": B, "S": S}
    if kind == "train":
        out["batch"] = train_batch_specs(cfg, B, S)
    elif kind == "prefill":
        out["tokens"] = SDS((B, S), jnp.int32)
        out["cache"] = cache_shapes(cfg, B, S)
        out["extras"] = serve_extras_specs(cfg, B, S, "prefill")
    else:  # decode: one new token against a seq_len-deep cache
        out["token"] = SDS((B,), jnp.int32)
        out["cache"] = cache_shapes(cfg, B, S)
        out["pos"] = SDS((), jnp.int32)
        out["extras"] = serve_extras_specs(cfg, B, S, "decode")
    return out

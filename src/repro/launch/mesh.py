"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips with the pod
axis carrying cross-pod data parallelism (DCN-grade collectives only:
gradient all-reduce, optionally int8-compressed).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_shard_mesh(n_shards: int, axis: str = "data") -> Mesh:
    """1-D mesh for partitioned-graph (ring) execution.

    On hardware this is the first ``n_shards`` devices; on a laptop/CI
    host the devices are emulated — set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` BEFORE
    importing jax (tests and benchmarks re-exec a subprocess to do
    this; see tests/conftest.run_multidevice). With too few devices,
    :func:`make_mesh`'s error spells out that exact flag.
    """
    return make_mesh((n_shards,), (axis,))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """make_mesh that tolerates more host devices than the mesh needs
    (the dry-run forces 512; the single-pod mesh uses the first 256)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"BEFORE importing jax (see launch/dryrun.py)")
    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes)

"""Roofline report from the dry-run JSONs.

Per (arch × shape × mesh):
    compute term    = FLOPs/device          / 197 TFLOP/s (bf16, v5e)
    memory term     = HBM bytes/device      / 819 GB/s
    collective term = collective bytes/dev  / 50 GB/s/link (ICI)

FLOPs and bytes come from the trip-count-aware HLO analysis (dryrun
``tripaware``; raw ``cost_analysis`` undercounts loop bodies — both are
recorded). MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve);
the ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
useful (remat, replicated attention, padding all lower it).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod]
                          [--md]  # emit the EXPERIMENTS.md table
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

OUT_DIR = os.path.join("experiments", "dryrun")


def load_cells(mesh: str = "pod", out_dir: str = OUT_DIR) -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("ok"):
            out.append(r)
    return out


def analytic_hbm_bytes(r: dict) -> float:
    """Per-device HBM traffic model (bytes/step).

    Methodology (documented in EXPERIMENTS.md): text-derived byte counts
    misprice fusion and in-place cache updates, so the memory term uses an
    analytic model of the TPU execution:
      train   = 3 passes over TP-shard weights + optimizer state sweep
                + activation write/read per layer (remat ≈ ×2)
      prefill = 1 pass over weights + activations + cache write
      decode  = 1 pass over weights + full cache read + slot write
    """
    from ..configs import SHAPES, get_config, ALIASES
    cfg = get_config(r["arch"])
    sh = SHAPES[r["shape"]]
    chips = r["n_chips"]
    model_ax = 16
    data_ax = chips // model_ax
    B, S = sh["global_batch"], sh["seq_len"]
    B_loc = max(B // data_ax, 1)
    N = cfg.param_count()
    W = N * 2                                   # bf16 weights
    D = cfg.d_model

    # per-token activation bytes per layer (residual stream, bf16),
    # sharded over model between blocks
    act_layer = B_loc * S * D * 2 / model_ax
    L = cfg.n_layers + cfg.n_enc_layers

    # kv-cache bytes (global)
    if cfg.family in ("ssm",):
        cache = 0
    else:
        n_attn = (cfg.n_layers // cfg.shared_attn_every
                  if cfg.family == "hybrid" else
                  cfg.n_layers + cfg.n_enc_layers)
        kv_s = min(S, cfg.sliding_window) if (
            cfg.sliding_window and r["shape"] == "long_500k") else S
        cache = n_attn * 2 * cfg.n_kv_heads * cfg.head_dim * kv_s * B * 2

    if r["kind"] == "train":
        w_traffic = 3 * W / model_ax            # fwd + bwd + remat-fwd
        opt = 32 * N / chips                    # f32 m,v,p,g read+write
        act = 8 * act_layer * L                 # write/read ×(fwd,bwd,remat)
        ce = 2 * 2 * B_loc * S * cfg.vocab * 4 / model_ax
        return w_traffic + opt + act + ce
    if r["kind"] == "prefill":
        return W / model_ax + 4 * act_layer * L + cache / chips
    # decode: own weight shard + the FSDP-gathered TP-shard copy + cache
    return W / chips + W / model_ax + cache / chips


def roofline_row(r: dict) -> Optional[dict]:
    ta = r.get("tripaware", {})
    if "flops_hlo" not in ta:
        return None
    chips = r["n_chips"]
    flops_dev = ta["flops_hlo"]
    hbm_dev = analytic_hbm_bytes(r)
    coll_dev = ta.get("collective_total", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / LINK_BW

    mult = 6 if r["kind"] == "train" else 2
    model_flops = mult * r["active_params"] * r["tokens_global"]
    model_dev = model_flops / chips
    useful = model_dev / flops_dev if flops_dev else 0.0

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())
    # achievable MFU if perfectly overlapped = useful work over bound time
    mfu_bound = model_dev / PEAK_FLOPS / t_bound if t_bound else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "kind": r["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_dev": model_dev, "hlo_flops_dev": flops_dev,
        "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
        "temp_bytes_dev": r.get("memory_analysis", {}).get(
            "temp_size_in_bytes"),
        "arg_bytes_dev": r.get("memory_analysis", {}).get(
            "argument_size_in_bytes"),
    }


def what_would_help(row: dict) -> str:
    b = row["bottleneck"]
    if b == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound but mostly waste: fix sharding so "
                    "attention/FFN aren't replicated (useful "
                    f"{row['useful_ratio']:.0%})")
        return "compute-bound: larger per-chip batch or faster kernels"
    if b == "memory":
        return ("memory-bound: raise arithmetic intensity (fuse, widen "
                "tiles, cut remat re-reads, quantize weights for decode)")
    return ("collective-bound: shrink/overlap collectives (reduce-scatter "
            "instead of all-reduce, int8 grad compression, fewer "
            "resharding hops)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    rows = []
    for r in load_cells(args.mesh, args.out_dir):
        row = roofline_row(r)
        if row:
            rows.append(row)
    rows.sort(key=lambda x: (x["arch"], x["shape"]))

    if args.md:
        print("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
              "bound | useful | roofline frac |")
        print("|---|---|---|---|---|---|---|---|")
        for x in rows:
            print(f"| {x['arch']} | {x['shape']} "
                  f"| {x['t_compute_s']*1e3:.1f} "
                  f"| {x['t_memory_s']*1e3:.1f} "
                  f"| {x['t_collective_s']*1e3:.1f} "
                  f"| {x['bottleneck']} "
                  f"| {x['useful_ratio']:.2f} "
                  f"| {x['roofline_fraction']:.2f} |")
    else:
        for x in rows:
            print(json.dumps(x))
            print("  ->", what_would_help(x))


if __name__ == "__main__":
    main()

"""Batched serving loop: continuous prefill + decode with a KV cache.

CPU-runnable on smoke configs; on the production mesh the same step
functions are what the dry-run compiles (launch/dryrun.py lowers them).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models.lm import init_params, init_cache, prefill, decode_step, encode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    B, S = args.batch, args.prompt_len
    max_seq = S + args.gen
    params = init_params(jax.random.PRNGKey(args.seed), cfg,
                         max_seq=max_seq)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    memory = None
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)),
                             jnp.float32)
        memory = encode(params, cfg, frames)
    positions = None
    if cfg.family == "vlm":
        positions = jnp.asarray(
            np.broadcast_to(np.arange(S), (3, B, S)).copy(), jnp.int32)

    cache = init_cache(cfg, B, max_seq, jnp.float32
                       if cfg.dtype != "bfloat16" else jnp.bfloat16)

    pf = jax.jit(lambda p, t, c: prefill(p, cfg, t, c, positions=positions,
                                         memory=memory))
    # decode reads cross-attention K/V from the cache (filled at prefill)
    dc = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))

    t0 = time.perf_counter()
    logits, cache = pf(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed + 1)
    tok = jnp.argmax(logits, -1)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = dc(params, tok, cache, jnp.asarray(S + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, 1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms "
          f"({B*S/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"[serve] decode  {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print(f"[serve] sample tokens[0,:8] = {gen[0,:8].tolist()}")


if __name__ == "__main__":
    main()

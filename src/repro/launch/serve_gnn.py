"""GNN inference serving entrypoint (DESIGN.md §10).

Stands up a :class:`~repro.core.GNNServer` over a synthetic dataset and
drives it with N concurrent requester threads through a
:class:`~repro.data.RequestQueue` — the full production shape: clients
submit node-id requests and block on futures, the serving loop drains
coalescing windows through the prefetcher, batches pad onto signature
classes, and steady state runs zero recompiles.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_gnn --app gcn \
      --dataset tiny --clients 4 --requests 50
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..core import planner
from ..core.serving import SERVE_APPS, GNNServer
from ..data import RequestQueue, make_node_dataset, relational_graph
from ..models.gnn import gat, gcn, rgcn, sage
from ..obs import (export_chrome_trace, percentile_nearest_rank, snapshot,
                   span_coverage)


def build_server(app: str, dataset: str, *, mode: str = "auto",
                 classes=(8, 32, 128), d_hidden: int = 32,
                 fanout: Optional[int] = None, cache_rows: int = 4096,
                 pin_hot: int = 256, seed: int = 0) -> GNNServer:
    """Dataset + randomly-initialized model + server, ready to serve.

    (Serving correctness is parameter-agnostic — the differential tests
    pin served predictions to the full forward under the SAME params,
    so random init exercises exactly the production code path.)
    """
    key = jax.random.PRNGKey(seed)
    if app == "rgcn":
        n, n_rel = (256, 4) if dataset == "tiny" else (4096, 8)
        rels = relational_graph(n, n_rel, max(n // 2, 64), seed=seed)
        rng = np.random.default_rng(seed)
        feats = rng.standard_normal((n, 32)).astype(np.float32)
        params = rgcn.init(key, 32, d_hidden, 8, n_rel)
        return GNNServer("rgcn", params, None, feats, rels=rels, mode=mode,
                         classes=classes, fanout=fanout,
                         cache_rows=cache_rows, pin_hot=pin_hot, seed=seed)
    g, feats, _labels, _tr, _va, n_classes = make_node_dataset(dataset)
    init = {"gcn": gcn.init, "sage": sage.init, "gat": gat.init}[app]
    params = init(key, feats.shape[1], d_hidden, n_classes)
    return GNNServer(app, params, g, feats, mode=mode, classes=classes,
                     fanout=fanout, cache_rows=cache_rows, pin_hot=pin_hot,
                     seed=seed)


def run_session(srv: GNNServer, *, n_clients: int, requests_per_client: int,
                ids_fn: Callable[[np.random.Generator], np.ndarray],
                max_wait: float = 0.002, depth: int = 2,
                timeout: float = 600.0) -> Dict:
    """Drive the server with ``n_clients`` concurrent requester threads.

    Each client submits ``requests_per_client`` node-id requests
    (drawn by ``ids_fn``) and blocks on each future before the next —
    closed-loop load. Returns per-request wall latencies (submit →
    fulfilled, so queueing + batching + compute), the recompile delta
    over the steady-state window, and server stats.
    """
    srv.warmup()                       # compiles happen HERE, not under load
    compiles_before = srv.compiles
    rq = RequestQueue(max_wait=max_wait)
    lat: List[List[float]] = [[] for _ in range(n_clients)]
    errs: List[BaseException] = []

    def client(cid: int) -> None:
        rng = np.random.default_rng(1000 + cid)
        try:
            for _ in range(requests_per_client):
                req = rq.submit(ids_fn(rng))
                req.result(timeout=timeout)
                lat[cid].append(time.perf_counter() - req.t_submit)
        except BaseException as e:      # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(n_clients)]

    def close_when_done() -> None:
        for t in threads:
            t.join()
        rq.close()

    for t in threads:
        t.start()
    threading.Thread(target=close_when_done, daemon=True).start()
    t0 = time.perf_counter()
    srv.run(rq, depth=depth)           # serving loop, main thread
    elapsed = time.perf_counter() - t0
    if errs:
        raise errs[0]

    flat = sorted(x for per in lat for x in per)
    n = len(flat)
    # nearest-rank percentiles over the FULL latency vector (the old
    # floor-index arithmetic under-read both tails: p99 of 100 samples
    # returned the 99th-smallest instead of the 100th)
    return {
        "latencies": flat,
        "n_samples": n,
        "p50_ms": 1e3 * percentile_nearest_rank(flat, 50) if n else
                  float("nan"),
        "p99_ms": 1e3 * percentile_nearest_rank(flat, 99) if n else
                  float("nan"),
        "throughput_rps": n / max(elapsed, 1e-9),
        "elapsed_s": elapsed,
        "recompiles_steady": srv.compiles - compiles_before,
        "stats": srv.stats(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", choices=SERVE_APPS, default="gcn")
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "layerwise", "fanout"))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=50,
                    help="requests per client")
    ap.add_argument("--request-ids", type=int, default=4,
                    help="node ids per request")
    ap.add_argument("--classes", type=int, nargs="+", default=[8, 32, 128])
    ap.add_argument("--fanout", type=int, default=None,
                    help="override full-neighbor fanout (inexact if < "
                         "max in-degree)")
    ap.add_argument("--cache-rows", type=int, default=4096)
    ap.add_argument("--pin-hot", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export the session as Chrome-trace JSON "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--drift", action="store_true",
                    help="print the planner predicted-vs-measured "
                         "drift report after the session")
    args = ap.parse_args()

    srv = build_server(args.app, args.dataset, mode=args.mode,
                       classes=tuple(args.classes), fanout=args.fanout,
                       cache_rows=args.cache_rows, pin_hot=args.pin_hot,
                       seed=args.seed)
    n_nodes = srv.g.n_src

    def ids_fn(rng: np.random.Generator) -> np.ndarray:
        return rng.integers(0, n_nodes, args.request_ids)

    res = run_session(srv, n_clients=args.clients,
                      requests_per_client=args.requests, ids_fn=ids_fn)
    modes = {c: srv.mode_for_class(c) for c in srv.batcher.classes}
    print(f"[serve_gnn] app={args.app} dataset={args.dataset} "
          f"clients={args.clients} req/client={args.requests} "
          f"ids/req={args.request_ids}")
    print(f"[serve_gnn] class→mode {modes}")
    print(f"[serve_gnn] p50 {res['p50_ms']:.2f} ms  p99 {res['p99_ms']:.2f} "
          f"ms  {res['throughput_rps']:.0f} req/s "
          f"(n={res['n_samples']})")
    print(f"[serve_gnn] steady-state recompiles: "
          f"{res['recompiles_steady']} (must be 0)")
    st = res["stats"]
    for tier in ("out_cache", "feat_cache"):
        cs = st[tier]
        if cs is not None:
            print(f"[serve_gnn] {tier}: hit_ratio {cs.hit_ratio:.3f} "
                  f"({cs.hits}h/{cs.misses}m, {cs.evictions} evictions, "
                  f"{cs.pinned} pinned)")
    if args.trace:
        from ..obs import trace_events
        export_chrome_trace(args.trace)
        print(f"[serve_gnn] trace: {len(trace_events())} events → "
              f"{args.trace} (span coverage {span_coverage():.1%})")
    if args.drift:
        rows = planner.drift_report()
        print(f"[serve_gnn] drift report ({len(rows)} rows):")
        for r in rows:
            print(f"  {r['op']:28s} {r['chosen']:10s} "
                  f"pred={r['predicted_cost']:.3g} "
                  f"meas={1e3 * r['measured_mean_s']:.3f}ms "
                  f"ratio={r['ratio']:.2f}"
                  f"{'  DRIFTED' if r['drifted'] else ''}")
        snap = snapshot()
        batch_h = snap.get("serve.batch_seconds")
        if batch_h:
            print(f"[serve_gnn] serve.batch_seconds: "
                  f"n={batch_h['count']} mean={1e3 * batch_h['mean']:.3f}ms")
    if res["recompiles_steady"]:
        raise SystemExit("steady-state recompiles detected")


if __name__ == "__main__":
    main()

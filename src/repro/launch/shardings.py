"""Parameter / state / batch sharding rules (FSDP × TP, optional EP).

Every rule is a CHAIN of candidates; the first whose divisibility holds on
the actual mesh wins (pjit rejects non-divisible input shardings). E.g.
attention wq (D, H, Dh) prefers heads-on-'model' (Megatron TP) but falls
back to head_dim-on-'model' when H doesn't divide the axis (28, 40, 24, 12
heads on a 16-way axis), and finally to fused FSDP×TP on D.

Design (DESIGN.md §5):
  * TP on 'model': heads / FFN inner / vocab.
  * FSDP (ZeRO-3) on 'data' ('pod','data' across pods): the other large
    dim; optimizer moments inherit the parameter spec.
  * EP: expert dim on 'model' when divisible (neither assigned MoE arch
    divides 16; rule activates on meshes where it does).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm.config import ModelConfig

Axis = Any  # None | str | tuple[str, ...]
Candidate = Tuple[Axis, ...]

# (name, rank) -> candidate chain (logical axes; 'data' expands to
# ('pod','data') on multi-pod meshes).
_RULES: Dict[tuple, List[Candidate]] = {
    ("embed", 2): [("model", "data"), (None, ("model", "data")),
                   (None, "model")],
    ("lm_head", 2): [("model", "data"), (None, ("model", "data")),
                     (None, "model")],
    ("enc_pos", 2): [(None, "model")],
    ("dec_pos", 2): [(None, "model")],
    # attention
    ("wq", 3): [("data", "model", None), ("data", None, "model"),
                (("data", "model"), None, None)],
    ("wk", 3): [("data", "model", None), ("data", None, "model"),
                (("data", "model"), None, None)],
    ("wv", 3): [("data", "model", None), ("data", None, "model"),
                (("data", "model"), None, None)],
    ("wo", 3): [("model", None, "data"), (None, "model", "data"),
                (None, None, ("data", "model"))],
    ("bq", 2): [("model", None), (None, "model")],
    ("bk", 2): [("model", None), (None, "model")],
    ("bv", 2): [("model", None), (None, "model")],
    # dense mlp
    ("w_gate", 2): [("data", "model"), (None, "model")],
    ("w_up", 2): [("data", "model"), (None, "model")],
    ("w_down", 2): [("model", "data"), ("model", None)],
    ("b_up", 1): [("model",)],
    ("b_down", 1): [(None,)],
    # moe (rank 3, experts-first)
    ("router", 2): [("data", None), (None, None)],
    ("w_gate", 3): [(None, "data", "model"), (None, None, "model")],
    ("w_up", 3): [(None, "data", "model"), (None, None, "model")],
    ("w_down", 3): [(None, "model", "data"), (None, "model", None)],
    # mamba2
    ("in_proj", 2): [("data", "model"), (None, "model")],
    ("out_proj", 2): [("model", "data"), ("model", None)],
    ("conv_w", 2): [(None, "model")],
    ("conv_b", 1): [("model",)],
    ("A_log", 1): [(None,)],
    ("dt_bias", 1): [(None,)],
    ("skip_D", 1): [(None,)],
    # norms
    ("scale", 1): [(None,)],
    ("bias", 1): [(None,)],
}

_MOE_EP_RULES: Dict[tuple, List[Candidate]] = {
    ("w_gate", 3): [("model", "data", None)],
    ("w_up", 3): [("model", "data", None)],
    ("w_down", 3): [("model", None, "data")],
}


def _expand(mesh: Mesh, axis: Axis) -> Optional[Tuple[str, ...]]:
    """Logical -> flat tuple of physical mesh axis names."""
    if axis is None:
        return None
    if isinstance(axis, str):
        axis = (axis,)
    out = []
    for a in axis:
        if a == "data" and "pod" in mesh.axis_names:
            out.extend(("pod", "data"))
        else:
            out.append(a)
    return tuple(out)


def _axis_size(mesh: Mesh, axes: Optional[Tuple[str, ...]]) -> int:
    if not axes:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(mesh: Mesh, shape: Sequence[int], cand: Candidate) -> bool:
    for dim, axis in zip(shape, cand):
        sz = _axis_size(mesh, _expand(mesh, axis))
        if sz > 1 and dim % sz != 0:
            return False
    return True


def _to_spec(mesh: Mesh, cand: Candidate) -> P:
    entries = []
    for axis in cand:
        flat = _expand(mesh, axis)
        if flat is None:
            entries.append(None)
        elif len(flat) == 1:
            entries.append(flat[0])
        else:
            entries.append(tuple(flat))
    return P(*entries)


def pick_spec(mesh: Mesh, shape: Sequence[int],
              candidates: List[Candidate], *, stacked: bool = False) -> P:
    body = shape[1:] if stacked else shape
    for cand in candidates:
        if _fits(mesh, body, cand):
            spec = _to_spec(mesh, cand)
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            return spec
    return P(*((None,) * len(shape)))


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def param_specs(params_shape: Any, cfg: Optional[ModelConfig],
                mesh: Mesh, fsdp: bool = True) -> Any:
    """PartitionSpec pytree for a params (or shapes) pytree."""
    model_axis = mesh.shape.get("model", 1)
    use_ep = (cfg is not None and cfg.n_experts > 0
              and cfg.n_experts % model_axis == 0)

    # tiny expert FFNs (granite: d_ff=512) must NOT be ff-TP-sharded: each
    # device would hold 32 columns and all-reduce the full (E,C,D) buffer
    # per layer (§Perf iter 3). Replicate the weights (they're small) and
    # let the slot dim carry the parallelism.
    small_moe = (cfg is not None and cfg.n_experts > 0
                 and cfg.n_experts * cfg.d_ff * cfg.d_model * 2 * 3
                 <= 512 * 1024 * 1024)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = any(n in ("blocks", "enc_blocks") for n in names)
        rank = len(leaf.shape) - (1 if stacked else 0)
        rules = dict(_RULES)
        if use_ep:
            rules.update(_MOE_EP_RULES)
        if small_moe and rank == 3 and name in ("w_gate", "w_up",
                                                "w_down"):
            rules[(name, 3)] = [(None, "data", None), (None, None, None)]
        cands = rules.get((name, rank), [(None,) * rank])
        if not fsdp:
            cands = [tuple(None if c == "data" else c for c in cand)
                     for cand in cands]
        return pick_spec(mesh, leaf.shape, cands, stacked=stacked)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# --------------------------------------------------------------------- #
# batch / cache
# --------------------------------------------------------------------- #
def _data_if_divisible(mesh: Mesh, B: int) -> Axis:
    ax = _expand(mesh, "data")
    return "data" if B % _axis_size(mesh, ax) == 0 else None


def batch_specs(cfg: ModelConfig, kind: str, mesh: Mesh,
                batch_size: Optional[int] = None) -> Dict[str, P]:
    """Input sharding: batch on ('pod','data') when divisible."""
    d = "data" if batch_size is None else _data_if_divisible(mesh,
                                                             batch_size)
    def s(*axes):
        return _to_spec(mesh, axes)
    if kind == "train":
        spec = {"tokens": s(d, None), "labels": s(d, None)}
    elif kind == "prefill":
        spec = {"tokens": s(d, None)}
    else:
        spec = {"tokens": s(d)}
    if cfg.family == "encdec":
        spec["frames"] = s(d, None, None)
    if cfg.family == "vlm" and kind != "decode":
        spec["positions"] = s(None, d, None)
    return spec


def cache_specs(cfg: ModelConfig, mesh: Mesh,
                batch_size: Optional[int] = None,
                seq_len: Optional[int] = None,
                kind: str = "prefill") -> Any:
    """KV cache / SSM state sharding: batch on data; heads on model when
    the Q-head count divides the axis (TP attention). Otherwise:
      * prefill — cache SEQUENCE dim on model (context-parallel attention,
        §Perf iter 1: S×S score traffic stays sharded);
      * decode — head_dim on model (single-token queries make seq-sharded
        softmax combine collectives dominate; disaggregated prefill/decode
        fleets each get their best layout, §Perf decode note)."""
    d = "data" if batch_size is None else _data_if_divisible(mesh,
                                                             batch_size)
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    m = mesh.shape.get("model", 1)
    if Hq % m == 0 and Hkv % m == 0:
        s_ax, h_ax, dh_ax = None, "model", None
    elif (kind == "prefill" and seq_len is not None
          and seq_len % m == 0):
        s_ax, h_ax, dh_ax = "model", None, None
    elif Dh % m == 0:
        s_ax, h_ax, dh_ax = None, None, "model"
    else:
        s_ax = h_ax = dh_ax = None

    def attn_spec():
        kv = _to_spec(mesh, (None, d, s_ax, h_ax, dh_ax))
        spec = {"k": kv, "v": kv, "len": P(None)}
        if cfg.family == "encdec":
            spec["cross_k"] = _to_spec(mesh, (None, d, None, h_ax, dh_ax))
            spec["cross_v"] = _to_spec(mesh, (None, d, None, h_ax, dh_ax))
        return spec

    def mamba_spec(extra_lead=0):
        H = cfg.ssm_heads
        conv_c = cfg.d_inner + 2 * cfg.ssm_state
        h_ok = "model" if H % m == 0 else None
        c_ok = "model" if conv_c % m == 0 else None
        lead = (None,) * extra_lead
        return {"conv": _to_spec(mesh, lead + (None, d, None, c_ok)),
                "ssm": _to_spec(mesh, lead + (None, d, h_ok, None, None))}

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        return attn_spec()  # encdec adds cross-KV entries above
    if cfg.family == "ssm":
        return mamba_spec()
    if cfg.family == "hybrid":
        return {"mamba": mamba_spec(extra_lead=1), "attn": attn_spec()}
    raise ValueError(cfg.family)


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def resolve_axis(mesh: Mesh, name):
    """Kept for dryrun: logical->physical single-axis resolve."""
    flat = _expand(mesh, name)
    if flat is None:
        return None
    return flat[0] if len(flat) == 1 else tuple(flat)

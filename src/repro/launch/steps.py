"""Jitted train / prefill / decode step builders with full shardings.

These are the functions the dry-run lowers and the real launcher runs.
TrainState is a NamedTuple so optimizer moments shard exactly like their
parameters (ZeRO-3 via shared PartitionSpecs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm import model as lm
from ..models.lm.config import ModelConfig
from ..optim import adamw, apply_updates, clip_by_global_norm
from ..optim.optimizers import AdamState
from ..pjit_utils import ambient_mesh
from . import shardings as shard_rules


class TrainState(NamedTuple):
    params: Any
    mu: Any
    nu: Any
    step: jnp.ndarray


def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    weight_decay: float = 0.1, clip: float = 1.0,
                    microbatch: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""
    _, opt_update = adamw(lr, weight_decay=weight_decay)

    def loss_of(params, batch):
        return lm.loss_fn(params, cfg, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if microbatch > 1:
            def split(x):
                b = x.shape[0] if x.ndim < 3 or x.shape[0] != 3 else None
                # vlm positions are (3, B, S): split on axis 1
                if x.ndim == 3 and x.shape[0] == 3:
                    return x.reshape(3, microbatch, -1, x.shape[-1]
                                     ).transpose(1, 0, 2, 3)
                return x.reshape(microbatch, -1, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbi):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(state.params, mbi)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            g0 = jax.tree.map(jnp.zeros_like, state.params)
            (grads, ltot), _ = jax.lax.scan(acc_body,
                                            (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = ltot / microbatch
        else:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, clip)
        ups, new_opt = opt_update(grads, AdamState(state.mu, state.nu),
                                  state.params, state.step)
        params = apply_updates(state.params, ups)
        new_state = TrainState(params, new_opt.mu, new_opt.nu,
                               state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, cache, extras):
        return lm.prefill(params, cfg, tokens, cache,
                          positions=extras.get("positions"),
                          memory=extras.get("memory"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, pos, extras):
        return lm.decode_step(params, cfg, token, cache, pos,
                              memory=extras.get("memory"))
    return decode_step


# --------------------------------------------------------------------- #
# sharding trees for the step signatures
# --------------------------------------------------------------------- #
def state_specs(params_shape, cfg: ModelConfig, mesh: Mesh) -> TrainState:
    ps = shard_rules.param_specs(params_shape, cfg, mesh)
    return TrainState(params=ps, mu=ps, nu=ps, step=P())


def eval_param_shapes(cfg: ModelConfig, max_seq: int = 0):
    return jax.eval_shape(
        lambda k: lm.init_params(k, cfg, max_seq=max_seq),
        jax.random.PRNGKey(0))


def init_state(key, cfg: ModelConfig, max_seq: int = 0) -> TrainState:
    params = lm.init_params(key, cfg, max_seq=max_seq)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return TrainState(params=params,
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))

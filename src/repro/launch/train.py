"""LM training launcher with fault tolerance.

Runs real steps on whatever mesh fits the host (CPU: 1 device; TPU pod:
the production mesh), with checkpoint/auto-resume: the training loop
discovers the latest good checkpoint, restores state (resharding to the
current mesh if it changed — elastic restart), and continues. Data is a
deterministic synthetic token stream keyed by (seed, step) so restarts
replay identically with no sampler state to persist.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3p2_3b \
      --smoke --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..models.lm.config import ModelConfig
from ..pjit_utils import ambient_mesh
from . import shardings as SR
from .mesh import make_mesh
from .steps import TrainState, make_train_step, init_state


def synthetic_batch(cfg: ModelConfig, step: int, B: int, S: int,
                    seed: int = 0):
    """Deterministic synthetic batch — replayable across restarts."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.family == "vlm":
        batch["positions"] = jnp.asarray(
            np.broadcast_to(np.arange(S), (3, B, S)).copy(), jnp.int32)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 (needs that many devices)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(dims, ("pod", "data", "model")[-len(dims):])

    train_step = make_train_step(cfg, lr=args.lr)
    max_seq = args.seq + 8 if cfg.family == "encdec" else 0
    state = init_state(jax.random.PRNGKey(args.seed), cfg, max_seq=max_seq)

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored = mgr.restore_latest(state, mesh=mesh)
        if restored is not None:
            state, start_step = restored
            print(f"[train] resumed from step {start_step}")

    if mesh is not None:
        specs = SR.param_specs(state.params, cfg, mesh)
        sh = SR.to_named(TrainState(specs, specs, specs,
                                    jax.sharding.PartitionSpec()), mesh)
        state = jax.device_put(state, sh)
        step_fn = jax.jit(train_step, donate_argnums=(0,))
    else:
        step_fn = jax.jit(train_step, donate_argnums=(0,))

    ctx = ambient_mesh(mesh) if mesh is not None else ambient_mesh(None)
    with ctx:
        t_hist = []
        for step in range(start_step, args.steps):
            batch = synthetic_batch(cfg, step, args.batch, args.seq,
                                    args.seed)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            t_hist.append(time.perf_counter() - t0)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={t_hist[-1]*1e3:.0f}ms")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(state, step + 1)
                print(f"[train] checkpoint @ {step + 1}")
        if mgr:
            mgr.save(state, args.steps)
    med = float(np.median(t_hist)) if t_hist else float("nan")
    print(f"[train] done. median step time {med*1e3:.1f} ms")


if __name__ == "__main__":
    main()

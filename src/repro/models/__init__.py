"""repro.models — GNN applications (paper §5.1) and the LM family stack."""

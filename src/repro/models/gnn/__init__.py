"""The seven GNN applications the paper profiles (paper §5.1).

Each model is a pair of pure functions ``init(key, ...) -> params`` and
``forward(params, bundle, x, ...) -> logits`` taking an aggregation
``strategy`` so the paper's baseline ('push') and optimized ('ell' /
'pallas') paths are swappable per run — that switch IS the experiment.
"""
from .common import GraphBundle, make_bundle
from . import gcn, sage, gat, rgcn, monet, gcmc, lgnn

APPLICATIONS = {
    "gcn": gcn, "graphsage": sage, "gat": gat, "rgcn": rgcn,
    "monet": monet, "gcmc": gcmc, "lgnn": lgnn,
}

__all__ = ["GraphBundle", "make_bundle", "APPLICATIONS",
           "gcn", "sage", "gat", "rgcn", "monet", "gcmc", "lgnn"]

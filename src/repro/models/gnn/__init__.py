"""The seven GNN applications the paper profiles (paper §5.1).

Each model is a pair of pure functions ``init(key, ...) -> params`` and
``forward(params, bundle, x, ...) -> logits``. Aggregation defaults to
``strategy='auto'``: the planner (``repro.core.planner``) picks the
execution strategy per op from graph statistics and memoized packs.
Pinning ``strategy`` ('push' baseline vs 'ell'/'segment'/'pallas'
optimized) reproduces the paper's experiments — that switch IS the
experiment.
"""
from .common import GraphBundle, make_bundle
from . import gcn, sage, gat, rgcn, monet, gcmc, lgnn

APPLICATIONS = {
    "gcn": gcn, "graphsage": sage, "gat": gat, "rgcn": rgcn,
    "monet": monet, "gcmc": gcmc, "lgnn": lgnn,
}

__all__ = ["GraphBundle", "make_bundle", "APPLICATIONS",
           "gcn", "sage", "gat", "rgcn", "monet", "gcmc", "lgnn"]

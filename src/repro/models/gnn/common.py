"""Shared GNN plumbing: graph bundles riding on the planner's PlanCache,
the one code path every app's sampled-minibatch forward runs on
(:func:`run_blocks` — see DESIGN.md §5), and the partitioned-execution
bundle every app's sharded forward runs on (DESIGN.md §6)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ...core.graph import Graph
from ...core.partition import PartitionedGraph
from ...core.planner import PlanCache, get_plan_cache
from ...core.tiling import ELLPack, TilePack
from ...core.training_ops import TrainingGraph, make_training_graph
from ...substrate.nn import dropout


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class GraphBundle:
    """Graph + its PlanCache + precomputed normalization weights.

    ``cache`` is the graph's process-wide :class:`PlanCache`: its packs
    are pytree children (so they cross ``jit`` as traced arrays) and its
    stats are static aux (so the planner can run its cost model inside a
    jitted train step). ``tg`` carries the reverse-graph packs so
    weighted Copy-Reduce runs blocked-pull in the BACKWARD pass too
    (core/training_ops.py). ``mean_norm``: per-edge 1/deg_in(dst) —
    mean aggregation as weighted CR.
    """
    g: Graph
    cache: PlanCache
    gcn_norm: Optional[jnp.ndarray]  # (n_edges,) 1/sqrt(d_u d_v), caller order
    tg: Optional[TrainingGraph]
    mean_norm: Optional[jnp.ndarray]  # (n_edges,) 1/deg_in(dst)

    # back-compat views onto the cache (never build)
    @property
    def ell(self) -> Optional[ELLPack]:
        return self.cache.peek("ell")

    @property
    def tiles(self) -> Optional[TilePack]:
        return self.cache.peek("tiles")

    def use_training_graph(self, strategy: str, d: int) -> bool:
        """Route through the custom-VJP blocked pull (fwd AND bwd)?
        Yes when ell is pinned, or under auto when the cost model
        prefers blocked pull at feature width ``d``."""
        return self.tg is not None and (
            strategy == "ell"
            or (strategy == "auto" and self.cache.prefers_ell(d)))

    def tree_flatten(self):
        return ((self.g, self.cache, self.gcn_norm, self.tg,
                 self.mean_norm), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def edge_norms(g: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """Per-edge (gcn, mean) normalization weights in CALLER edge order:
    1/sqrt(deg_out(u)·deg_in(v)) and 1/deg_in(v), degrees clamped ≥ 1.
    The one home of this computation — shared by the full-graph bundle
    and the partitioned bundle."""
    deg_in = np.maximum(np.asarray(g.in_degrees, np.float64), 1)
    deg_out = np.maximum(np.asarray(g.out_degrees, np.float64), 1)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = 1.0 / np.sqrt(deg_out[src] * deg_in[dst])
    mean_w = 1.0 / deg_in[dst]
    # canonical order -> caller order
    w_caller = np.zeros_like(w)
    w_caller[np.asarray(g.eid)] = w
    m_caller = np.zeros_like(mean_w)
    m_caller[np.asarray(g.eid)] = mean_w
    return w_caller.astype(np.float32), m_caller.astype(np.float32)


def make_bundle(g: Graph, *, ell: bool = True, tiles: bool = False,
                ell_width: int = 64, training: bool = True,
                krel: Optional[int] = None) -> GraphBundle:
    """Assemble a bundle; packs are pulled from (and memoized in) the
    graph's PlanCache, so they are built at most once per process even
    across bundles and direct ``gspmm`` calls. ``krel=K`` prebuilds the
    K-relation RelGraph (MoNet's fused per-kernel aggregation) so it
    crosses jit with the cache."""
    w_caller, m_caller = edge_norms(g)
    cache = get_plan_cache(g)
    cache.set_ell_cap(ell_width)
    if ell or training:
        cache.ell()            # force-build so it crosses jit boundaries
    if tiles:
        cache.tiles()
    if krel is not None:
        cache.krel(krel)
    tg = make_training_graph(g, ell_width) if training else None
    return GraphBundle(
        g=g,
        cache=cache,
        gcn_norm=jnp.asarray(w_caller, jnp.float32),
        tg=tg,
        mean_norm=jnp.asarray(m_caller, jnp.float32),
    )


# --------------------------------------------------------------------- #
# partitioned (multi-device ring) execution bundle
# --------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class PartitionedBundle:
    """Partition plan + pre-bucketed normalization weights + the mesh.

    ``pg`` is the graph's memoized :class:`PartitionedGraph` (from the
    per-graph PlanCache, so the same partition serves direct ``gspmm``
    calls and the trains); ``gcn_w``/``mean_w`` are ``bundle.gcn_norm``/
    ``mean_norm`` scattered into the (S, S, eb) bucket layout. ``mesh``
    is static aux — ``None`` runs the emulated single-device ring, which
    is how the partitioned forwards stay testable everywhere.
    """
    pg: PartitionedGraph
    gcn_w: jnp.ndarray         # (S, S, eb) 1/sqrt(d_u d_v), 0 on pads
    mean_w: jnp.ndarray        # (S, S, eb) 1/deg_in(dst), 0 on pads
    mesh: Optional[Mesh] = dataclasses.field(
        default=None, metadata={"static": True})
    axis: str = dataclasses.field(default="data",
                                  metadata={"static": True})

    def tree_flatten(self):
        return ((self.pg, self.gcn_w, self.mean_w), (self.mesh, self.axis))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def make_partitioned_bundle(g: Graph, n_shards: int, *,
                            mesh: Optional[Mesh] = None,
                            axis: str = "data",
                            mode: str = "contiguous") -> PartitionedBundle:
    """Assemble the partitioned bundle. The partition comes from (and is
    memoized in) the graph's PlanCache; the per-edge norms are the same
    quantities :func:`make_bundle` computes, bucketed once host-side."""
    pg = get_plan_cache(g).partition(n_shards, mode)
    w_caller, m_caller = edge_norms(g)
    return PartitionedBundle(
        pg=pg,
        gcn_w=pg.scatter_edges(jnp.asarray(w_caller)),
        mean_w=pg.scatter_edges(jnp.asarray(m_caller)),
        mesh=mesh, axis=axis)


def shard_partitioned(pb: PartitionedBundle, *arrays):
    """``device_put`` the bundle and padded node arrays onto the mesh:
    bucket tensors and (n_pad, ...) node tensors shard along the first
    axis, small index maps replicate. No-op without a mesh."""
    if pb.mesh is None:
        return (pb,) + arrays if arrays else pb
    mesh, axis = pb.mesh, pb.axis
    n_pad = pb.pg.n_pad

    def put(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and (
                x.shape[0] in (pb.pg.n_shards, n_pad)):
            spec = P(axis, *([None] * (x.ndim - 1)))
        else:
            spec = P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    out = jax.tree_util.tree_map(put, (pb,) + arrays)
    return out if arrays else out[0]


# --------------------------------------------------------------------- #
# sampled-minibatch (block) forward — one code path for every app
# --------------------------------------------------------------------- #
def pad_features(feats) -> jnp.ndarray:
    """Append one zero row so global id -1 (pad) gathers zeros."""
    feats = np.asarray(feats, np.float32)
    return jnp.asarray(np.vstack([feats,
                                  np.zeros((1, feats.shape[1]),
                                           np.float32)]))


def block_features(feats_padded: jnp.ndarray, ids) -> jnp.ndarray:
    """Gather input features for padded global ids (-1 → the zero row)."""
    ids = jnp.asarray(ids)
    safe = jnp.where(ids >= 0, ids, feats_padded.shape[0] - 1)
    return jnp.take(feats_padded, safe, axis=0)


def run_blocks(block_layer: Callable, layers: Sequence, blocks: Sequence,
               h: jnp.ndarray, *, strategy: str = "auto",
               bwd_strategy: str = "auto",
               activation: Callable = jax.nn.relu, train: bool = False,
               rng=None, drop: float = 0.0) -> jnp.ndarray:
    """Drive a per-app layer function over a minibatch's blocks.

    ``block_layer(lyr, blk, h, strategy=..., bwd_strategy=...)`` maps
    the layer-l frontier features ``h`` (n_src_pad, d) to destination
    features (n_dst_real, d'). Thanks to the sampler's dst-first source
    numbering the next block's frontier IS this block's destination set,
    so the loop just chains layers — exactly the full-graph forward with
    the graph swapped per layer. The final block's destinations are the
    seeds: the return value is (batch_size, d_out), no slicing needed.

    ``bwd_strategy`` is the block DIFFERENTIATION strategy (gather /
    scatter / auto — see DESIGN.md §7), threaded to every
    ``block_gspmm`` so the planner's ``block_bwd:<op>`` decisions apply
    inside a differentiated train step.
    """
    if len(layers) != len(blocks):
        raise ValueError(f"{len(layers)} layers but {len(blocks)} blocks: "
                         f"sampler fanouts must match model depth")
    for i, (lyr, blk) in enumerate(zip(layers, blocks)):
        if train and rng is not None and drop > 0.0:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        h = block_layer(lyr, blk, h, strategy=strategy,
                        bwd_strategy=bwd_strategy)
        if i < len(layers) - 1:
            h = activation(h)
    return h

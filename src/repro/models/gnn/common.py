"""Shared GNN plumbing: graph bundles with precomputed packs."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core.graph import Graph
from ...core.tiling import ELLPack, TilePack, build_ell, build_tiles
from ...core.training_ops import TrainingGraph, make_training_graph


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class GraphBundle:
    """Graph + blocked packs + precomputed normalization weights.

    ``tg`` carries the reverse-graph packs so weighted Copy-Reduce runs
    blocked-pull in the BACKWARD pass too (core/training_ops.py).
    ``mean_norm``: per-edge 1/deg_in(dst) — mean aggregation as weighted CR.
    """
    g: Graph
    ell: Optional[ELLPack]
    tiles: Optional[TilePack]
    gcn_norm: Optional[jnp.ndarray]  # (n_edges,) 1/sqrt(d_u d_v), caller order
    tg: Optional[TrainingGraph]
    mean_norm: Optional[jnp.ndarray]  # (n_edges,) 1/deg_in(dst)

    def tree_flatten(self):
        return ((self.g, self.ell, self.tiles, self.gcn_norm, self.tg,
                 self.mean_norm), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_bundle(g: Graph, *, ell: bool = True, tiles: bool = False,
                ell_width: int = 64, training: bool = True) -> GraphBundle:
    """Build packs once per graph (host-side preprocessing)."""
    deg_in = np.asarray(g.in_degrees, np.float64)
    deg_out = np.asarray(g.out_degrees, np.float64)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = 1.0 / np.sqrt(np.maximum(deg_out[src], 1)
                      * np.maximum(deg_in[dst], 1))
    mean_w = 1.0 / np.maximum(deg_in[dst], 1)
    # canonical order -> caller order
    w_caller = np.zeros_like(w)
    w_caller[np.asarray(g.eid)] = w
    m_caller = np.zeros_like(mean_w)
    m_caller[np.asarray(g.eid)] = mean_w
    tg = make_training_graph(g, ell_width) if training else None
    return GraphBundle(
        g=g,
        ell=(tg.ell if tg is not None else
             (build_ell(g, ell_width) if ell else None)),
        tiles=build_tiles(g) if tiles else None,
        gcn_norm=jnp.asarray(w_caller, jnp.float32),
        tg=tg,
        mean_norm=jnp.asarray(m_caller, jnp.float32),
    )


def strategy_kwargs(bundle: GraphBundle, strategy: str) -> dict:
    kw = {"strategy": strategy}
    if strategy == "ell":
        kw["ell"] = bundle.ell
    elif strategy in ("onehot", "pallas"):
        kw["tiles"] = bundle.tiles
    return kw

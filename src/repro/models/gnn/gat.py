"""GAT — the paper's heaviest BR user (Table 2, row 8):

    e_copy_add_v, e_copy_max_v, u_add_v_copy_e, e_sub_v_copy_e,
    e_div_v_copy_e, u_mul_e_add_v, v_mul_e_copy_e

Attention logits per edge via the planned gSDDMM (``u_add_v_copy_e``);
normalization via edge-softmax; aggregation via ``u_mul_e_add_v`` with
per-head scalars. ``attn`` selects how much of that pipeline fuses:

    'multipass'     — gsddmm logits + composed 5-primitive softmax +
                      separate weighted aggregate (the paper's layering),
    'softmax-fused' — single-pass softmax, separate logits/aggregate,
    'fused'/'pallas'/'auto'
                    — the whole pipeline as ONE planned pass
                      (:func:`repro.core.fused_attention`, DESIGN.md §9).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gsddmm, gspmm
from ...core.blocks import block_gspmm
from ...core.edge_softmax import (block_edge_softmax,
                                  block_fused_attention, edge_softmax,
                                  edge_softmax_fused, fused_attention,
                                  fused_attention_partitioned)
from ...substrate.nn import glorot, dropout, leaky_relu
from .common import GraphBundle, PartitionedBundle, run_blocks

_ATTN_MODES = ("multipass", "softmax-fused", "fused", "pallas", "auto")


def init(key, d_in: int, d_hidden: int, n_classes: int, n_heads: int = 4,
         n_layers: int = 2) -> Dict:
    layers = []
    d = d_in
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        heads = 1 if i == n_layers - 1 else n_heads
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "w": glorot(k1, (d, heads * out)),
            "attn_l": glorot(k2, (heads, out)),
            "attn_r": glorot(k3, (heads, out)),
        })
        d = heads * out
    return {"layers": layers}


def _resolve_attn(attn: Optional[str], fused_softmax: bool) -> str:
    """Back-compat: ``fused_softmax`` predates ``attn`` and keeps its
    meaning when ``attn`` is not given."""
    if attn is None:
        return "softmax-fused" if fused_softmax else "multipass"
    if attn not in _ATTN_MODES:
        raise ValueError(f"unknown attn mode {attn!r}; expected one of "
                         f"{_ATTN_MODES}")
    return attn


def _gat_layer(lyr, bundle: GraphBundle, h, heads: int, out: int, *,
               strategy: str, attn: str):
    g = bundle.g
    z = (h @ lyr["w"]).reshape(-1, heads, out)           # (n, H, F)
    el = jnp.sum(z * lyr["attn_l"], axis=-1)             # (n, H)
    er = jnp.sum(z * lyr["attn_r"], axis=-1)
    if attn in ("fused", "pallas", "auto"):
        out_feat = fused_attention(g, el, er, z, strategy=attn)
        return out_feat.reshape(-1, heads * out)
    # u_add_v_copy_e: per-edge logits on the planned gSDDMM path; a
    # pinned gspmm strategy maps onto the sddmm lattice like gspmm's own
    # edge-output delegation (baselines pin the caller-order gather)
    sddmm_req = {"auto": "auto", "pallas": "pallas", "push": "gather",
                 "segment": "gather"}.get(strategy, "canonical")
    logits = gsddmm(g, "u_add_v_copy_e", u=el, v=er, strategy=sddmm_req)
    logits = leaky_relu(logits)
    if attn == "softmax-fused":
        alpha = edge_softmax_fused(g, logits)            # (nnz, H)
    else:
        alpha = edge_softmax(g, logits, strategy=strategy,
                             cache=bundle.cache)
    # u_mul_e_add_v with per-head scalar α is a 3-D broadcast: the
    # planner keeps it on segment/ell (pallas/onehot are rank-2 only)
    out_feat = gspmm(g, "u_mul_e_add_v", u=z, e=alpha[:, :, None],
                     strategy=strategy, cache=bundle.cache)
    return out_feat.reshape(-1, heads * out)


def forward(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False, rng=None,
            drop: float = 0.4, fused_softmax: bool = False,
            attn: Optional[str] = None) -> jnp.ndarray:
    attn = _resolve_attn(attn, fused_softmax)
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        hd = lyr["attn_l"].shape[0]     # heads encoded in param shapes
        out = lyr["attn_l"].shape[-1]
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        h = _gat_layer(lyr, bundle, h, hd, out, strategy=strategy,
                       attn=attn)
        if i < n_layers - 1:
            h = jax.nn.elu(h)
    return h


def block_layer(lyr, blk, h: jnp.ndarray, *, strategy: str = "auto",
                bwd_strategy: str = "auto",
                attn: str = "multipass") -> jnp.ndarray:
    """One GAT layer on a sampled block.

    Attention logits are per-edge over the block's sampled edges; the
    destination-side term uses ``z[:n_dst_real]`` (dst-first numbering)
    padded with one dummy row, and the softmax normalizes over each
    destination's REAL in-edges only (pads live in the dummy row)."""
    bg = blk.bg
    heads, out = lyr["attn_l"].shape
    z = (h @ lyr["w"]).reshape(-1, heads, out)           # (n_src_pad, H, F)
    el = jnp.sum(z * lyr["attn_l"], axis=-1)             # (n_src_pad, H)
    er = jnp.sum(z[: bg.n_dst_real] * lyr["attn_r"], axis=-1)
    er = jnp.concatenate([er, jnp.zeros((1, heads), er.dtype)], axis=0)
    if attn in ("fused", "pallas", "auto"):
        out_feat = block_fused_attention(bg, el, er, z, strategy=attn)
        return out_feat.reshape(bg.n_dst_real, heads * out)
    logits = gsddmm(bg.g, "u_add_v_copy_e", u=el, v=er)
    logits = leaky_relu(logits)
    alpha = block_edge_softmax(bg, logits, strategy=strategy,
                               bwd_strategy=bwd_strategy)  # (nnz, H)
    out_feat = block_gspmm(bg, "u_mul_e_add_v", u=z, e=alpha[:, :, None],
                           strategy=strategy,
                           bwd_strategy=bwd_strategy)    # (nd, H, F)
    return out_feat.reshape(bg.n_dst_real, heads * out)


def forward_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                   strategy: str = "auto", bwd_strategy: str = "auto",
                   train: bool = False, rng=None, drop: float = 0.4,
                   attn: Optional[str] = None) -> jnp.ndarray:
    """Sampled mini-batch forward on the shared block path."""
    attn = _resolve_attn(attn, False) if attn is not None else "multipass"

    def layer(lyr, blk, h, **kw):
        return block_layer(lyr, blk, h, attn=attn, **kw)

    return run_blocks(layer, params["layers"], blocks, x,
                      strategy=strategy, bwd_strategy=bwd_strategy,
                      activation=jax.nn.elu,
                      train=train, rng=rng, drop=drop)


def forward_partitioned(params: Dict, pb: PartitionedBundle,
                        x: jnp.ndarray, *, halo=None, refresh: bool = True,
                        comm_state=None, train: bool = False, rng=None,
                        drop: float = 0.4):
    """Partitioned full-graph GAT (always exact — attention weights are
    parameter-dependent, so a stale remote partial has no DistGNN-style
    formulation; delayed halos are a GCN/SAGE knob).

    Each layer is one :func:`fused_attention_partitioned` call: a ring
    pass assembles bucketed logits, the softmax normalizes each
    destination locally (every dst bucket is owner-resident), and a
    second ring pass does the α-weighted aggregation with per-head
    weights.

    int8-compressed exchanges (``comm_state``) are a GCN/SAGE knob too:
    GAT's exchanges carry pre-softmax logits whose quantization error
    amplifies through exp(), and the two-ring fused pass has no single
    payload for error feedback to track (DESIGN.md §12). Train GAT in
    bf16 with uncompressed rings instead.
    """
    if halo is not None:
        raise ValueError("GAT has no delayed-halo mode (attention "
                         "weights are parameter-dependent)")
    if comm_state is not None:
        raise ValueError("GAT has no compressed-comm mode (the fused "
                         "attention rings exchange pre-softmax logits; "
                         "see DESIGN.md §12)")
    pg = pb.pg
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        heads, out = lyr["attn_l"].shape
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        z = (h @ lyr["w"]).reshape(-1, heads, out)       # (n_pad, H, F)
        el = jnp.sum(z * lyr["attn_l"], axis=-1)         # (n_pad, H)
        er = jnp.sum(z * lyr["attn_r"], axis=-1)
        out_feat = fused_attention_partitioned(pg, el, er, z,
                                               mesh=pb.mesh, axis=pb.axis)
        h = out_feat.reshape(-1, heads * out)
        if i < n_layers - 1:
            h = jax.nn.elu(h)
    return h, None


def infer(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
          strategy: str = "auto",
          attn: Optional[str] = None) -> jnp.ndarray:
    """Inference-mode forward — the serving tier's layer-wise refresh
    entry point (dropout off, no rng threading)."""
    return forward(params, bundle, x, strategy=strategy, train=False,
                   attn=attn)


def infer_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                 strategy: str = "auto",
                 attn: Optional[str] = None) -> jnp.ndarray:
    """Inference-mode block forward — the serving tier's fan-out path.

    Defaults to the same multipass softmax family as the full forward
    so the two serve modes agree to float tolerance."""
    return forward_blocks(params, blocks, x, strategy=strategy,
                          train=False, attn=attn)

"""GAT — the paper's heaviest BR user (Table 2, row 8):

    e_copy_add_v, e_copy_max_v, u_add_v_copy_e, e_sub_v_copy_e,
    e_div_v_copy_e, u_mul_e_add_v, v_mul_e_copy_e

Attention logits per edge via ``u_add_v_copy_e``; normalization via
edge-softmax (composed from the max/sub/div chain, or the fused kernel);
aggregation via ``u_mul_e_add_v`` with per-head scalars.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.blocks import block_gspmm
from ...core.edge_softmax import (edge_softmax, edge_softmax_fused,
                                  block_edge_softmax)
from ...core.partition import (bucket_softmax, ring_edge_values,
                               ring_gspmm)
from ...substrate.nn import glorot, dropout, leaky_relu
from .common import GraphBundle, PartitionedBundle, run_blocks


def init(key, d_in: int, d_hidden: int, n_classes: int, n_heads: int = 4,
         n_layers: int = 2) -> Dict:
    layers = []
    d = d_in
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        heads = 1 if i == n_layers - 1 else n_heads
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "w": glorot(k1, (d, heads * out)),
            "attn_l": glorot(k2, (heads, out)),
            "attn_r": glorot(k3, (heads, out)),
        })
        d = heads * out
    return {"layers": layers}


def _gat_layer(lyr, bundle: GraphBundle, h, heads: int, out: int, *,
               strategy: str, fused_softmax: bool):
    g = bundle.g
    z = (h @ lyr["w"]).reshape(-1, heads, out)           # (n, H, F)
    el = jnp.sum(z * lyr["attn_l"], axis=-1)             # (n, H)
    er = jnp.sum(z * lyr["attn_r"], axis=-1)
    # u_add_v_copy_e: per-edge logits (strategy-free edge output)
    logits = gspmm(g, "u_add_v_copy_e", u=el, v=er)
    logits = leaky_relu(logits)
    if fused_softmax:
        alpha = edge_softmax_fused(g, logits)            # (nnz, H)
    else:
        alpha = edge_softmax(g, logits, strategy=strategy,
                             cache=bundle.cache)
    # u_mul_e_add_v with per-head scalar α is a 3-D broadcast: the
    # planner keeps it on segment/ell (pallas/onehot are rank-2 only)
    out_feat = gspmm(g, "u_mul_e_add_v", u=z, e=alpha[:, :, None],
                     strategy=strategy, cache=bundle.cache)
    return out_feat.reshape(-1, heads * out)


def forward(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False, rng=None,
            drop: float = 0.4, fused_softmax: bool = False) -> jnp.ndarray:
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        hd = lyr["attn_l"].shape[0]     # heads encoded in param shapes
        out = lyr["attn_l"].shape[-1]
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        h = _gat_layer(lyr, bundle, h, hd, out, strategy=strategy,
                       fused_softmax=fused_softmax)
        if i < n_layers - 1:
            h = jax.nn.elu(h)
    return h


def block_layer(lyr, blk, h: jnp.ndarray, *, strategy: str = "auto",
                bwd_strategy: str = "auto") -> jnp.ndarray:
    """One GAT layer on a sampled block.

    Attention logits are per-edge over the block's sampled edges; the
    destination-side term uses ``z[:n_dst_real]`` (dst-first numbering)
    padded with one dummy row, and the softmax normalizes over each
    destination's REAL in-edges only (pads live in the dummy row)."""
    bg = blk.bg
    heads, out = lyr["attn_l"].shape
    z = (h @ lyr["w"]).reshape(-1, heads, out)           # (n_src_pad, H, F)
    el = jnp.sum(z * lyr["attn_l"], axis=-1)             # (n_src_pad, H)
    er = jnp.sum(z[: bg.n_dst_real] * lyr["attn_r"], axis=-1)
    er = jnp.concatenate([er, jnp.zeros((1, heads), er.dtype)], axis=0)
    logits = gspmm(bg.g, "u_add_v_copy_e", u=el, v=er)
    logits = leaky_relu(logits)
    alpha = block_edge_softmax(bg, logits, strategy=strategy,
                               bwd_strategy=bwd_strategy)  # (nnz, H)
    out_feat = block_gspmm(bg, "u_mul_e_add_v", u=z, e=alpha[:, :, None],
                           strategy=strategy,
                           bwd_strategy=bwd_strategy)    # (nd, H, F)
    return out_feat.reshape(bg.n_dst_real, heads * out)


def forward_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                   strategy: str = "auto", bwd_strategy: str = "auto",
                   train: bool = False, rng=None,
                   drop: float = 0.4) -> jnp.ndarray:
    """Sampled mini-batch forward on the shared block path."""
    return run_blocks(block_layer, params["layers"], blocks, x,
                      strategy=strategy, bwd_strategy=bwd_strategy,
                      activation=jax.nn.elu,
                      train=train, rng=rng, drop=drop)


def forward_partitioned(params: Dict, pb: PartitionedBundle,
                        x: jnp.ndarray, *, halo=None, refresh: bool = True,
                        train: bool = False, rng=None, drop: float = 0.4):
    """Partitioned full-graph GAT (always exact — attention weights are
    parameter-dependent, so a stale remote partial has no DistGNN-style
    formulation; delayed halos are a GCN/SAGE knob).

    Per layer: one ring pass assembles the per-edge attention logits in
    bucket layout (``ring_edge_values``), the softmax normalizes each
    destination locally (every dst bucket is owner-resident), and a
    second ring pass does the α-weighted aggregation with per-head
    weights (``ring_gspmm``).
    """
    if halo is not None:
        raise ValueError("GAT has no delayed-halo mode (attention "
                         "weights are parameter-dependent)")
    pg = pb.pg
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        heads, out = lyr["attn_l"].shape
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        z = (h @ lyr["w"]).reshape(-1, heads, out)       # (n_pad, H, F)
        el = jnp.sum(z * lyr["attn_l"], axis=-1)         # (n_pad, H)
        er = jnp.sum(z * lyr["attn_r"], axis=-1)
        logits = ring_edge_values(pg, el, er, mesh=pb.mesh, axis=pb.axis)
        logits = leaky_relu(logits)                      # (S, S, eb, H)
        alpha = bucket_softmax(pg, logits)
        out_feat = ring_gspmm(pg, z, alpha, mesh=pb.mesh, axis=pb.axis)
        h = out_feat.reshape(-1, heads * out)
        if i < n_layers - 1:
            h = jax.nn.elu(h)
    return h, None

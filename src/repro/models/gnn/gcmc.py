"""GC-MC (graph conv matrix completion) — configs: u_copy_add_v and
u_dot_v_add_e (paper Table 2, row 5).

Bipartite user→item rating graph with R levels. Encoder: per level r a CR
over the level subgraph (both directions); decoder: bilinear score per
observed edge via the ``u_dot_v_add_e`` BR.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.graph import Graph, from_coo, reverse
from ...substrate.nn import glorot, linear_init, linear_apply
from .common import GraphBundle


def build_level_graphs(u, i, r, n_users: int, n_items: int, levels: int):
    """Per rating level: user→item Graph and its reverse."""
    import numpy as np
    fwd, bwd = [], []
    for lv in range(levels):
        m = np.asarray(r) == lv
        g = from_coo(np.asarray(u)[m], np.asarray(i)[m],
                     n_src=n_users, n_dst=n_items)
        fwd.append(g)
        bwd.append(reverse(g))
    return fwd, bwd


def init(key, d_user: int, d_item: int, d_hidden: int, d_out: int,
         levels: int) -> Dict:
    key, *ks = jax.random.split(key, 2 * levels + 4)
    return {
        "w_user": [glorot(ks[lv], (d_user, d_hidden))
                   for lv in range(levels)],
        "w_item": [glorot(ks[levels + lv], (d_item, d_hidden))
                   for lv in range(levels)],
        "fc_user": linear_init(ks[-3], d_hidden, d_out),
        "fc_item": linear_init(ks[-2], d_hidden, d_out),
        "q": jax.random.normal(ks[-1], (levels, d_out, d_out)) * 0.05,
    }


def encode(params: Dict, fwd: Sequence[Graph], bwd: Sequence[Graph],
           x_user: jnp.ndarray, x_item: jnp.ndarray, *,
           strategy: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    levels = len(fwd)
    h_item = 0.0
    h_user = 0.0
    for lv in range(levels):
        h_item = h_item + gspmm(fwd[lv], "u_copy_mean_v",
                                u=x_user @ params["w_user"][lv],
                                strategy=strategy)
        h_user = h_user + gspmm(bwd[lv], "u_copy_mean_v",
                                u=x_item @ params["w_item"][lv],
                                strategy=strategy)
    h_user = linear_apply(params["fc_user"], jax.nn.relu(h_user))
    h_item = linear_apply(params["fc_item"], jax.nn.relu(h_item))
    return h_user, h_item


def decode(params: Dict, g_all: Graph, h_user: jnp.ndarray,
           h_item: jnp.ndarray) -> jnp.ndarray:
    """Per observed edge, logits over rating levels via u_dot_v_add_e."""
    levels = params["q"].shape[0]
    logits = []
    for lv in range(levels):
        logits.append(gspmm(g_all, "u_dot_v_add_e",
                            u=h_user @ params["q"][lv], v=h_item)[:, 0])
    return jnp.stack(logits, axis=-1)          # (n_edges, levels)


def forward(params: Dict, graphs, x_user, x_item, *,
            strategy: str = "auto") -> jnp.ndarray:
    fwd, bwd, g_all = graphs
    hu, hi = encode(params, fwd, bwd, x_user, x_item, strategy=strategy)
    return decode(params, g_all, hu, hi)

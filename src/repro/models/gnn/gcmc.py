"""GC-MC (graph conv matrix completion) — configs: u_copy_add_v and
u_dot_v_add_e (paper Table 2, row 5).

Bipartite user→item rating graph with R levels. Encoder: the per-level
CRs (both directions) collapse onto TWO fused
:class:`~repro.core.hetero.RelGraph` aggregations — one user→item, one
item→user — with the rating levels as relations and the per-level
projections as the relation-indexed weight stack; decoder: bilinear
score per observed edge via the ``u_dot_v_add_e`` BR.
:func:`encode_loop` keeps the pre-refactor per-level loop as baseline
and differential reference.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.binary_reduce import gsddmm, gspmm
from ...core.graph import Graph, from_coo, reverse
from ...core.hetero import RelGraph, from_rels, hetero_gspmm
from ...substrate.nn import glorot, linear_init, linear_apply
from .common import GraphBundle


def _level_edges(u, i, r, levels: int):
    """Per rating level ``(src, dst)`` pairs, caller edge order."""
    u = np.asarray(u)
    i = np.asarray(i)
    r = np.asarray(r)
    return [(u[r == lv], i[r == lv]) for lv in range(levels)]


def build_level_relgraphs(u, i, r, n_users: int, n_items: int,
                          levels: int) -> Tuple[RelGraph, RelGraph]:
    """The encoder's two fused structures: rating levels as relations,
    user→item and item→user directions as separate RelGraphs."""
    edges = _level_edges(u, i, r, levels)
    fwd = from_rels(edges, n_src=n_users, n_dst=n_items)
    bwd = from_rels([(d, s) for s, d in edges],
                    n_src=n_items, n_dst=n_users)
    return fwd, bwd


def build_level_graphs(u, i, r, n_users: int, n_items: int, levels: int):
    """Per rating level: user→item Graph and its reverse (the
    pre-refactor per-level structures — kept for :func:`encode_loop`)."""
    fwd, bwd = [], []
    for src, dst in _level_edges(u, i, r, levels):
        g = from_coo(src, dst, n_src=n_users, n_dst=n_items)
        fwd.append(g)
        bwd.append(reverse(g))
    return fwd, bwd


def init(key, d_user: int, d_item: int, d_hidden: int, d_out: int,
         levels: int) -> Dict:
    key, *ks = jax.random.split(key, 2 * levels + 4)
    return {
        "w_user": [glorot(ks[lv], (d_user, d_hidden))
                   for lv in range(levels)],
        "w_item": [glorot(ks[levels + lv], (d_item, d_hidden))
                   for lv in range(levels)],
        "fc_user": linear_init(ks[-3], d_hidden, d_out),
        "fc_item": linear_init(ks[-2], d_hidden, d_out),
        "q": jax.random.normal(ks[-1], (levels, d_out, d_out)) * 0.05,
    }


def encode(params: Dict, fwd: RelGraph, bwd: RelGraph,
           x_user: jnp.ndarray, x_item: jnp.ndarray, *,
           strategy: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused encoder: both directions are ONE ``hetero_gspmm`` each —
    the level loop is gone; the per-level projections ride as the
    relation-indexed weight stack."""
    h_item = hetero_gspmm(fwd, x_user, w=jnp.stack(params["w_user"]),
                          reduce="mean", strategy=strategy)
    h_user = hetero_gspmm(bwd, x_item, w=jnp.stack(params["w_item"]),
                          reduce="mean", strategy=strategy)
    h_user = linear_apply(params["fc_user"], jax.nn.relu(h_user))
    h_item = linear_apply(params["fc_item"], jax.nn.relu(h_item))
    return h_user, h_item


def encode_loop(params: Dict, fwd: Sequence[Graph], bwd: Sequence[Graph],
                x_user: jnp.ndarray, x_item: jnp.ndarray, *,
                strategy: str = "auto"
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-refactor reference: one CR per level per direction."""
    levels = len(fwd)
    h_item = 0.0
    h_user = 0.0
    for lv in range(levels):
        h_item = h_item + gspmm(fwd[lv], "u_copy_mean_v",
                                u=x_user @ params["w_user"][lv],
                                strategy=strategy)
        h_user = h_user + gspmm(bwd[lv], "u_copy_mean_v",
                                u=x_item @ params["w_item"][lv],
                                strategy=strategy)
    h_user = linear_apply(params["fc_user"], jax.nn.relu(h_user))
    h_item = linear_apply(params["fc_item"], jax.nn.relu(h_item))
    return h_user, h_item


def decode(params: Dict, g_all: Graph, h_user: jnp.ndarray,
           h_item: jnp.ndarray) -> jnp.ndarray:
    """Per observed edge, logits over rating levels via u_dot_v_add_e —
    a planned gSDDMM (one ``sddmm:u_dot_v_copy_e`` row per level)."""
    levels = params["q"].shape[0]
    logits = []
    for lv in range(levels):
        logits.append(gsddmm(g_all, "u_dot_v_add_e",
                             u=h_user @ params["q"][lv], v=h_item)[:, 0])
    return jnp.stack(logits, axis=-1)          # (n_edges, levels)


def forward(params: Dict, graphs, x_user, x_item, *,
            strategy: str = "auto") -> jnp.ndarray:
    """``graphs = (fwd, bwd, g_all)``: RelGraphs run the fused encoder;
    per-level Graph lists delegate to the pre-refactor loop."""
    fwd, bwd, g_all = graphs
    if isinstance(fwd, RelGraph):
        hu, hi = encode(params, fwd, bwd, x_user, x_item,
                        strategy=strategy)
    else:
        hu, hi = encode_loop(params, fwd, bwd, x_user, x_item,
                             strategy=strategy)
    return decode(params, g_all, hu, hi)

"""GCN (Kipf & Welling) — aggregation config: u_copy_add_v (paper Table 2).

H^{l+1} = σ( D^{-1/2} (A+I) D^{-1/2} H^l W^l )

The symmetric normalization is folded into per-edge scalar weights
(`bundle.gcn_norm`), so the hot op is ``u_mul_e_add_v`` with a scalar edge
operand — which every strategy (including the weighted Pallas SpMM)
supports.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.blocks import block_gspmm
from ...core.partition import ring_gspmm, ring_gspmm_delayed
from ...core.training_ops import weighted_copy_reduce
from ...substrate.nn import linear_init, linear_apply, dropout
from .common import GraphBundle, PartitionedBundle, run_blocks


def init(key, d_in: int, d_hidden: int, n_classes: int,
         n_layers: int = 2) -> Dict:
    dims = [d_in] + [d_hidden] * (n_layers - 1) + [n_classes]
    keys = jax.random.split(key, n_layers)
    return {"layers": [linear_init(k, dims[i], dims[i + 1])
                       for i, k in enumerate(keys)]}


def forward(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False,
            rng=None, drop: float = 0.5) -> jnp.ndarray:
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        h = linear_apply(lyr, h)
        if bundle.use_training_graph(strategy, h.shape[-1]):
            # blocked pull in fwd AND bwd (custom VJP over the reverse pack)
            h = weighted_copy_reduce(bundle.tg, h, bundle.gcn_norm[:, None])
        else:
            h = gspmm(bundle.g, "u_mul_e_add_v", u=h,
                      e=bundle.gcn_norm[:, None], strategy=strategy,
                      cache=bundle.cache)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def block_layer(lyr, blk, h: jnp.ndarray, *, strategy: str = "auto",
                bwd_strategy: str = "auto") -> jnp.ndarray:
    """One GCN layer on a sampled block: linear, then the weighted sum
    ``u_mul_e_add_v`` with the FULL graph's symmetric normalization
    gathered per sampled edge (``blk.gcn_norm``; pad edges weigh 0).
    With fanout ≥ max in-degree this is exactly the full-graph layer."""
    h = linear_apply(lyr, h)
    return block_gspmm(blk.bg, "u_mul_e_add_v", u=h,
                       e=blk.gcn_norm[:, None], strategy=strategy,
                       bwd_strategy=bwd_strategy)


def forward_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                   strategy: str = "auto", bwd_strategy: str = "auto",
                   train: bool = False, rng=None,
                   drop: float = 0.5) -> jnp.ndarray:
    """Sampled mini-batch forward on the shared block path."""
    return run_blocks(block_layer, params["layers"], blocks, x,
                      strategy=strategy, bwd_strategy=bwd_strategy,
                      activation=jax.nn.relu,
                      train=train, rng=rng, drop=drop)


def init_halo(params: Dict, pg):
    """Zero remote-partial carry for the delayed-halo mode: one
    (n_pad, d_out) array per layer (GCN aggregates AFTER the linear)."""
    return tuple(jnp.zeros((pg.n_pad, lyr["w"].shape[1]), jnp.float32)
                 for lyr in params["layers"])


def init_comm(params: Dict, pg):
    """Zero error-feedback residual for int8-compressed ring exchanges:
    one fp32 (n_pad, d_out) array per layer — the residual lives at the
    exchange payload's shape (GCN exchanges the post-linear features).
    See DESIGN.md §12."""
    return tuple(jnp.zeros((pg.n_pad, lyr["w"].shape[1]), jnp.float32)
                 for lyr in params["layers"])


def forward_partitioned(params: Dict, pb: PartitionedBundle,
                        x: jnp.ndarray, *, halo=None, refresh: bool = True,
                        comm_state=None, train: bool = False, rng=None,
                        drop: float = 0.5):
    """Full-graph forward on a vertex-partitioned graph (DESIGN.md §6).

    ``x``: (n_pad, d) padded node layout (``pg.scatter_nodes``). With
    ``halo`` (a tuple from :func:`init_halo`) the cross-shard partial
    aggregates are recomputed only when ``refresh`` and otherwise
    reused stale — DistGNN-style delayed halos. Returns
    ``(logits_pad, halo_out)``.

    With ``comm_state`` (a tuple from :func:`init_comm`) every refreshed
    cross-shard exchange quantizes its payload to int8 with per-block
    scales and error feedback (DESIGN.md §12); the return grows to
    ``(logits_pad, halo_out, comm_out)``.
    """
    pg = pb.pg
    h = x
    halo_out = []
    comm_out = []
    comm = "none" if comm_state is None else "int8"
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        h = linear_apply(lyr, h)
        if halo is None:
            if comm_state is None:
                h = ring_gspmm(pg, h, pb.gcn_w, mesh=pb.mesh, axis=pb.axis)
            else:
                h, res = ring_gspmm(pg, h, pb.gcn_w, mesh=pb.mesh,
                                    axis=pb.axis, comm="int8",
                                    residual=comm_state[i])
                comm_out.append(res)
        else:
            if comm_state is None:
                h, stale = ring_gspmm_delayed(pg, h, pb.gcn_w, halo[i],
                                              refresh, mesh=pb.mesh,
                                              axis=pb.axis)
            else:
                h, stale, res = ring_gspmm_delayed(
                    pg, h, pb.gcn_w, halo[i], refresh, mesh=pb.mesh,
                    axis=pb.axis, comm="int8", residual=comm_state[i])
                comm_out.append(res)
            halo_out.append(stale)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    halo_ret = tuple(halo_out) if halo is not None else None
    if comm_state is None:
        return h, halo_ret
    return h, halo_ret, tuple(comm_out)


def infer(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
          strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode forward — the serving tier's layer-wise refresh
    entry point (dropout off, no rng threading)."""
    return forward(params, bundle, x, strategy=strategy, train=False)


def infer_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                 strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode block forward — the serving tier's fan-out path."""
    return forward_blocks(params, blocks, x, strategy=strategy,
                          train=False)

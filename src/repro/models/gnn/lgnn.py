"""LGNN (line graph neural network, community detection on SBM).

The application that exercises the paper's §4 framework primitives:
BatchNorm1d after every conv and an Embedding table for initial node
representations — plus TWO aggregation streams (node graph G and its line
graph L), which is why the paper calls it "particularly suitable".

Layer (simplified but structurally faithful to Chen et al.):
  x' = BN(ρ( x θ1 + (deg·x) θ2 + CR_G(x) θ3 + (P y) θ4 ))
  y' = BN(ρ( y φ1 + (deg_L·y) φ2 + CR_L(y) φ3 + (Pᵀ x) φ4 ))
where P maps line-graph (edge) features back to nodes (e_copy_add_v)
and Pᵀ projects node features onto line nodes: per edge e=(u→v) the
endpoint sum x_u + x_v — the ``u_add_v_copy_e`` gSDDMM (planned,
``sddmm:u_add_v_copy_e`` in the plan log).

The three aggregation streams (CR_G, P, CR_L) ride the relation-fused
machinery: :func:`build_relgraph` stacks them as a 3-relation
:class:`~repro.core.hetero.RelGraph` over the disjoint node∪line-node
space, and :func:`forward` runs them as ONE fused ``hetero_gspmm`` per
layer (θ3/θ4/φ3 ride as the relation-indexed weight stack — linearity
makes agg(x)@θ ≡ agg(x@θ)). Without a prebuilt RelGraph the
pre-refactor three-call path runs (also the differential reference).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.binary_reduce import gsddmm, gspmm
from ...core.graph import Graph, from_coo
from ...core.hetero import RelGraph, caller_coo, from_rels, hetero_gspmm
from ...substrate.batchnorm import batchnorm1d_init, batchnorm1d_apply
from ...substrate.embedding import embedding_init, embedding_lookup
from ...substrate.nn import glorot
from .common import GraphBundle


def build_line_graph(g: Graph, max_out: int = 10_000_000) -> Graph:
    """Line graph: edges of G are nodes of L; e1→e2 iff dst(e1)=src(e2)."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    eid = np.asarray(g.eid)
    n = g.n_edges
    # group edges by source node
    order = np.argsort(src, kind="stable")
    by_src = {}
    for pos in order:
        by_src.setdefault(int(src[pos]), []).append(int(eid[pos]))
    ls, ld = [], []
    for pos in range(n):
        e1 = int(eid[pos])
        for e2 in by_src.get(int(dst[pos]), ()):
            if e2 != e1:
                ls.append(e1)
                ld.append(e2)
                if len(ls) >= max_out:
                    raise ValueError("line graph too large")
    return from_coo(np.asarray(ls, np.int64), np.asarray(ld, np.int64),
                    n_src=n, n_dst=n)


def build_relgraph(g: Graph, lg: Graph) -> RelGraph:
    """Stack the layer's three aggregation streams as one RelGraph.

    Node space = G's nodes (ids 0..n-1) ∪ line nodes (ids n..n+E-1, one
    per edge of G, numbered by caller edge id — L's vertex ids).
    Relations: 0 = G's edges (CR_G), 1 = line-node→dst(e) (the P
    operator: e_copy_add_v), 2 = L's edges (CR_L).
    """
    n, E = g.n_dst, g.n_edges
    g_src, g_dst = caller_coo(g)
    l_src, l_dst = caller_coo(lg)
    rels = [
        (g_src, g_dst),                     # CR_G
        (np.arange(E, dtype=np.int64) + n, g_dst),   # P: line node e→dst(e)
        (l_src + n, l_dst + n),             # CR_L
    ]
    return from_rels(rels, n_src=n + E, n_dst=n + E)


def init(key, n_nodes: int, d_emb: int, d_hidden: int, n_classes: int,
         n_layers: int = 3) -> Dict:
    key, ke = jax.random.split(key)
    layers = []
    dx, dy = d_emb + 1, 1          # node emb + degree; line-graph starts with degree
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        key, *ks = jax.random.split(key, 9)
        layers.append({
            "t1": glorot(ks[0], (dx, out)),
            "t2": glorot(ks[1], (dx, out)),
            "t3": glorot(ks[2], (dx, out)),
            "t4": glorot(ks[3], (dy, out)),
            "p1": glorot(ks[4], (dy, out)),
            "p2": glorot(ks[5], (dy, out)),
            "p3": glorot(ks[6], (dy, out)),
            "p4": glorot(ks[7], (dx, out)),    # Pᵀ skip (node → line)
            "bn_x": batchnorm1d_init(out),
            "bn_y": batchnorm1d_init(out),
        })
        dx, dy = out, out
    return {"embed": embedding_init(ke, n_nodes, d_emb), "layers": layers}


def _fused_aggs(rg: RelGraph, x, y, lyr, n: int, strategy: str):
    """agg_x@t3 + ey@t4 (node rows) and agg_y@p3 (line rows) as ONE
    relation-fused aggregation over the union space. Features and the
    per-relation weights zero-pad to the wider of (dx, dy) — padded
    columns multiply zero rows, so the sum is exact."""
    dx, dy, out = (lyr["t3"].shape[0], lyr["p3"].shape[0],
                   lyr["t3"].shape[1])
    dmax = max(dx, dy)

    def padf(a, d):
        return a if d == dmax else jnp.pad(a, ((0, 0), (0, dmax - d)))

    def padw(wm, d):
        return wm if d == dmax else jnp.pad(wm, ((0, dmax - d), (0, 0)))

    z = jnp.concatenate([padf(x, dx), padf(y, dy)], axis=0)
    w = jnp.stack([padw(lyr["t3"], dx), padw(lyr["t4"], dy),
                   padw(lyr["p3"], dy)])
    fused = hetero_gspmm(rg, z, w=w, strategy=strategy)
    return fused[:n], fused[n:]


def forward(params: Dict, g: Graph, lg: Graph, *,
            rg: Optional[RelGraph] = None, strategy: str = "auto",
            train: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """Returns (node logits, params-with-updated-BN-stats). With ``rg``
    (from :func:`build_relgraph`) each layer's three aggregation
    streams run as one fused pass; without it, the pre-refactor
    three-call path."""
    n = g.n_dst
    deg = g.in_degrees.astype(jnp.float32)[:, None]
    deg_l = lg.in_degrees.astype(jnp.float32)[:, None]
    ids = jnp.arange(n)
    x = jnp.concatenate([embedding_lookup(params["embed"], ids), deg],
                        axis=-1)
    y = deg_l / jnp.maximum(deg_l.max(), 1.0)
    new_layers = []
    for i, lyr in enumerate(params["layers"]):
        # Pᵀ x: endpoint sums per edge of G = line-node features, in
        # caller edge order (= L's vertex numbering). A planned gSDDMM,
        # shared by both branches.
        px = gsddmm(g, "u_add_v_copy_e", u=x, v=x)
        if rg is not None:
            xa, ya = _fused_aggs(rg, x, y, lyr, n, strategy)
            xn = x @ lyr["t1"] + (deg * x) @ lyr["t2"] + xa
            yn = (y @ lyr["p1"] + (deg_l * y) @ lyr["p2"] + ya
                  + px @ lyr["p4"])
        else:
            agg_x = gspmm(g, "u_copy_add_v", u=x, strategy=strategy)
            ey = gspmm(g, "e_copy_add_v", e=y, strategy=strategy)  # P·y
            xn = (x @ lyr["t1"] + (deg * x) @ lyr["t2"]
                  + agg_x @ lyr["t3"] + ey @ lyr["t4"])
            agg_y = gspmm(lg, "u_copy_add_v", u=y, strategy=strategy)
            yn = (y @ lyr["p1"] + (deg_l * y) @ lyr["p2"]
                  + agg_y @ lyr["p3"] + px @ lyr["p4"])
        xn = jax.nn.relu(xn)
        yn = jax.nn.relu(yn)
        xn, bn_x = batchnorm1d_apply(lyr["bn_x"], xn, train=train)
        yn, bn_y = batchnorm1d_apply(lyr["bn_y"], yn, train=train)
        new_layers.append({**lyr, "bn_x": bn_x, "bn_y": bn_y})
        x, y = xn, yn
    new_params = {"embed": params["embed"], "layers": new_layers}
    return x, new_params

"""MoNet (Gaussian mixture model conv) — config: u_mul_e_add_v (Table 2).

Edge pseudo-coordinates p_e = (1/√deg(u), 1/√deg(v)); per mixture kernel k
the edge weight is w_k(e) = exp(-½ Σ_d (p_ed - μ_kd)² / σ²_kd). The K
per-kernel aggregations execute as ONE fused pass over a K-relation
:class:`~repro.core.hetero.RelGraph` (the edge set replicated per
kernel, memoized in the bundle's PlanCache — ``make_bundle(g, krel=K)``
prebuilds it so the fused path serves jitted train steps); without a
prebuilt RelGraph the pre-refactor per-kernel loop runs, which is also
the differential reference.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.hetero import hetero_gspmm
from ...substrate.nn import linear_init, linear_apply
from .common import GraphBundle


def init(key, d_in: int, d_hidden: int, n_classes: int,
         n_kernels: int = 3, n_layers: int = 2) -> Dict:
    layers = []
    d = d_in
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "fc": linear_init(k1, d, out * n_kernels, bias=False),
            "mu": jax.random.normal(k2, (n_kernels, 2)) * 0.1,
            "inv_sigma": jnp.ones((n_kernels, 2))
                         + jax.random.normal(k3, (n_kernels, 2)) * 0.01,
        })
        d = out
    return {"layers": layers}


def edge_pseudo_coords(bundle: GraphBundle) -> jnp.ndarray:
    """(n_edges, 2) pseudo-coords in caller edge order."""
    g = bundle.g
    du = 1.0 / jnp.sqrt(jnp.maximum(g.out_degrees.astype(jnp.float32), 1))
    dv = 1.0 / jnp.sqrt(jnp.maximum(g.in_degrees.astype(jnp.float32), 1))
    pu = gspmm(g, "u_copy_add_e", u=du[:, None])  # per-edge src value
    pv = gspmm(g, "v_copy_add_e", v=dv[:, None])
    return jnp.concatenate([pu, pv], axis=-1)


def forward(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False,
            rng=None) -> jnp.ndarray:
    pseudo = edge_pseudo_coords(bundle)                  # (nnz, 2)
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        K = lyr["mu"].shape[0]       # kernels encoded in param shapes
        z = linear_apply(lyr["fc"], h)                   # (n, K*out)
        out = z.shape[-1] // K
        z = z.reshape(-1, K, out)
        diff = pseudo[:, None, :] - lyr["mu"]            # (nnz, K, 2)
        logw = -0.5 * jnp.sum((diff * lyr["inv_sigma"]) ** 2, axis=-1)
        w = jnp.exp(logw)                                # (nnz, K)
        rg = bundle.cache.krel(K)
        if rg is not None:
            # one fused pass over the K-relation graph: per-kernel
            # features index (src, kernel), per-kernel weights ride as
            # the relation-concatenated e operand
            acc = hetero_gspmm(rg, z, e=w.T.reshape(-1),
                               strategy=strategy)
        else:
            # no prebuilt RelGraph (e.g. traced bundle that never saw
            # make_bundle(krel=K)): the pre-refactor per-kernel loop
            acc = 0.0
            for k in range(K):
                acc = acc + gspmm(bundle.g, "u_mul_e_add_v", u=z[:, k],
                                  e=w[:, k:k + 1], strategy=strategy,
                                  cache=bundle.cache)
        h = acc / K
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h

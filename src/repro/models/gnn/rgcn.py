"""R-GCN (relational GCN) — config: u_copy_add_v per relation (Table 2).

h'_v = σ( W_0 h_v + Σ_r Σ_{u∈N_r(v)} (1/c_{v,r}) W_r h_u )

Basis decomposition keeps the parameter count bounded for many relations
(BGS has 103). All relations execute as ONE fused aggregation over a
:class:`~repro.core.hetero.RelGraph` (``hetero_gspmm`` — the basis
composition is a relation-indexed einsum inside the op, the normalizer
1/c_{v,r} its per-relation mean reduce); ``strategy`` routes through
the planner's ``hetero:<op>`` rows. :func:`forward_loop` keeps the
pre-refactor per-relation loop of ``gspmm`` calls as the measured
baseline and differential reference. Sampled training rides the shared
block path: the relational sampler tags every sampled edge with its
relation id (``SampledBlock.rel``/``rel_norm``) and
:func:`block_layer` fuses all relations per block via
``hetero_block_gspmm``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.binary_reduce import gspmm
from ...core.graph import Graph, from_coo
from ...core.hetero import (RelGraph, from_rels, hetero_gspmm,
                            hetero_block_gspmm)
from ...substrate.nn import glorot
from .common import GraphBundle, run_blocks


def init(key, d_in: int, d_hidden: int, n_classes: int, n_rel: int,
         n_bases: int = 4, n_layers: int = 2) -> Dict:
    layers = []
    d = d_in
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "basis": glorot(k1, (n_bases, d, out)),
            "coeff": jax.random.normal(k2, (n_rel, n_bases)) * 0.3,
            "self": glorot(k3, (d, out)),
        })
        d = out
    return {"layers": layers}


def build_relgraph(rels: Sequence, n: int) -> RelGraph:
    """BGS-like typed graph from per-relation ``(src, dst)`` pairs."""
    return from_rels(list(rels), n_src=n, n_dst=n)


def merged_graph(rels: Sequence, n: int):
    """Flat (untyped) merged graph + caller-order relation ids — what
    the relational :class:`~repro.data.NeighborSampler` consumes."""
    src = np.concatenate([np.asarray(s, np.int64) for s, _ in rels])
    dst = np.concatenate([np.asarray(d, np.int64) for _, d in rels])
    rel = np.concatenate([np.full(len(np.asarray(s)), r, np.int64)
                          for r, (s, _) in enumerate(rels)])
    return from_coo(src, dst, n_src=n, n_dst=n), rel


def forward(params: Dict, rg, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False,
            rng=None) -> jnp.ndarray:
    """Full-graph forward over a :class:`RelGraph` (fused path).

    A sequence of per-relation ``Graph``s still works (delegates to
    :func:`forward_loop`) so pre-refactor callers keep running.
    """
    if not isinstance(rg, RelGraph):
        return forward_loop(params, rg, x, strategy=strategy,
                            train=train, rng=rng)
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        h = (h @ lyr["self"]
             + hetero_gspmm(rg, h, basis=lyr["basis"],
                            coeff=lyr["coeff"], reduce="mean",
                            strategy=strategy))
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def forward_loop(params: Dict, rel_graphs: Sequence[Graph],
                 x: jnp.ndarray, *, strategy: str = "auto",
                 train: bool = False, rng=None) -> jnp.ndarray:
    """Pre-refactor reference: one mean CR per relation, R sequential
    ``gspmm`` calls — the per-type launch overhead the fused path
    removes. Kept as the fig_hetero baseline and the differential
    anchor for :func:`forward`."""
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        w_rel = jnp.einsum("rb,bio->rio", lyr["coeff"], lyr["basis"])
        acc = h @ lyr["self"]
        for r, g in enumerate(rel_graphs):
            hr = h @ w_rel[r]
            acc = acc + gspmm(g, "u_copy_mean_v", u=hr, strategy=strategy)
        h = acc
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


# --------------------------------------------------------------------- #
# sampled minibatch path (relational blocks — DESIGN.md §8.5)
# --------------------------------------------------------------------- #
def block_layer(lyr, blk, h: jnp.ndarray, *, strategy: str = "auto",
                bwd_strategy: str = "auto") -> jnp.ndarray:
    """One R-GCN layer on a sampled relational block: the self loop on
    the destinations' own features plus ONE fused relation-indexed
    aggregation (``blk.rel`` carries the sampled edges' relation ids,
    ``blk.rel_norm`` the per-(dst, relation) sampled-mean weights)."""
    if blk.rel is None:
        raise ValueError("R-GCN blocks need relation ids: sample with "
                         "NeighborSampler(..., edge_rel=...)")
    bg = blk.bg
    w_rel = jnp.einsum("rb,bio->rio", lyr["coeff"], lyr["basis"])
    agg = hetero_block_gspmm(bg, blk.rel, h, w_rel, norm=blk.rel_norm,
                             strategy=strategy, bwd_strategy=bwd_strategy)
    return h[: bg.n_dst_real] @ lyr["self"] + agg


def forward_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                   strategy: str = "auto", bwd_strategy: str = "auto",
                   train: bool = False, rng=None) -> jnp.ndarray:
    """Sampled mini-batch forward on the shared ``run_blocks`` path."""
    return run_blocks(block_layer, params["layers"], blocks, x,
                      strategy=strategy, bwd_strategy=bwd_strategy,
                      activation=jax.nn.relu, train=train, rng=rng)


def infer(params: Dict, rg, x: jnp.ndarray, *,
          strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode forward — the serving tier's layer-wise refresh
    entry point (no rng threading)."""
    return forward(params, rg, x, strategy=strategy, train=False)


def infer_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                 strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode relational block forward — the serving tier's
    fan-out path."""
    return forward_blocks(params, blocks, x, strategy=strategy,
                          train=False)

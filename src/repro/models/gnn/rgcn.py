"""R-GCN (relational GCN) — config: u_copy_add_v per relation (Table 2).

h'_v = σ( W_0 h_v + Σ_r Σ_{u∈N_r(v)} (1/c_{v,r}) W_r h_u )

Basis decomposition keeps the parameter count bounded for many relations
(BGS has 103). Each relation owns a Graph; aggregation is one CR per
relation (mean-normalized).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.graph import Graph
from ...substrate.nn import glorot
from .common import GraphBundle


def init(key, d_in: int, d_hidden: int, n_classes: int, n_rel: int,
         n_bases: int = 4, n_layers: int = 2) -> Dict:
    layers = []
    d = d_in
    for i in range(n_layers):
        out = n_classes if i == n_layers - 1 else d_hidden
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "basis": glorot(k1, (n_bases, d, out)),
            "coeff": jax.random.normal(k2, (n_rel, n_bases)) * 0.3,
            "self": glorot(k3, (d, out)),
        })
        d = out
    return {"layers": layers}


def forward(params: Dict, rel_graphs: Sequence[Graph], x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False,
            rng=None) -> jnp.ndarray:
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        w_rel = jnp.einsum("rb,bio->rio", lyr["coeff"], lyr["basis"])
        acc = h @ lyr["self"]
        for r, g in enumerate(rel_graphs):
            hr = h @ w_rel[r]
            acc = acc + gspmm(g, "u_copy_mean_v", u=hr, strategy=strategy)
        h = acc
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h

"""GraphSAGE (mean aggregator) — config: u_copy_add_v (paper Table 2).

Full-graph and sampled (paper Fig. 3) variants. h'_v =
σ(W·[h_v ; mean_{u∈N(v)} h_u]).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from ...core.binary_reduce import gspmm
from ...core.blocks import block_gspmm
from ...core.partition import ring_gspmm, ring_gspmm_delayed
from ...core.training_ops import weighted_copy_reduce
from ...substrate.nn import linear_init, linear_apply, dropout
from .common import GraphBundle, PartitionedBundle, run_blocks


def init(key, d_in: int, d_hidden: int, n_classes: int,
         n_layers: int = 2) -> Dict:
    dims = [d_in] + [d_hidden] * (n_layers - 1) + [n_classes]
    keys = jax.random.split(key, n_layers)
    return {"layers": [linear_init(k, 2 * dims[i], dims[i + 1])
                       for i, k in enumerate(keys)]}


def forward(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
            strategy: str = "auto", train: bool = False, rng=None,
            drop: float = 0.5) -> jnp.ndarray:
    h = x
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        if bundle.use_training_graph(strategy, h.shape[-1]):
            # mean = weighted CR with 1/deg(dst); blocked pull both ways
            hn = weighted_copy_reduce(bundle.tg, h,
                                      bundle.mean_norm[:, None])
        else:
            hn = gspmm(bundle.g, "u_copy_mean_v", u=h, strategy=strategy,
                       cache=bundle.cache)
        h = linear_apply(lyr, jnp.concatenate([h, hn], axis=-1))
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def block_layer(lyr, blk, h: jnp.ndarray, *, strategy: str = "auto",
                bwd_strategy: str = "auto") -> jnp.ndarray:
    """One SAGE layer on a sampled block: mean over sampled in-edges
    (mask-corrected, pad slots contribute zero) concat the destination's
    own features (dst-first numbering: ``h[:n_dst_real]``)."""
    bg = blk.bg
    hn = block_gspmm(bg, "u_copy_mean_v", u=h, strategy=strategy,
                     bwd_strategy=bwd_strategy)
    return linear_apply(lyr, jnp.concatenate(
        [h[: bg.n_dst_real], hn], axis=-1))


def forward_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                   strategy: str = "auto", bwd_strategy: str = "auto",
                   train: bool = False, rng=None,
                   drop: float = 0.5) -> jnp.ndarray:
    """Sampled mini-batch forward (paper Fig. 3) on the shared path."""
    return run_blocks(block_layer, params["layers"], blocks, x,
                      strategy=strategy, bwd_strategy=bwd_strategy,
                      activation=jax.nn.relu,
                      train=train, rng=rng, drop=drop)


def init_halo(params: Dict, pg):
    """Zero remote-partial carry per layer: SAGE aggregates the layer
    INPUT (before the linear), so the halo width is w.shape[0] // 2."""
    return tuple(jnp.zeros((pg.n_pad, lyr["w"].shape[0] // 2), jnp.float32)
                 for lyr in params["layers"])


def init_comm(params: Dict, pg):
    """Zero error-feedback residual for int8-compressed ring exchanges:
    fp32, at the exchange payload's shape — SAGE exchanges the layer
    INPUT (width w.shape[0] // 2). See DESIGN.md §12."""
    return tuple(jnp.zeros((pg.n_pad, lyr["w"].shape[0] // 2), jnp.float32)
                 for lyr in params["layers"])


def forward_partitioned(params: Dict, pb: PartitionedBundle,
                        x: jnp.ndarray, *, halo=None, refresh: bool = True,
                        comm_state=None, train: bool = False, rng=None,
                        drop: float = 0.5):
    """Partitioned full-graph forward: the neighbor mean is a weighted
    ring CR (1/deg folded into ``pb.mean_w``); the self term needs no
    communication. Optional DistGNN-style delayed halo as in GCN, and
    optional int8-compressed exchanges via ``comm_state`` (a tuple from
    :func:`init_comm`) — the return then grows to
    ``(logits_pad, halo_out, comm_out)``."""
    pg = pb.pg
    h = x
    halo_out = []
    comm_out = []
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        if train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train)
        if halo is None:
            if comm_state is None:
                hn = ring_gspmm(pg, h, pb.mean_w, mesh=pb.mesh,
                                axis=pb.axis)
            else:
                hn, res = ring_gspmm(pg, h, pb.mean_w, mesh=pb.mesh,
                                     axis=pb.axis, comm="int8",
                                     residual=comm_state[i])
                comm_out.append(res)
        else:
            if comm_state is None:
                hn, stale = ring_gspmm_delayed(pg, h, pb.mean_w, halo[i],
                                               refresh, mesh=pb.mesh,
                                               axis=pb.axis)
            else:
                hn, stale, res = ring_gspmm_delayed(
                    pg, h, pb.mean_w, halo[i], refresh, mesh=pb.mesh,
                    axis=pb.axis, comm="int8", residual=comm_state[i])
                comm_out.append(res)
            halo_out.append(stale)
        h = linear_apply(lyr, jnp.concatenate([h, hn], axis=-1))
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    halo_ret = tuple(halo_out) if halo is not None else None
    if comm_state is None:
        return h, halo_ret
    return h, halo_ret, tuple(comm_out)


def forward_sampled(params: Dict, blocks, feats_fn, *,
                    strategy: str = "auto", batch_size: int
                    ) -> jnp.ndarray:
    """Back-compat wrapper: gather inputs via ``feats_fn`` then run the
    shared block path. ``feats_fn`` maps padded global ids (-1 = pad) to
    zero-padded features."""
    h = feats_fn(blocks[0].src_ids)
    return forward_blocks(params, blocks, h, strategy=strategy)[:batch_size]


def infer(params: Dict, bundle: GraphBundle, x: jnp.ndarray, *,
          strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode forward — the serving tier's layer-wise refresh
    entry point (dropout off, no rng threading)."""
    return forward(params, bundle, x, strategy=strategy, train=False)


def infer_blocks(params: Dict, blocks, x: jnp.ndarray, *,
                 strategy: str = "auto") -> jnp.ndarray:
    """Inference-mode block forward — the serving tier's fan-out path."""
    return forward_blocks(params, blocks, x, strategy=strategy,
                          train=False)

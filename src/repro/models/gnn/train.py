"""Full-graph GNN training loop (paper Fig. 2 protocol).

One jitted step = forward + CE loss on the train mask + AdamW update;
per-epoch wall time is the paper's reported metric. ``strategy`` selects
the aggregation implementation — 'auto' (default) lets the planner pick
per op from graph statistics (the bundle's PlanCache carries static
stats through the jitted step); pinning 'push' reproduces the DGL
baseline and 'ell'/'segment' the optimized paths.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...optim import adamw, apply_updates, clip_by_global_norm
from ...substrate.nn import cross_entropy_loss, accuracy


def make_train_step(forward_fn: Callable, strategy: str, lr: float = 1e-2,
                    weight_decay: float = 5e-4, clip: float = 5.0):
    opt_init, opt_update = adamw(lr, weight_decay=weight_decay)

    @partial(jax.jit, static_argnames=())
    def step(params, opt_state, step_i, bundle, x, labels, mask, rng):
        def loss_fn(p):
            logits = forward_fn(p, bundle, x, strategy=strategy,
                                train=True, rng=rng)
            return cross_entropy_loss(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, clip)
        ups, opt_state = opt_update(grads, opt_state, params, step_i)
        params = apply_updates(params, ups)
        return params, opt_state, loss

    return opt_init, step


def train_full_graph(forward_fn: Callable, params: Dict, bundle, x,
                     labels, train_mask, *, strategy: str = "auto",
                     epochs: int = 10, lr: float = 1e-2, seed: int = 0,
                     val_mask=None) -> Tuple[Dict, Dict]:
    """Returns (params, history) with per-epoch times and losses."""
    opt_init, step = make_train_step(forward_fn, strategy, lr=lr)
    opt_state = opt_init(params)
    x = jnp.asarray(x)
    labels = jnp.asarray(labels)
    mask = jnp.asarray(train_mask)
    rng = jax.random.PRNGKey(seed)

    history = {"loss": [], "epoch_time": [], "val_acc": []}
    # warmup compile (excluded from timing, like the paper's epoch averages)
    p, o, l = step(params, opt_state, 0, bundle, x, labels, mask, rng)
    jax.block_until_ready(l)

    for e in range(epochs):
        rng, sub = jax.random.split(rng)
        t0 = time.perf_counter()
        params, opt_state, loss = step(params, opt_state, e, bundle, x,
                                       labels, mask, sub)
        jax.block_until_ready(loss)
        history["epoch_time"].append(time.perf_counter() - t0)
        history["loss"].append(float(loss))
        if val_mask is not None:
            logits = forward_fn(params, bundle, x, strategy=strategy)
            history["val_acc"].append(float(accuracy(
                logits, labels, jnp.asarray(val_mask))))
    return params, history

"""GNN training loops: full-graph (paper Fig. 2), sampled minibatch
(paper Fig. 3), and partitioned multi-device full-graph
(:func:`train_partitioned`, DESIGN.md §6).

One jitted step = forward + CE loss + AdamW update; per-epoch wall time
is the paper's reported metric. ``strategy`` selects the aggregation
implementation — 'auto' (default) lets the planner pick per op: from
graph statistics for full graphs (the bundle's PlanCache carries static
stats through the jitted step), from the shape-keyed block plan cache
for sampled minibatches. Pinning 'push' reproduces the DGL baseline and
'ell'/'segment' the optimized paths.

The sampled loop (:func:`train_sampled`) overlaps host-side neighbor
sampling with the device step via a double-buffered prefetcher, pads the
short final batch up to the static batch size (loss rows masked by
``MiniBatch.label_mask``), and tracks the minibatch shape signatures so
an accidental de-staticization fails loudly instead of recompiling per
batch.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...data.pipeline import prefetch
from ...data.sampler import NeighborSampler
from ...obs import events as _obs_events
from ...obs.signatures import SignatureTracker
from ...obs.spans import span as _span
from ...optim import (adamw, apply_updates, cast_logits, cast_tree,
                      clip_by_global_norm, Precision)
from ...substrate.nn import cross_entropy_loss, accuracy
from .common import (block_features, make_partitioned_bundle,
                     pad_features, shard_partitioned)


def _resolve_precision(precision) -> Precision:
    """Accept None (fp32), a name ("fp32"/"bf16"), or a Precision."""
    if precision is None:
        return Precision.fp32()
    if isinstance(precision, str):
        return Precision.parse(precision)
    return precision


def make_train_step(forward_fn: Callable, strategy: str, lr: float = 1e-2,
                    weight_decay: float = 5e-4, clip: float = 5.0,
                    precision=None):
    """Mixed precision (DESIGN.md §12): parameters and optimizer moments
    stay fp32 master copies; the forward runs on ``precision.compute``
    casts, the loss is always taken on fp32 logits, and the cast's VJP
    hands fp32 gradients back to AdamW — SplitSGD-style."""
    precision = _resolve_precision(precision)
    opt_init, opt_update = adamw(lr, weight_decay=weight_decay)

    @partial(jax.jit, static_argnames=())
    def step(params, opt_state, step_i, bundle, x, labels, mask, rng):
        def loss_fn(p):
            pc = cast_tree(p, precision.compute)
            xc = cast_tree(x, precision.compute)
            logits = forward_fn(pc, bundle, xc, strategy=strategy,
                                train=True, rng=rng)
            return cross_entropy_loss(cast_logits(logits), labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, clip)
        ups, opt_state = opt_update(grads, opt_state, params, step_i)
        params = apply_updates(params, ups)
        return params, opt_state, loss

    return opt_init, step


def train_full_graph(forward_fn: Callable, params: Dict, bundle, x,
                     labels, train_mask, *, strategy: str = "auto",
                     epochs: int = 10, lr: float = 1e-2, seed: int = 0,
                     val_mask=None, precision=None) -> Tuple[Dict, Dict]:
    """Returns (params, history) with per-epoch times and losses."""
    precision = _resolve_precision(precision)
    opt_init, step = make_train_step(forward_fn, strategy, lr=lr,
                                     precision=precision)
    opt_state = opt_init(params)
    x = jnp.asarray(x)
    labels = jnp.asarray(labels)
    mask = jnp.asarray(train_mask)
    rng = jax.random.PRNGKey(seed)

    history = {"loss": [], "epoch_time": [], "val_acc": []}
    # warmup compile (excluded from timing, like the paper's epoch averages)
    p, o, l = step(params, opt_state, 0, bundle, x, labels, mask, rng)
    jax.block_until_ready(l)

    for e in range(epochs):
        rng, sub = jax.random.split(rng)
        t0 = time.perf_counter()
        params, opt_state, loss = step(params, opt_state, e, bundle, x,
                                       labels, mask, sub)
        jax.block_until_ready(loss)
        history["epoch_time"].append(time.perf_counter() - t0)
        history["loss"].append(float(loss))
        if val_mask is not None:
            logits = forward_fn(params, bundle, x, strategy=strategy)
            history["val_acc"].append(float(accuracy(
                logits, labels, jnp.asarray(val_mask))))
    return params, history


# --------------------------------------------------------------------- #
# partitioned multi-device full-graph training (DESIGN.md §6)
# --------------------------------------------------------------------- #
def make_partitioned_train_step(forward_part_fn: Callable,
                                lr: float = 1e-2,
                                weight_decay: float = 5e-4,
                                clip: float = 5.0, drop: float = 0.0,
                                precision=None):
    """One jitted step over padded sharded node arrays.

    ``forward_part_fn(params, pb, x, halo=..., refresh=..., ...)``
    returns ``(logits_pad, halo_out)``. Features/labels/masks stay in
    the padded layout end-to-end (pad rows are loss-masked); parameters
    are replicated, so with a mesh installed the partitioned loss makes
    GSPMD emit the gradient all-reduce on its own. ``refresh`` is
    static: exact steps and stale-halo steps are two compilations of
    the same function.

    Mixed precision works as in :func:`make_train_step` (fp32 masters,
    compute-dtype casts inside the loss, fp32 logits). When
    ``precision.comm == "int8"`` the step carries the per-layer
    error-feedback residual ``comm`` (from the model's ``init_comm``)
    through the train state: the forward is called with
    ``comm_state=comm`` and its third return becomes next step's
    residual. ``comm=None`` runs uncompressed exchanges; the step
    returns ``(params, opt_state, loss, halo_out, comm_out)`` either
    way (``comm_out`` mirrors ``comm``'s None-ness).
    """
    precision = _resolve_precision(precision)
    opt_init, opt_update = adamw(lr, weight_decay=weight_decay)

    @partial(jax.jit, static_argnames=("refresh",))
    def step(params, opt_state, step_i, pb, xp, yp, mp, halo, comm, rng,
             refresh=True):
        def loss_fn(p):
            pc = cast_tree(p, precision.compute)
            xc = cast_tree(xp, precision.compute)
            if comm is None:
                logits, halo_out = forward_part_fn(
                    pc, pb, xc, halo=halo, refresh=refresh,
                    train=True, rng=rng, drop=drop)
                comm_out = None
            else:
                logits, halo_out, comm_out = forward_part_fn(
                    pc, pb, xc, halo=halo, refresh=refresh,
                    comm_state=comm, train=True, rng=rng, drop=drop)
            return (cross_entropy_loss(cast_logits(logits), yp, mp),
                    (halo_out, comm_out))

        (loss, (halo_out, comm_out)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, _ = clip_by_global_norm(grads, clip)
        ups, opt_state = opt_update(grads, opt_state, params, step_i)
        params = apply_updates(params, ups)
        return params, opt_state, loss, halo_out, comm_out

    return opt_init, step


def train_partitioned(forward_part_fn: Callable, params: Dict, g, x,
                      labels, train_mask, *, n_shards: int, mesh=None,
                      axis: str = "data", mode: str = "contiguous",
                      halo_staleness: int = 0, epochs: int = 10,
                      lr: float = 1e-2, weight_decay: float = 5e-4,
                      drop: float = 0.0, seed: int = 0, val_mask=None,
                      init_halo_fn: Optional[Callable] = None,
                      precision=None,
                      init_comm_fn: Optional[Callable] = None
                      ) -> Tuple[Dict, Dict]:
    """Full-graph training across ``n_shards`` vertex shards.

    Features are scattered once into the padded sharded layout and the
    whole run stays there (labels padded with masked rows); parameters
    are replicated and gradients all-reduced by GSPMD. ``mesh=None``
    trains on the emulated single-device ring (bit-for-bit the same
    math — used by tests and anywhere without emulated devices).

    ``halo_staleness=0`` is exact every step; ``k > 0`` refreshes the
    cross-shard partial aggregates every k-th epoch and reuses them
    stale in between (DistGNN-style; needs ``init_halo_fn``, e.g.
    ``gcn.init_halo``). Returns (params, history) with per-epoch wall
    times, losses, and which epochs refreshed.

    ``precision`` ("fp32"/"bf16" or a :class:`~repro.optim.Precision`)
    selects the compute dtype (masters stay fp32) and, via
    ``precision.comm == "int8"``, per-block-scaled int8 ring exchanges
    with error feedback — which needs ``init_comm_fn`` (e.g.
    ``gcn.init_comm``) to seed the per-layer residual carried in the
    train state (DESIGN.md §12).
    """
    precision = _resolve_precision(precision)
    pb = make_partitioned_bundle(g, n_shards, mesh=mesh, axis=axis,
                                 mode=mode)
    pg = pb.pg
    # the subsystem's execution decision, in the shared plan log (so
    # BENCH_partitioned.json reports it like every planner-routed op)
    from ...core import planner as _planner
    _planner._record(
        "partitioned:train", "auto",
        (f"ring:s{n_shards}:{mode}" if mesh is not None
         else f"ring-emulated:s{n_shards}:{mode}") + f":{precision.tag()}",
        dtype=str(jnp.dtype(precision.compute)))
    x = jnp.asarray(np.asarray(x, np.float32))
    yp = pg.scatter_nodes(jnp.asarray(np.asarray(labels, np.int32)))
    mp = pg.scatter_nodes(jnp.asarray(np.asarray(train_mask, bool)))
    xp = pg.scatter_nodes(x)
    vp = (pg.scatter_nodes(jnp.asarray(np.asarray(val_mask, bool)))
          if val_mask is not None else None)

    delayed = halo_staleness > 0
    if delayed and init_halo_fn is None:
        raise ValueError("halo_staleness > 0 needs init_halo_fn "
                         "(e.g. gcn.init_halo)")
    halo = init_halo_fn(params, pg) if delayed else None
    if precision.comm == "int8" and init_comm_fn is None:
        raise ValueError('precision.comm == "int8" needs init_comm_fn '
                         "(e.g. gcn.init_comm)")
    comm = (init_comm_fn(params, pg)
            if precision.comm == "int8" else None)

    opt_init, step = make_partitioned_train_step(
        forward_part_fn, lr=lr, weight_decay=weight_decay, drop=drop,
        precision=precision)
    opt_state = opt_init(params)
    if mesh is not None:
        pb, xp, yp, mp = shard_partitioned(pb, xp, yp, mp)
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(mesh, P())
        params = jax.device_put(params, rep)
        opt_state = jax.device_put(opt_state, rep)
        if delayed:
            halo = shard_partitioned(pb, *halo)[1:]
        if comm is not None:
            comm = shard_partitioned(pb, *comm)[1:]
    rng = jax.random.PRNGKey(seed)

    @jax.jit
    def eval_logits(params, pb, xp):
        pc = cast_tree(params, precision.compute)
        xc = cast_tree(xp, precision.compute)
        return cast_logits(forward_part_fn(pc, pb, xc)[0])

    history = {"loss": [], "epoch_time": [], "val_acc": [],
               "refreshed": []}
    # warmup: compile both refresh variants, discard the updates
    step(params, opt_state, 0, pb, xp, yp, mp, halo, comm, rng,
         refresh=True)
    if delayed:
        step(params, opt_state, 0, pb, xp, yp, mp, halo, comm, rng,
             refresh=False)

    for e in range(epochs):
        refresh = (not delayed) or (e % halo_staleness == 0)
        rng, sub = jax.random.split(rng)
        t0 = time.perf_counter()
        params, opt_state, loss, halo, comm = step(
            params, opt_state, e, pb, xp, yp, mp, halo, comm, sub,
            refresh=refresh)
        jax.block_until_ready(loss)
        history["epoch_time"].append(time.perf_counter() - t0)
        history["loss"].append(float(loss))
        history["refreshed"].append(bool(refresh))
        if vp is not None:
            logits = eval_logits(params, pb, xp)
            history["val_acc"].append(float(accuracy(logits, yp, vp)))
    return params, history


# --------------------------------------------------------------------- #
# sampled minibatch training (paper Fig. 3)
# --------------------------------------------------------------------- #
def make_sampled_train_step(forward_blocks_fn: Callable, strategy: str,
                            bwd_strategy: str = "auto",
                            lr: float = 1e-2, weight_decay: float = 5e-4,
                            clip: float = 5.0, precision=None):
    """One jitted step over a :class:`~repro.data.MiniBatch` pytree.

    The minibatch's static aux (padded sizes + fanouts) keys the jit
    cache, so every batch of one sampler configuration reuses a single
    compilation; block planning inside the trace is shape-keyed and thus
    identical for all of them. Pad seed rows are masked out of the loss.
    ``bwd_strategy`` selects the block differentiation path (DESIGN.md
    §7): 'auto' (default) lets the planner route ∂x through the
    reverse-table gather VJP, 'scatter' pins the autodiff baseline.
    Mixed precision as in :func:`make_train_step` (DESIGN.md §12).
    """
    precision = _resolve_precision(precision)
    opt_init, opt_update = adamw(lr, weight_decay=weight_decay)

    @jax.jit
    def step(params, opt_state, step_i, mb, feats_pad, rng):
        def loss_fn(p):
            pc = cast_tree(p, precision.compute)
            x = cast_tree(block_features(feats_pad, mb.input_ids),
                          precision.compute)
            logits = forward_blocks_fn(pc, mb.blocks, x, strategy=strategy,
                                       bwd_strategy=bwd_strategy,
                                       train=True, rng=rng)
            return cross_entropy_loss(cast_logits(logits), mb.labels,
                                      mb.label_mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, clip)
        ups, opt_state = opt_update(grads, opt_state, params, step_i)
        params = apply_updates(params, ups)
        return params, opt_state, loss

    return opt_init, step


def _drift_probe(forward_blocks_fn, params, mb, feats_pad, strategy,
                 bwd_strategy) -> None:
    """Once-per-new-signature eager probe feeding the drift report.

    The jitted train step never executes block ops eagerly, so the
    ``block:*`` / ``block_bwd:*`` plan rows would have predictions but
    no measurements. This runs the block forward un-jitted (the timed
    hooks in core/blocks.py fire) and replays its VJP (the custom
    gather backward executes eagerly at ``vjp_fn`` call time). It rides
    the compile batch — a NEW signature already pays a trace+compile —
    so steady-state per-step time is untouched.
    """
    if not _obs_events.enabled():
        return
    with _span("train.drift_probe"):
        x = block_features(feats_pad, mb.input_ids)

        def f(p):
            return forward_blocks_fn(p, mb.blocks, x, strategy=strategy,
                                     bwd_strategy=bwd_strategy,
                                     train=False)

        jax.block_until_ready(f(params))        # eager fwd → block:*
        out, vjp_fn = jax.vjp(f, params)
        jax.block_until_ready(vjp_fn(jnp.ones_like(out)))  # block_bwd:*


def train_sampled(forward_blocks_fn: Callable, params: Dict, g, feats,
                  labels, train_ids, *, fanouts=(10, 10),
                  batch_size: int = 64, strategy: str = "auto",
                  bwd_strategy: str = "auto",
                  epochs: int = 5, lr: float = 1e-2,
                  weight_decay: float = 5e-4, seed: int = 0,
                  prefetch_depth: int = 2, drop_last: bool = False,
                  sampler: Optional[NeighborSampler] = None,
                  max_batches: Optional[int] = None,
                  precision=None) -> Tuple[Dict, Dict]:
    """End-to-end minibatch training: sample (host, prefetched) → one
    jitted step (device) per batch.

    Returns (params, history); history splits per-epoch wall time into
    ``sample_time`` (host time the consumer actually waited on the
    prefetcher) and ``step_time`` (device step incl. transfer) — the
    sampling-vs-aggregation split the Fig. 3 benchmark reports.
    """
    labels = np.asarray(labels)
    train_ids = np.asarray(train_ids)
    opt_init, step = make_sampled_train_step(
        forward_blocks_fn, strategy, bwd_strategy=bwd_strategy,
        lr=lr, weight_decay=weight_decay,
        precision=_resolve_precision(precision))
    opt_state = opt_init(params)
    feats_pad = pad_features(feats)
    if sampler is None:
        sampler = NeighborSampler(g, fanouts, batch_size, seed=seed)
    rng = jax.random.PRNGKey(seed)
    tracker = SignatureTracker()
    history = {"loss": [], "epoch_time": [], "sample_time": [],
               "step_time": [], "n_batches": []}
    step_i = 0
    for _ in range(epochs):
        # one top-level span per epoch; sample/step/probe spans nest
        # under it, so the exported trace tiles the whole run
        with _span("train.epoch"):
            it = prefetch(sampler.batches(train_ids, labels[train_ids],
                                          drop_last=drop_last),
                          depth=prefetch_depth)
            t_epoch = time.perf_counter()
            t_sample = t_step = 0.0
            losses = []
            try:
                while max_batches is None or len(losses) < max_batches:
                    t0 = time.perf_counter()
                    with _span("train.sample"):
                        mb = next(it, None)
                    if mb is None:
                        break
                    t_sample += time.perf_counter() - t0
                    # signature-change work is hoisted behind the
                    # tracker: only a NEW signature (⇒ a fresh compile)
                    # re-checks the bound — unchanged batches skip the
                    # per-step accounting (the sampler likewise reuses
                    # one cached label-mask array per real-seed count
                    # instead of re-padding)
                    if tracker.observe_checked(mb.shape_signature()):
                        _drift_probe(forward_blocks_fn, params, mb,
                                     feats_pad, strategy, bwd_strategy)
                    rng, sub = jax.random.split(rng)
                    t0 = time.perf_counter()
                    with _span("train.step") as sp:
                        params, opt_state, loss = step(params, opt_state,
                                                       step_i, mb,
                                                       feats_pad, sub)
                        sp.fence(loss)
                        jax.block_until_ready(loss)
                    t_step += time.perf_counter() - t0
                    losses.append(float(loss))
                    step_i += 1
                # stop the clock before close(): the join waits out an
                # abandoned in-flight sample no train step consumed
                t_epoch = time.perf_counter() - t_epoch
            finally:
                it.close()  # never leave the producer thread mid-batch
        history["loss"].append(float(np.mean(losses)) if losses
                               else float("nan"))
        history["epoch_time"].append(t_epoch)
        history["sample_time"].append(t_sample)
        history["step_time"].append(t_step)
        history["n_batches"].append(len(losses))
    return params, history

"""repro.models.lm — the assigned LM-family architecture stack."""
from .config import ModelConfig
from . import layers, model, moe, mamba2
from .model import (init_params, loss_fn, prefill, decode_step, init_cache,
                    backbone, encode)

__all__ = ["ModelConfig", "layers", "model", "moe", "mamba2",
           "init_params", "loss_fn", "prefill", "decode_step", "init_cache",
           "backbone", "encode"]

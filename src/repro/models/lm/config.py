"""Model configuration for the assigned LM-family architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    d_head: Optional[int] = None     # default d_model // n_heads
    qkv_bias: bool = False           # qwen2 family
    rope_theta: float = 1e6
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2): shared attention block applied every N ssm layers
    shared_attn_every: int = 0

    # attention extras
    sliding_window: int = 0          # 0 = full attention (mixtral SWA)
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE

    # encoder-decoder (whisper): n_layers counts DECODER layers
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend sequence length (1500)

    # training defaults
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape cell (DESIGN.md)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        Dh, Hq, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
        mlp_dense = 3 * D * F if self.act == "swiglu" else 2 * D * F
        if self.family == "moe":
            mlp = self.n_experts * mlp_dense + D * self.n_experts  # + router
        else:
            mlp = mlp_dense
        if self.family == "ssm":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            blk = (D * (2 * di + 2 * N + H)       # in_proj
                   + self.ssm_conv * (di + 2 * N)  # depthwise conv
                   + 2 * H                        # A_log, dt_bias
                   + di                           # skip D
                   + di * D)                      # out_proj
            return emb + self.n_layers * (blk + 2 * D)
        if self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            blk = (D * (2 * di + 2 * N + H) + self.ssm_conv * (di + 2 * N)
                   + 2 * H + di + di * D)
            shared = attn + mlp_dense + 4 * D
            return emb + self.n_layers * (blk + 2 * D) + shared
        per_layer = attn + mlp + 4 * D
        total = emb + self.n_layers * per_layer
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + mlp_dense + 4 * D)
            total += self.n_layers * (attn + 2 * D)   # cross-attention
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        mlp_dense = 3 * D * F if self.act == "swiglu" else 2 * D * F
        return self.param_count() - \
            self.n_layers * (self.n_experts - self.top_k) * mlp_dense

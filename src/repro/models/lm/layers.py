"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, MLP.

Attention is blockwise (flash-style online softmax via ``lax.scan`` over
KV chunks) so 32k-prefill activations never materialize an S×S score
matrix; sliding-window attention masks within the same machinery.
All einsums keep explicit head axes so TP sharding (heads on 'model')
propagates cleanly through XLA SPMD.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ...pjit_utils import current_mesh, shard_hint
from .config import ModelConfig


def _attn_parallel_mode(cfg: ModelConfig, seq_len: int) -> Optional[str]:
    """Pick the attention sharding strategy for the ambient mesh.

    'heads'   — Megatron TP when n_heads divides the model axis;
    'context' — sequence(context)-parallel otherwise: q is sharded on S
                over 'model' and only the (small, GQA) K/V are gathered.
                Removes the Dh-fallback resharding storm for head counts
                like 28/40/24/12 on a 16-way axis (§Perf iteration 1).
    """
    mesh = current_mesh()
    if mesh is None:
        return None
    m = mesh.shape.get("model", 1)
    if m <= 1:
        return None
    if cfg.n_heads % m == 0:
        return "heads"
    if seq_len >= m:
        return "context"
    return None

# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def norm_init(d: int, kind: str) -> Dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def norm_apply(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:   # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:             # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------- #
# RoPE (+ M-RoPE)
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """(B, S, head_dim/2) rotation angles.

    ``positions``: (B, S) for standard RoPE, or (3, B, S) for M-RoPE where
    the three rows are (t, h, w) coordinates and ``sections`` splits the
    head_dim/2 frequency slots among them (qwen2-vl).
    """
    freqs = rope_freqs(head_dim, theta)           # (hd/2,)
    if positions.ndim == 2:
        return positions[..., None].astype(jnp.float32) * freqs
    assert sections and sum(sections) == head_dim // 2, \
        "M-RoPE sections must sum to head_dim/2"
    parts = []
    off = 0
    for row, sec in enumerate(sections):
        f = freqs[off:off + sec]
        parts.append(positions[row][..., None].astype(jnp.float32) * f)
        off += sec
    return jnp.concatenate(parts, axis=-1)        # (B, S, hd/2)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, Dh), angles: (B, S, Dh/2). Rotates pairs (even, odd)."""
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def attention_init(key, cfg: ModelConfig, dtype) -> Dict:
    D, Dh = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (D, Hq, Dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (D, Hkv, Dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (D, Hkv, Dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (Hq, Dh, D)) * s).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, Dh), dtype)
        p["bk"] = jnp.zeros((Hkv, Dh), dtype)
        p["bv"] = jnp.zeros((Hkv, Dh), dtype)
    return p


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, Dh) -> (B, S, Hkv*groups, Dh) by head replication."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)
                            ).reshape(b, s, h * groups, d)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool, window: int = 0,
                        q_offset: int = 0, kv_len: Optional[jnp.ndarray] = None,
                        block: int = 512) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Sq, H, Dh); k/v: (B, Skv, H, Dh) (kv heads already repeated).
    ``q_offset``: absolute position of q[0] (prefill continuation/decode).
    ``kv_len``: optional dynamic valid-length of the KV (cache decoding).
    ``window``: sliding-window size (0 = unlimited).
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    scale = Dh ** -0.5
    nblk = -(-Skv // block)
    pad = nblk * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, H, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, Dh).transpose(1, 0, 2, 3, 4)

    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        acc, m, denom = carry
        kblk, vblk, blk_i = xs
        kpos = blk_i * block + jnp.arange(block)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kblk.astype(jnp.float32))
        mask = jnp.ones((Sq, block), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        if pad:
            mask &= kpos[None, :] < Skv
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, H, Sq, Dh), jnp.float32)
    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    # flash-style backward: recompute per-block scores/masks instead of
    # saving them as scan residuals (otherwise bwd holds S×S worth of
    # probabilities + masks)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (acc, m, denom), _ = jax.lax.scan(
        body, (acc0, m0, d0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)    # (B, Sq, H, Dh)


def attention_kv(p: Dict, cfg: ModelConfig, src: jnp.ndarray):
    """K/V projection only (used to precompute cross-attention KV once
    at prefill instead of re-projecting the encoder memory every decode
    step — §Perf whisper note)."""
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


def attention_apply(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    angles: Optional[jnp.ndarray], *,
                    causal: bool = True,
                    memory: Optional[jnp.ndarray] = None,
                    kv_override=None,
                    cache: Optional[Dict] = None,
                    q_offset: int = 0,
                    block: int = 512
                    ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Self- or cross-attention with optional KV cache.

    ``memory``: encoder output for cross-attention (keys/values from it).
    ``kv_override``: precomputed (k, v) — skips the K/V projections.
    ``cache``: {"k","v": (B, Smax, Hkv, Dh), "len": ()} — updated
    functionally and returned.
    """
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    groups = Hq // Hkv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if kv_override is not None:
        k, v = kv_override
    else:
        src = memory if memory is not None else x
        k, v = attention_kv(p, cfg, src)

    mode = _attn_parallel_mode(cfg, q.shape[1])
    if mode == "heads":
        q = shard_hint(q, "data", None, "model", None)
        # GQA K/V heads rarely divide the axis — replicate them instead
        # of letting the partitioner reshard per block
        k = shard_hint(k, "data", None, None, None)
        v = shard_hint(v, "data", None, None, None)
    elif mode == "context":
        # context parallel: q sharded on sequence, K/V gathered (small)
        q = shard_hint(q, "data", "model", None, None)
        k = shard_hint(k, "data", None, None, None)
        v = shard_hint(v, "data", None, None, None)
    if angles is not None and memory is None:
        q = apply_rope(q, angles)
        k_angles = angles
        if cache is not None and angles.shape[1] == q.shape[1]:
            k_angles = angles
        k = apply_rope(k, k_angles)

    new_cache = None
    kv_len = None
    if cache is not None:
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice(cache["k"],
                                          k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"],
                                          v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": idx + k.shape[1]}
        k, v = ck, cv
        kv_len = new_cache["len"]

    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    out = blockwise_attention(q, k, v, causal=causal,
                              window=cfg.sliding_window,
                              q_offset=q_offset, kv_len=kv_len,
                              block=block)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# --------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------- #
def mlp_init(key, d: int, ff: int, act: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    if act == "swiglu":
        return {"w_gate": (jax.random.normal(ks[0], (d, ff)) * s_in
                           ).astype(dtype),
                "w_up": (jax.random.normal(ks[1], (d, ff)) * s_in
                         ).astype(dtype),
                "w_down": (jax.random.normal(ks[2], (ff, d)) * s_out
                           ).astype(dtype)}
    return {"w_up": (jax.random.normal(ks[0], (d, ff)) * s_in).astype(dtype),
            "b_up": jnp.zeros((ff,), dtype),
            "w_down": (jax.random.normal(ks[1], (ff, d)) * s_out
                       ).astype(dtype),
            "b_down": jnp.zeros((d,), dtype)}


def mlp_apply(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in p:
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]

"""Mamba2 block — SSD (state-space duality), chunked matmul form.

Follows Dao & Gu 2024 (arXiv:2405.21060): the selective SSM
    h_t = exp(Δ_t a) h_{t-1} + Δ_t B_t x_tᵀ        (per head, state N)
    y_t = C_tᵀ h_t + D x_t
is computed chunk-parallel: within chunks of Q tokens everything is dense
matmuls (MXU-friendly); across chunks a short ``lax.scan`` or
``associative_scan`` carries the (H, P, N) state. Decode is the O(1)
recurrence — this is why `long_500k` runs for the SSM/hybrid archs.

Layout: x (B, S, d_inner) viewed as (B, S, H, P) with P = ssm_head_dim;
B/C are shared across heads within a group (n_groups=1 here, like the
reference implementation's default).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def mamba2_init(key, cfg: ModelConfig, dtype) -> Dict:
    D = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    kproj, kconv, kA, kdt, kD, kout = jax.random.split(key, 6)
    d_proj = 2 * di + 2 * N + H   # z, x, B, C, dt
    s = D ** -0.5
    return {
        "in_proj": (jax.random.normal(kproj, (D, d_proj)) * s).astype(dtype),
        "conv_w": (jax.random.normal(kconv, (cfg.ssm_conv, di + 2 * N))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "skip_D": jnp.ones((H,), jnp.float32),
        "out_proj": (jax.random.normal(kout, (di, D))
                     * di ** -0.5).astype(dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bmat = zxbcdt[..., 2 * di:2 * di + N]
    Cmat = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, x, Bmat, Cmat, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv via explicit shifts (width K small).

    x: (B, S, C); w: (K, C). Returns (y, new_state (B, K-1, C))."""
    K = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    pads = []
    S_out = x.shape[1] - (K - 1) if state is not None else x.shape[1]
    for k in range(K):
        if state is not None:
            xs = x[:, k:k + S_out]
        else:
            shift = K - 1 - k
            xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        pads.append(xs * w[k])
    y = sum(pads) + b
    new_state = x[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(y), new_state


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bmat: jnp.ndarray, Cmat: jnp.ndarray, Q: int,
                h0: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bmat/Cmat: (B, S, N). Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bmat.reshape(Bsz, nc, Q, N)
    Cc = Cmat.reshape(Bsz, nc, Q, N)

    dA = dtc * A                                   # (B, nc, Q, H) negative
    cs = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum
    # intra-chunk: L[q,t] = exp(cs_q - cs_t) for q >= t. Mask the EXPONENT
    # (not the value) so masked slots are exp(-inf)=0 with zero gradient —
    # exp-then-mask produces inf·0 = NaN in the backward pass.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
    Lmat = jnp.exp(diff)
    # scores[b,c,q,t,h] = C_q·B_t L[q,t] dt_t
    CB = jnp.einsum("bcqn,bctn->bcqt", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    M = CB[..., None] * Lmat * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqth,bcthp->bcqhp", M, xc.astype(jnp.float32))

    # chunk summaries: S_c = Σ_t exp(cs_end - cs_t) dt_t B_t x_tᵀ
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)            # (B,nc,Q,H)
    weighted_x = xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None]
    S_chunk = jnp.einsum("bctn,bcthp->bchpn", Bc.astype(jnp.float32),
                         weighted_x)                          # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                    # (B,nc,H)

    # inter-chunk state scan
    def body(h, xs):
        dec, s_c = xs                                        # (B,H), (B,H,P,N)
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h                                      # emit PREVIOUS

    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bsz, H, P, N), jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        body, h_init,
        (chunk_decay.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    # inter-chunk contribution: y_t += C_t exp(cs_t) h_prev
    decay_from_start = jnp.exp(cs)                           # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc.astype(jnp.float32),
                         h_prevs) * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(Bsz, nc * Q, H, P)[:, :S]
    return y, h_last


def mamba2_apply(p: Dict, cfg: ModelConfig, u: jnp.ndarray,
                 state: Optional[Dict] = None
                 ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """u: (B, S, D). state (decode): {"conv": (B,K-1,di+2N), "ssm": (B,H,P,N)}."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = u @ p["in_proj"]
    z, x, Bmat, Cmat, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([x, Bmat, Cmat], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    x = conv_out[..., :di]
    Bmat = conv_out[..., di:di + N]
    Cmat = conv_out[..., di + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    xh = x.reshape(*x.shape[:2], H, P)

    if state is None:
        y, h_last = ssd_chunked(xh, dt, A, Bmat, Cmat, cfg.ssm_chunk)
        new_state = None
    elif u.shape[1] > 1:
        # prefill with carried state (chunked, h0 = previous state)
        y, h_last = ssd_chunked(xh, dt, A, Bmat, Cmat, cfg.ssm_chunk,
                                h0=state["ssm"])
        new_state = {"conv": new_conv, "ssm": h_last}
    else:
        # O(1) decode recurrence (S == 1)
        h = state["ssm"].astype(jnp.float32)                 # (B,H,P,N)
        dA = jnp.exp(dt[:, 0, :] * A)                        # (B,H)
        Bx = jnp.einsum("bn,bhp->bhpn", Bmat[:, 0].astype(jnp.float32),
                        xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None])
        h = h * dA[:, :, None, None] + Bx
        y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32),
                       h)[:, None]
        h_last = h
        new_state = {"conv": new_conv, "ssm": h_last}

    y = y + xh.astype(jnp.float32) * p["skip_D"][:, None]
    y = y.reshape(*u.shape[:2], di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if state is None:
        return out, None
    return out, new_state

"""TransformerLM — one composable model covering all 10 assigned archs.

Families:
  dense   — scan over uniform (attn + MLP) blocks (qwen2/llama/internlm2)
  moe     — scan over (attn + MoE) blocks (mixtral / granite-moe)
  ssm     — scan over Mamba2 blocks (mamba2-1.3b)
  hybrid  — grouped Mamba2 scans + ONE weight-shared attention block
            applied every `shared_attn_every` layers (zamba2)
  encdec  — encoder scan + decoder scan with cross-attn (whisper; stub
            frontend supplies precomputed frame embeddings)
  vlm     — dense with M-RoPE 3-D positions and merged embeddings in
            (qwen2-vl; stub frontend)

Layer parameters are STACKED on a leading L axis and iterated with
``jax.lax.scan`` (+``jax.checkpoint`` per block) so HLO stays compact for
the 512-device dry-run and remat keeps activation memory at one block.
KV caches / SSM states travel through the scan as per-layer xs/ys.

Residual-stream activations carry sharding hints (batch on 'data',
d_model on 'model' between blocks = Megatron-style sequence/tensor
hybrid; XLA inserts the all-gather/reduce-scatter pairs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ...pjit_utils import shard_hint
from .config import ModelConfig
from .layers import (norm_init, norm_apply, attention_init, attention_apply,
                     attention_kv, mlp_init, mlp_apply, rope_angles)
from .moe import moe_init, moe_apply
from .mamba2 import mamba2_init, mamba2_apply

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _residual_hint(h):
    """Residual-stream sharding between blocks.

    Sequence-sharded over 'model' (Megatron-SP): the TP block outputs
    reduce-scatter into sequence shards (bf16) instead of all-reducing the
    full f32 residual, and norms run on 1/16th of the tokens
    (§Perf qwen2_7b iter 2). Falls back to d_model sharding for
    single-token (decode) calls."""
    if h.shape[1] >= 16:
        return shard_hint(h, "data", "model", None)
    return shard_hint(h, "data", None, "model")


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _block_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"norm": norm_init(cfg.d_model, cfg.norm),
                "mixer": mamba2_init(ks[0], cfg, dtype)}
    p = {"norm1": norm_init(cfg.d_model, cfg.norm),
         "attn": attention_init(ks[0], cfg, dtype),
         "norm2": norm_init(cfg.d_model, cfg.norm)}
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    if kind == "cross":   # decoder block with cross-attention
        p["norm_x"] = norm_init(cfg.d_model, cfg.norm)
        p["xattn"] = attention_init(ks[2], cfg, dtype)
    return p


def _stack_init(key, cfg: ModelConfig, kind: str, n: int, dtype) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg, kind, dtype))(keys)


def init_params(key, cfg: ModelConfig, *, max_seq: int = 0) -> Params:
    """``max_seq`` sizes learned positional tables (encdec only)."""
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    p: Params = {
        "embed": (jax.random.normal(keys[0], (V, D)) * 0.02).astype(dtype),
        "final_norm": norm_init(D, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[1], (V, D)) * 0.02
                        ).astype(dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(keys[2], cfg, "dense", cfg.n_layers, dtype)
    elif fam == "moe":
        p["blocks"] = _stack_init(keys[2], cfg, "moe", cfg.n_layers, dtype)
    elif fam == "ssm":
        p["blocks"] = _stack_init(keys[2], cfg, "mamba", cfg.n_layers, dtype)
    elif fam == "hybrid":
        p["blocks"] = _stack_init(keys[2], cfg, "mamba", cfg.n_layers, dtype)
        p["shared"] = _block_init(keys[3], cfg, "dense", dtype)
    elif fam == "encdec":
        p["enc_blocks"] = _stack_init(keys[2], cfg, "dense",
                                      cfg.n_enc_layers, dtype)
        p["blocks"] = _stack_init(keys[3], cfg, "cross", cfg.n_layers, dtype)
        p["enc_pos"] = (jax.random.normal(keys[4], (cfg.enc_seq, D))
                        * 0.02).astype(dtype)
        p["dec_pos"] = (jax.random.normal(keys[5], (max(max_seq, 8), D))
                        * 0.02).astype(dtype)
        p["enc_final_norm"] = norm_init(D, cfg.norm)
    else:
        raise ValueError(fam)
    return p


# --------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------- #
def _attn_block(bp: Params, cfg: ModelConfig, h, angles, *, causal=True,
                memory=None, cache=None, q_offset=0):
    x = norm_apply(bp["norm1"], h)
    y, new_cache = attention_apply(bp["attn"], cfg, x, angles,
                                   causal=causal, cache=cache,
                                   q_offset=q_offset)
    h = h + y
    new_xcache = None
    if "xattn" in bp:
        x = norm_apply(bp["norm_x"], h)
        # cross-attention K/V: projected from the encoder memory once
        # (prefill / train) and reused from the cache at decode
        if memory is not None:
            xk, xv = attention_kv(bp["xattn"], cfg, memory)
        else:
            xk, xv = cache["cross_k"], cache["cross_v"]
        y, _ = attention_apply(bp["xattn"], cfg, x, None, causal=False,
                               kv_override=(xk, xv))
        h = h + y
        if cache is not None:
            new_xcache = {"cross_k": xk.astype(cache["cross_k"].dtype),
                          "cross_v": xv.astype(cache["cross_v"].dtype)}
    x = norm_apply(bp["norm2"], h)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in bp:
        y, aux = moe_apply(bp["moe"], cfg, x)
    else:
        y = mlp_apply(bp["mlp"], x)
    h = h + y
    h = _residual_hint(h)
    return h, aux, new_cache, new_xcache


def _mamba_block(bp: Params, cfg: ModelConfig, h, state=None):
    x = norm_apply(bp["norm"], h)
    y, new_state = mamba2_apply(bp["mixer"], cfg, x, state)
    h = h + y
    h = _residual_hint(h)
    return h, new_state


# --------------------------------------------------------------------- #
# stacks (scan over layers, remat per block)
# --------------------------------------------------------------------- #
def _scan_attn_stack(blocks: Params, cfg: ModelConfig, h, angles, *,
                     causal=True, memory=None, caches=None, q_offset=0):
    """Uniform attention stack. caches: stacked {"k","v","len"} or None."""

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, xs):
        h, aux = carry
        bp, cache = xs
        h, a, new_cache, new_x = _attn_block(bp, cfg, h, angles,
                                             causal=causal, memory=memory,
                                             cache=cache, q_offset=q_offset)
        if new_x is not None and new_cache is not None:
            new_cache = {**new_cache, **new_x}
        return (h, aux + a), new_cache

    (h, aux), new_caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                        (blocks, caches))
    return h, aux, new_caches


def _scan_mamba_stack(blocks: Params, cfg: ModelConfig, h, states=None):
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(h, xs):
        bp, st = xs
        h, new_st = _mamba_block(bp, cfg, h, st)
        return h, new_st

    h, new_states = jax.lax.scan(body, h, (blocks, states))
    return h, new_states


# --------------------------------------------------------------------- #
# embedding / logits / loss
# --------------------------------------------------------------------- #
def embed_tokens(p: Params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    e = jnp.take(p["embed"], tokens, axis=0)
    return _residual_hint(e)


def _head_table(p: Params) -> jnp.ndarray:
    return p["embed"] if "lm_head" not in p else p["lm_head"]


def logits_fn(p: Params, cfg: ModelConfig, h) -> jnp.ndarray:
    w = _head_table(p)
    return jnp.einsum("bsd,vd->bsv", h, w).astype(jnp.float32)


def chunked_ce_loss(p: Params, cfg: ModelConfig, h, labels,
                    chunk: int = 512) -> jnp.ndarray:
    """CE over vocab without materializing full (B,S,V) logits.

    Scans the sequence in chunks; per chunk the (B,c,V) logits live only
    transiently (vocab TP-sharded -> (B,c,V/16) per device).
    """
    B, S, D = h.shape
    w = _head_table(p)
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        hx, lx = xs
        logits = jnp.einsum("bsd,vd->bsv", hx, w).astype(jnp.float32)
        logits = shard_hint(logits, "data", None, "model")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lx, cfg.vocab, dtype=logits.dtype)
        lab = jnp.sum(logits * onehot, axis=-1)
        valid = (lx >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - lab) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------------- #
# forward passes
# --------------------------------------------------------------------- #
def _positions_default(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S) + offset, (B, S))


def backbone(p: Params, cfg: ModelConfig, h, positions, *,
             caches=None, q_offset=0, memory=None):
    """Shared trunk: embeddings -> blocks -> final norm.

    positions: (B,S) or (3,B,S) for M-RoPE. caches: family-specific pytree
    (see init_cache). Returns (h, aux_loss, new_caches).
    """
    fam = cfg.family
    zero = jnp.zeros((), jnp.float32)
    if fam in ("dense", "vlm", "moe"):
        angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
        h, aux, new_caches = _scan_attn_stack(
            p["blocks"], cfg, h, angles, causal=True, caches=caches,
            q_offset=q_offset)
        return norm_apply(p["final_norm"], h), aux, new_caches

    if fam == "ssm":
        h, new_states = _scan_mamba_stack(p["blocks"], cfg, h,
                                          states=caches)
        return norm_apply(p["final_norm"], h), zero, new_states

    if fam == "hybrid":
        angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        blocks = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), p["blocks"])
        m_states = caches["mamba"] if caches is not None else None
        a_caches = caches["attn"] if caches is not None else None
        new_m, new_a = [], []
        for gi in range(n_groups):
            blk_g = jax.tree.map(lambda x: x[gi], blocks)
            st_g = (jax.tree.map(lambda x: x[gi], m_states)
                    if m_states is not None else None)
            h, ns = _scan_mamba_stack(blk_g, cfg, h, states=st_g)
            new_m.append(ns)
            ac = (jax.tree.map(lambda x: x[gi], a_caches)
                  if a_caches is not None else None)
            h, _, nc, _ = _attn_block(p["shared"], cfg, h, angles,
                                      causal=True, cache=ac,
                                      q_offset=q_offset)
            new_a.append(nc)
        new_caches = None
        if caches is not None:
            new_caches = {
                "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_a),
            }
        return norm_apply(p["final_norm"], h), zero, new_caches

    if fam == "encdec":
        angles = None   # learned positions added at embedding time
        h, aux, new_caches = _scan_attn_stack(
            p["blocks"], cfg, h, None, causal=True, caches=caches,
            q_offset=q_offset, memory=memory)
        return norm_apply(p["final_norm"], h), aux, new_caches

    raise ValueError(fam)


def encode(p: Params, cfg: ModelConfig, frames) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    h = frames + p["enc_pos"][None, : frames.shape[1]]
    h = shard_hint(h, "data", None, "model")
    h, _, _ = _scan_attn_stack(p["enc_blocks"], cfg, h, None, causal=False)
    return norm_apply(p["enc_final_norm"], h)


def loss_fn(p: Params, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    """Training loss. batch keys: tokens (B,S) int32, plus per family:
    encdec: frames (B,enc_seq,D); vlm: positions (3,B,S)."""
    tokens = batch["tokens"]
    if "labels" in batch:
        inputs, labels = tokens, batch["labels"]
    else:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    B, S = inputs.shape
    h = embed_tokens(p, cfg, inputs)
    memory = None
    if cfg.family == "encdec":
        memory = encode(p, cfg, batch["frames"].astype(h.dtype))
        h = h + p["dec_pos"][None, : h.shape[1]]
    if cfg.family == "vlm":
        positions = batch["positions"]
        if "labels" not in batch:
            positions = positions[:, :, :-1]
    else:
        positions = _positions_default(B, S)
    h, aux, _ = backbone(p, cfg, h, positions, memory=memory)
    ce = chunked_ce_loss(p, cfg, h, labels)
    return ce + 0.01 * aux


# --------------------------------------------------------------------- #
# serving: prefill + decode with caches
# --------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers

    def attn_cache(n):
        return {
            "k": jnp.zeros((n, batch, max_seq, Hkv, Dh), dtype),
            "v": jnp.zeros((n, batch, max_seq, Hkv, Dh), dtype),
            "len": jnp.zeros((n,), jnp.int32),
        }

    def mamba_state(n):
        di, N = cfg.d_inner, cfg.ssm_state
        H, P = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, di + 2 * N),
                              dtype),
            "ssm": jnp.zeros((n, batch, H, P, N), jnp.float32),
        }

    if cfg.family == "encdec":
        c = attn_cache(L)
        c["cross_k"] = jnp.zeros((L, batch, cfg.enc_seq, Hkv, Dh), dtype)
        c["cross_v"] = jnp.zeros((L, batch, cfg.enc_seq, Hkv, Dh), dtype)
        return c
    if cfg.family in ("dense", "vlm", "moe"):
        return attn_cache(L)
    if cfg.family == "ssm":
        return mamba_state(L)
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = L // every
        m = mamba_state(L)
        m = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), m)
        return {"mamba": m, "attn": attn_cache(n_groups)}
    raise ValueError(cfg.family)


def prefill(p: Params, cfg: ModelConfig, tokens, cache, *,
            positions=None, memory=None):
    """Run the prompt through the model, filling caches.

    Returns (last-position logits (B, V), caches)."""
    B, S = tokens.shape
    h = embed_tokens(p, cfg, tokens)
    if cfg.family == "encdec":
        h = h + p["dec_pos"][None, :S]
    if positions is None:
        positions = _positions_default(B, S)
    h, _, new_cache = backbone(p, cfg, h, positions, caches=cache,
                               q_offset=0, memory=memory)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], _head_table(p))
    return logits.astype(jnp.float32), new_cache


def decode_step(p: Params, cfg: ModelConfig, token, cache, pos, *,
                memory=None):
    """One decode step. token: (B,) int32; pos: () int32 absolute position.

    Returns (logits (B,V), new cache)."""
    B = token.shape[0]
    h = embed_tokens(p, cfg, token[:, None])
    if cfg.family == "encdec":
        h = h + jax.lax.dynamic_slice_in_dim(p["dec_pos"], pos, 1)[None]
    if cfg.family == "vlm":
        positions = jnp.broadcast_to(pos, (3, B, 1))
    else:
        positions = jnp.broadcast_to(pos, (B, 1))
    h, _, new_cache = backbone(p, cfg, h, positions, caches=cache,
                               q_offset=pos, memory=memory)
    logits = jnp.einsum("bd,vd->bv", h[:, 0], _head_table(p))
    return logits.astype(jnp.float32), new_cache

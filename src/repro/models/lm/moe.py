"""Mixture-of-Experts FFN (mixtral / granite-moe).

Token dispatch/combine is — structurally — the paper's Copy-Reduce:
dispatch scatters token vectors to per-expert slots (collision-free by
construction: slot index = rank of the token within its expert, via a
cumsum over the one-hot assignment matrix — the same owner-computes trick
as the pull model), and combine is a gate-weighted gather-reduce
(``e_mul_v_add_v`` in BR terms). See DESIGN.md §4.

Fixed shapes via capacity: C = ceil(top_k · T · capacity_factor / E);
overflow tokens are dropped (standard GShard semantics), with an
auxiliary load-balancing loss to keep drops rare.

Sharding: expert weights (E, d, ff) are TP-sharded on ff over 'model' and
FSDP-sharded on d over 'data'. The expert axis E is left unsharded because
the production mesh's model axis (16) does not divide either assigned
expert count (8, 40); the layer supports EP (experts over 'model') when
``E % model_axis == 0`` — see launch/shardings.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ...pjit_utils import current_mesh, shard_hint
from .config import ModelConfig


def _block_layout(B: int, S: int, small_ffn: bool):
    """(dd, dm): token-block grid aligned to the (data, model) mesh.

    Blocks are built by splitting the BATCH dim dd-ways (data axis) and
    the SEQUENCE dim dm-ways (model axis) — so the (dd, dm) block grid
    maps 1:1 onto mesh shards and every dispatch gather is provably
    local. A flat ``T.reshape(ds, Tb)`` blocking only aligns when S is a
    multiple of Tb — it silently garbles the mapping for prefill shapes
    and the partitioner falls back to a full all-reduce of the gathered
    buffer (§Perf granite-prefill iteration).

    dm > 1 only for small (replicated-weight) expert FFNs: tokens are
    model-replicated there, so model-axis blocks stay local while the FFN
    compute spreads over the whole mesh (§Perf iter 7)."""
    mesh = current_mesh()
    if mesh is None:
        return 1, 1
    ds = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    ms = mesh.shape.get("model", 1)
    dd = ds if B % ds == 0 else 1
    dm = ms if (small_ffn and S % ms == 0) else 1
    return dd, dm


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = D ** -0.5, F ** -0.5
    return {
        "router": (jax.random.normal(k1, (D, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, D, F)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, D, F)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, F, D)) * s_out).astype(dtype),
    }


def moe_apply(p: Dict, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    # per-block capacity: the position-in-expert cumsum runs WITHIN each
    # token block, so a token's slot lives on the shard that owns the
    # token — dispatch needs no communication (§Perf iters 5-7; GShard's
    # "local dispatch" semantics: drops are decided per block). Blocks
    # form a (data, model)-aligned grid — see _block_layout.
    small = E * cfg.d_ff * D * 2 * 3 <= 512 * 1024 * 1024
    dd, dm = _block_layout(B, S, small)
    ds = dd * dm
    block_ax = (("data", "model") if dm > 1 else
                ("data" if dd > 1 else None))
    Tb = T // ds
    Cb = max(1, int(K * Tb * cfg.capacity_factor / E))
    C = ds * Cb

    # mesh-aligned blocking: (B,S,D) -> (dd, B/dd, dm, S/dm, D) ->
    # (dd, dm, B/dd, S/dm, D) -> (ds, Tb, D). The transpose only reorders
    # replicated dims; the merges combine (sharded, replicated) dims —
    # all layout-local under GSPMD.
    xb = x.reshape(dd, B // dd, dm, S // dm, D)
    xb = shard_hint(xb, "data", None, "model" if dm > 1 else None,
                    None, None)
    xb = xb.transpose(0, 2, 1, 3, 4).reshape(ds, Tb, D)
    xb = shard_hint(xb, block_ax, None, None)

    logits = xb.astype(jnp.float32) @ p["router"]            # (ds, Tb, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (ds, Tb, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- dispatch (paper's pull insight: scatter INDICES, gather
    # payloads — a payload scatter across shardings replicates the whole
    # expert buffer; an index scatter is 2+ orders smaller) -------------
    flat_e = gate_idx.reshape(ds, Tb * K)                    # block-local
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (ds, TbK, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                # rank in block
    flat_pos = jnp.sum(pos * onehot, axis=-1)                # (ds, TbK)
    keep = flat_pos < Cb
    slot_e = jnp.where(keep, flat_e, E)                      # drop -> pad
    slot_c = jnp.where(keep, flat_pos, Cb)                   # block-local c

    # batched (vmapped) index scatter: the leading block dim aligns with
    # the mesh grid, so GSPMD proves every scatter/gather local — dynamic
    # flat indices would force a conservative all-to-all (§Perf iter 6).
    tok_local = jnp.broadcast_to(jnp.repeat(jnp.arange(Tb), K)[None],
                                 (ds, Tb * K))               # (ds, TbK)
    slot_tok = jax.vmap(
        lambda e, c, t: jnp.full((E + 1, Cb + 1), Tb, jnp.int32)
        .at[e, c].set(t, mode="drop"))(slot_e, slot_c, tok_local)
    slot_tok = slot_tok[:, :E, :Cb]                          # (ds, E, Cb)
    x_pad = jnp.concatenate([xb, jnp.zeros((ds, 1, D), xb.dtype)], axis=1)
    buf = jnp.take_along_axis(
        x_pad, slot_tok.reshape(ds, E * Cb)[:, :, None], axis=1)
    buf = (buf.reshape(ds, E, Cb, D).transpose(1, 0, 2, 3)
           .reshape(E, C, D))                                # (E, C, D)

    # ---- expert FFN: ff-TP for big experts; for tiny experts (granite)
    # replicate the weights and let the block-sharded slot dim carry the
    # parallelism (§Perf iters 3-6) --------------------------------------
    ff_ax = None if small else "model"
    buf = shard_hint(buf, None, block_ax, None)
    h_g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(shard_hint(h_g, None, block_ax, ff_ax)) * h_u
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, C, D)
    y_buf = shard_hint(y_buf, None, block_ax, None)

    # ---- combine: batched within-block gather, weight, reshape-sum the
    # K choices — no payload scatter anywhere ----------------------------
    y_blk = (y_buf.reshape(E, ds, Cb, D).transpose(1, 0, 2, 3)
             .reshape(ds, E * Cb, D))
    idx = (jnp.clip(slot_e, 0, E - 1) * Cb
           + jnp.minimum(slot_c, Cb - 1))                    # (ds, TbK)
    gathered = jnp.take_along_axis(y_blk, idx[:, :, None], axis=1)
    gathered = jnp.where(keep[:, :, None], gathered, 0)
    w = gate_vals.reshape(ds, Tb * K, 1).astype(gathered.dtype)
    y = (gathered * w).reshape(ds, Tb, K, D).sum(axis=2)
    # inverse of the mesh-aligned blocking
    y = (y.reshape(dd, dm, B // dd, S // dm, D)
         .transpose(0, 2, 1, 3, 4).reshape(B, S, D))
    return y.astype(x.dtype), aux

"""repro.obs — unified telemetry: metrics registry, spans, plan events.

The observability layer every subsystem reports through (DESIGN.md
§11). Three pieces, one on/off switch (``REPRO_TELEMETRY=0`` disables
everything; :func:`set_enabled` toggles at runtime):

* :mod:`.metrics` — a low-overhead, thread-safe registry of counters /
  gauges / log2-bucket histograms. Serving caches, compile trackers,
  pack-build counters and the benchmark rows all register here, so one
  :func:`snapshot` describes a whole run.
* :mod:`.spans` — ``with span("compute") as sp: ...; sp.fence(out)``
  wall-time tracing with ``block_until_ready`` fencing at span exit
  (device work is attributed to the span that launched it), exportable
  as Chrome-trace JSON (:func:`export_chrome_trace`, loadable in
  Perfetto / chrome://tracing).
* :mod:`.events` — the structured plan-event stream: every planner
  decision row records the cost model's *predicted* cost, eager op
  executions record *measured* wall time, and :func:`drift_report`
  surfaces ops where prediction and reality diverge.

``repro.obs`` sits below every other repro package (it imports only
jax/numpy/stdlib), so core/data/models/launch can all report here
without import cycles.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      REGISTRY, counter, gauge, histogram, snapshot,
                      reset_metrics, enabled, set_enabled,
                      percentile_nearest_rank)
from .spans import (Span, span, export_chrome_trace, trace_events,
                    clear_trace, span_coverage)
from .events import (PLAN_EVENT_FIELDS, DRIFT_FIELDS, plan_event,
                     measured_event, timed, plan_events, drift_report,
                     clear_events, family_of)
from .signatures import SignatureTracker

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "gauge", "histogram", "snapshot", "reset_metrics",
    "enabled", "set_enabled", "percentile_nearest_rank",
    "Span", "span", "export_chrome_trace", "trace_events",
    "clear_trace", "span_coverage",
    "PLAN_EVENT_FIELDS", "DRIFT_FIELDS", "plan_event", "measured_event",
    "timed", "plan_events", "drift_report", "clear_events", "family_of",
    "SignatureTracker",
]

"""Structured plan-event stream: predicted cost vs measured wall time.

Every planner decision (`gspmm`, `block:*`, `block_bwd:*`, `hetero:*`,
`sddmm:*`, `attn:*`, `serve:infer`, `partitioned:train`) flows through
:func:`plan_event`, which records the cost model's *predicted* cost for
the chosen strategy next to the decision. When the op actually runs
eagerly (serve refresh, fan-out inference, the sampled-training drift
probe, autotune measurement, attributed benchmark rows),
:func:`measured_event` / :func:`timed` record *measured* wall time
under the same op key.

:func:`drift_report` joins the two. Predicted costs are relative
element-op counts whose absolute scale differs per plan-row family, so
the report fits one scale per family (median of measured/predicted over
that family's ops) and flags ops whose normalized ratio falls outside
``[1/threshold, threshold]`` — i.e. ops where the cost model's
*ranking within its own family* has drifted from reality.

The record schemas (:data:`PLAN_EVENT_FIELDS`, :data:`DRIFT_FIELDS`)
are pinned by a golden test; BENCH_*.json embeds both streams.
"""
import threading
import time

import jax

from . import metrics as _metrics
from .metrics import enabled

__all__ = ["PLAN_EVENT_FIELDS", "DRIFT_FIELDS", "plan_event",
           "measured_event", "timed", "plan_events", "drift_report",
           "clear_events", "family_of", "enabled"]

# Golden schema: tests/obs/test_plan_events.py pins these field lists so
# downstream BENCH_*.json parsing can't silently break. Extend by
# appending (and updating the golden test) — never reorder or rename.
PLAN_EVENT_FIELDS = (
    "op", "family", "requested", "chosen", "count",
    "predicted_cost", "measured_calls", "measured_total_s",
    "measured_mean_s", "dtype",
)
DRIFT_FIELDS = (
    "op", "family", "requested", "chosen", "predicted_cost",
    "measured_calls", "measured_mean_s", "family_scale",
    "ratio", "drifted", "dtype",
)

_LOCK = threading.Lock()
# (op, requested, chosen, dtype) -> {"count", "predicted_cost"}
_PLANS = {}
# op -> {"calls": int, "total_s": float, "min_s": float, "max_s": float}
_MEASURED = {}

_FAMILIES = ("block_bwd", "block", "hetero", "sddmm", "attn", "serve",
             "partitioned")


def family_of(op):
    """Plan-row family of an op key: the prefix before ':' for
    prefixed rows, ``gspmm`` for bare binary-reduce spec names."""
    head, sep, _ = op.partition(":")
    if sep and head in _FAMILIES:
        return head
    return "gspmm"


def plan_event(op, requested, chosen, predicted_cost=None, dtype=None):
    """Record one planner decision row. ``predicted_cost`` is the cost
    model's estimate for the *chosen* strategy (relative element-ops);
    pass None when the site has no cost model input (e.g. forced
    strategies without graph stats). ``dtype`` is the operand element
    type the decision was made for (a string, e.g. "bfloat16"), or None
    at sites with no operand in hand — rows are keyed on it, so the
    same op planned at two precisions yields two rows."""
    if not enabled():
        return
    key = (str(op), str(requested), str(chosen),
           None if dtype is None else str(dtype))
    with _LOCK:
        row = _PLANS.get(key)
        if row is None:
            row = {"count": 0, "predicted_cost": None}
            _PLANS[key] = row
        row["count"] += 1
        if predicted_cost is not None:
            row["predicted_cost"] = float(predicted_cost)


def measured_event(op, seconds):
    """Record one measured execution of ``op`` (seconds of wall time,
    fenced by the caller)."""
    if not enabled():
        return
    s = float(seconds)
    with _LOCK:
        row = _MEASURED.get(op)
        if row is None:
            row = {"calls": 0, "total_s": 0.0, "min_s": s, "max_s": s}
            _MEASURED[op] = row
        row["calls"] += 1
        row["total_s"] += s
        row["min_s"] = min(row["min_s"], s)
        row["max_s"] = max(row["max_s"], s)


def timed(op, thunk):
    """Run ``thunk()``; when telemetry is on *and* we are executing
    eagerly (not under a jit/vjp trace, where timing would measure
    tracing instead of execution), fence the result and record the wall
    time as a measured event for ``op``. Returns the thunk's result."""
    if not enabled() or not jax.core.trace_state_clean():
        return thunk()
    t0 = time.perf_counter()
    out = thunk()
    jax.block_until_ready(out)
    measured_event(op, time.perf_counter() - t0)
    return out


def plan_events():
    """The plan-event stream as a list of dicts in the pinned
    :data:`PLAN_EVENT_FIELDS` schema, joined with per-op measurements,
    sorted by op key."""
    with _LOCK:
        plans = {k: dict(v) for k, v in _PLANS.items()}
        measured = {k: dict(v) for k, v in _MEASURED.items()}
    rows = []
    def sort_key(k):
        op, requested, chosen, dtype = k
        return (op, requested, chosen, dtype or "")

    for (op, requested, chosen, dtype) in sorted(plans, key=sort_key):
        p = plans[(op, requested, chosen, dtype)]
        m = measured.get(op)
        rows.append({
            "op": op,
            "family": family_of(op),
            "requested": requested,
            "chosen": chosen,
            "count": p["count"],
            "predicted_cost": p["predicted_cost"],
            "measured_calls": m["calls"] if m else 0,
            "measured_total_s": m["total_s"] if m else None,
            "measured_mean_s": (m["total_s"] / m["calls"]) if m else None,
            "dtype": dtype,
        })
    return rows


def drift_report(threshold=4.0):
    """Predicted-vs-measured drift rows (:data:`DRIFT_FIELDS` schema).

    One row per plan decision that has both a predicted cost and a
    measurement for its op. ``family_scale`` is the median
    measured/predicted ratio within the row's family (predicted costs
    are relative, so only within-family ranking is meaningful);
    ``ratio`` is the row's measured/predicted normalized by that scale,
    and ``drifted`` flags ratios outside ``[1/threshold, threshold]`` —
    the cost model mis-ranks that op relative to its family by more
    than ``threshold``x.
    """
    if threshold <= 1.0:
        raise ValueError(f"drift threshold must be > 1, got {threshold}")
    rows = [r for r in plan_events()
            if r["predicted_cost"] and r["predicted_cost"] > 0
            and r["measured_mean_s"] is not None]
    scales = {}
    by_family = {}
    for r in rows:
        by_family.setdefault((r["family"], r["dtype"]), []).append(
            r["measured_mean_s"] / r["predicted_cost"])
    for fam, ratios in by_family.items():
        scales[fam] = _metrics.percentile_nearest_rank(ratios, 50)
    out = []
    for r in rows:
        scale = scales[(r["family"], r["dtype"])]
        raw = r["measured_mean_s"] / r["predicted_cost"]
        ratio = raw / scale if scale > 0 else None
        drifted = (ratio is not None
                   and not (1.0 / threshold <= ratio <= threshold))
        out.append({
            "op": r["op"],
            "family": r["family"],
            "requested": r["requested"],
            "chosen": r["chosen"],
            "predicted_cost": r["predicted_cost"],
            "measured_calls": r["measured_calls"],
            "measured_mean_s": r["measured_mean_s"],
            "family_scale": scale,
            "ratio": ratio,
            "drifted": drifted,
            "dtype": r["dtype"],
        })
    out.sort(key=lambda r: -(r["ratio"] or 0))
    return out


def clear_events():
    """Drop all plan and measured events (tests / bench isolation)."""
    with _LOCK:
        _PLANS.clear()
        _MEASURED.clear()

"""Low-overhead metrics registry: counters, gauges, log2 histograms.

Design constraints (ISSUE 8 / DESIGN.md §11):

* **Thread-safe** — serve requester threads hit the same instruments
  concurrently; every instrument guards its state with its own lock so
  contention stays per-instrument, not registry-wide.
* **Zero-cost when disabled** — ``REPRO_TELEMETRY=0`` (or
  :func:`set_enabled` ``(False)``) makes every registry accessor return
  a shared null instrument whose methods are no-ops; nothing is
  allocated, registered, or locked.
* **Fixed log2 buckets** — histograms bucket a value ``v > 0`` by
  ``floor(log2(v))`` clamped to ``[lo, hi]``, so observation is O(1)
  with no per-histogram configuration to drift between runs. The
  default range ``[-20, 4]`` spans ~1 µs to ~16 s in seconds, which
  covers every latency this repo records.

The module-level :data:`REGISTRY` is the process-wide default; the
``counter``/``gauge``/``histogram``/``snapshot``/``reset_metrics``
functions delegate to it.
"""
import math
import os
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "gauge", "histogram", "snapshot", "reset_metrics",
    "enabled", "set_enabled", "percentile_nearest_rank",
]

_ENABLED = os.environ.get("REPRO_TELEMETRY", "1") != "0"


def enabled() -> bool:
    """True when telemetry (metrics, spans, plan events) is on."""
    return _ENABLED


def set_enabled(on):
    """Flip the global telemetry switch at runtime (overhead gate uses
    this to compare on/off in one process). Returns the previous value."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def percentile_nearest_rank(values, p):
    """Nearest-rank percentile over the full sample vector.

    ``sorted(values)[ceil(p/100 * n) - 1]`` — exact for small n (no
    interpolation between a handful of points), standard for large n.
    """
    if not 0 < p <= 100:
        raise ValueError(f"percentile p must be in (0, 100], got {p}")
    xs = sorted(values)
    if not xs:
        raise ValueError("percentile of empty sample")
    k = math.ceil(p / 100.0 * len(xs))
    return xs[max(0, k - 1)]


class Counter:
    """Monotonic counter."""
    __slots__ = ("name", "_lock", "_n")

    def __init__(self, name):
        self.name = name
        self._lock = threading.Lock()
        self._n = 0

    def inc(self, n=1):
        with self._lock:
            self._n += n

    @property
    def value(self):
        with self._lock:
            return self._n

    def _snapshot(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name):
        self.name = name
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v):
        with self._lock:
            self._v = float(v)

    @property
    def value(self):
        with self._lock:
            return self._v

    def _snapshot(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed log2-bucket histogram.

    Bucket ``i`` (for ``lo <= i <= hi``) counts values in
    ``[2**i, 2**(i+1))``; values below ``2**lo`` land in bucket ``lo``,
    values at or above ``2**(hi+1)`` land in bucket ``hi``, and
    non-positive values land in a dedicated underflow bucket. Also
    tracks count/sum/min/max exactly.
    """
    __slots__ = ("name", "lo", "hi", "_lock", "_buckets", "_underflow",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name, lo=-20, hi=4):
        if hi < lo:
            raise ValueError(f"histogram range hi < lo: [{lo}, {hi}]")
        self.name = name
        self.lo = lo
        self.hi = hi
        self._lock = threading.Lock()
        self._buckets = [0] * (hi - lo + 1)
        self._underflow = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def bucket_index(self, v):
        """Bucket exponent for value ``v`` (None for the underflow
        bucket). ``2**k`` maps to bucket ``k``: frexp gives
        ``v = m * 2**e`` with ``m in [0.5, 1)``, so ``floor(log2 v)``
        is ``e - 1`` without float-log rounding at the boundaries."""
        if v <= 0:
            return None
        _, e = math.frexp(v)
        return min(self.hi, max(self.lo, e - 1))

    def observe(self, v):
        v = float(v)
        idx = self.bucket_index(v)
        with self._lock:
            if idx is None:
                self._underflow += 1
            else:
                self._buckets[idx - self.lo] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self):
        with self._lock:
            return self._count

    @property
    def sum(self):
        with self._lock:
            return self._sum

    def buckets(self):
        """List of ``(2**i, count)`` rows (bucket lower bounds), plus
        the underflow bucket as ``(None, count)`` when populated."""
        with self._lock:
            rows = [(2.0 ** (self.lo + i), n)
                    for i, n in enumerate(self._buckets)]
            if self._underflow:
                rows.insert(0, (None, self._underflow))
            return rows

    def quantile(self, q):
        """Approximate quantile: upper bound of the bucket holding the
        nearest-rank sample. None when empty."""
        with self._lock:
            if self._count == 0:
                return None
            rank = max(1, math.ceil(q * self._count))
            seen = self._underflow
            if rank <= seen:
                return 2.0 ** self.lo
            for i, n in enumerate(self._buckets):
                seen += n
                if rank <= seen:
                    return 2.0 ** (self.lo + i + 1)
            return self._max

    def _snapshot(self):
        with self._lock:
            out = {
                "type": "histogram",
                "count": self._count,
                "sum": self._sum,
                "lo": self.lo,
                "hi": self.hi,
                "buckets": {str(self.lo + i): n
                            for i, n in enumerate(self._buckets) if n},
            }
            if self._underflow:
                out["underflow"] = self._underflow
            if self._count:
                out["min"] = self._min
                out["max"] = self._max
                out["mean"] = self._sum / self._count
        return out


class _NullInstrument:
    """Shared do-nothing instrument returned while telemetry is off."""
    __slots__ = ()
    name = "<disabled>"
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def buckets(self):
        return []

    def quantile(self, q):
        return None


_NULL = _NullInstrument()


class MetricsRegistry:
    """Name → instrument map with get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments = {}

    def _get(self, name, cls, *args):
        if not _ENABLED:
            return _NULL
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name):
        return self._get(name, Counter)

    def gauge(self, name):
        return self._get(name, Gauge)

    def histogram(self, name, lo=-20, hi=4):
        return self._get(name, Histogram, lo, hi)

    def snapshot(self):
        """JSON-able dict of every registered instrument's state."""
        with self._lock:
            instruments = sorted(self._instruments.items())
        return {name: inst._snapshot() for name, inst in instruments}

    def reset(self):
        """Drop every registered instrument (tests / bench isolation)."""
        with self._lock:
            self._instruments.clear()


REGISTRY = MetricsRegistry()


def counter(name):
    return REGISTRY.counter(name)


def gauge(name):
    return REGISTRY.gauge(name)


def histogram(name, lo=-20, hi=4):
    return REGISTRY.histogram(name, lo, hi)


def snapshot():
    return REGISTRY.snapshot()


def reset_metrics():
    REGISTRY.reset()

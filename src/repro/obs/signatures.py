"""Compile-signature accounting, shared by train and serve.

``SignatureTracker`` (moved here from ``repro.data.pipeline``, which
re-exports it for compatibility) counts distinct static shape
signatures seen by a jitted step. ``observe_checked`` is the single
accounting path both ``train_sampled`` and ``GNNServer`` use: record
the signature, and if it is new (⇒ a fresh compile) immediately
enforce the bounded-signatures invariant — identical behavior to the
observe/assert pairs the two call sites used to hand-roll.

New signatures increment the registry counter
``signatures.<name>.compiles`` so recompile counts appear in metrics
snapshots next to cache and serve statistics.
"""
from typing import Set, Tuple

from . import metrics as _metrics

__all__ = ["SignatureTracker"]


class SignatureTracker:
    """Counts distinct static shape signatures seen by a jitted step."""

    def __init__(self, limit: int = 4, name: str = "default"):
        self.limit = limit
        self.name = name
        self.seen: Set[Tuple] = set()

    def observe(self, signature: Tuple) -> bool:
        """Record a signature; True if it is new (⇒ a fresh compile)."""
        new = signature not in self.seen
        self.seen.add(signature)
        if new:
            _metrics.counter(f"signatures.{self.name}.compiles").inc()
        return new

    def assert_bounded(self) -> None:
        if len(self.seen) > self.limit:
            raise RuntimeError(
                f"{len(self.seen)} distinct minibatch shape signatures "
                f"(> {self.limit}): static padding is broken, every batch "
                f"recompiles the train step")

    def observe_checked(self, signature: Tuple) -> bool:
        """Observe + enforce the bound when the signature is new.

        The shared accounting path: returns True on a fresh signature
        (the caller is about to pay a compile), raising first if the
        tracker has now seen more signatures than its limit.
        """
        new = self.observe(signature)
        if new:
            self.assert_bounded()
        return new

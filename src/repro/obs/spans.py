"""Span tracing with device fencing and Chrome-trace export.

``with span("compute") as sp: out = f(x); sp.fence(out)`` records a
wall-time interval. At span exit the fence value (if any) is passed to
``jax.block_until_ready`` *before* the stop timestamp is taken, so
asynchronously dispatched device work is attributed to the span that
launched it instead of leaking into whichever span happens to block
next.

Spans nest (a per-thread depth is recorded with each event) and are
thread-safe: requester threads and the serve loop trace concurrently
into one shared buffer. :func:`export_chrome_trace` writes the buffer
as Chrome-trace JSON (``{"traceEvents": [...]}``, complete-event
``"ph": "X"`` records with microsecond timestamps) loadable in
Perfetto or chrome://tracing. :func:`span_coverage` reports the
fraction of a wall-clock window covered by top-level spans — the
acceptance metric for "spans cover ≥95% of session wall time".

Every span also feeds the metrics registry histogram
``span.<name>`` (seconds), so span statistics appear in metrics
snapshots without parsing the trace.
"""
import json
import os
import threading
import time

import jax

from . import metrics as _metrics

__all__ = ["Span", "span", "export_chrome_trace", "trace_events",
           "clear_trace", "span_coverage"]

# Process epoch for trace timestamps: Chrome traces want microseconds
# on a shared monotonic axis, not wall-clock.
_T0_NS = time.perf_counter_ns()

_LOCK = threading.Lock()
_EVENTS = []
# Bounded buffer: long sessions must not grow memory without limit.
# Overflow drops new events and counts them (surfaced in snapshots).
_MAX_EVENTS = 500_000

_tls = threading.local()


class Span:
    """One open span. ``fence(x)`` registers a value to
    ``block_until_ready`` at exit; exiting also accepts exceptions
    (the span is recorded either way)."""
    __slots__ = ("name", "cat", "args", "depth", "_t0_ns", "_fence")

    def __init__(self, name, cat, args, depth, t0_ns):
        self.name = name
        self.cat = cat
        self.args = args
        self.depth = depth
        self._t0_ns = t0_ns
        self._fence = None

    def fence(self, value):
        """Block on ``value`` (any pytree of jax arrays) before the
        span's stop timestamp is taken."""
        self._fence = value
        return value

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._fence is not None:
            jax.block_until_ready(self._fence)
        t1_ns = time.perf_counter_ns()
        _tls.depth = self.depth
        dur_ns = t1_ns - self._t0_ns
        ev = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self._t0_ns - _T0_NS) / 1e3,
            "dur": dur_ns / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": dict(self.args or {}, depth=self.depth),
        }
        with _LOCK:
            if len(_EVENTS) < _MAX_EVENTS:
                _EVENTS.append(ev)
            else:
                _metrics.counter("trace.dropped_events").inc()
        _metrics.histogram(f"span.{self.name}").observe(dur_ns / 1e9)
        return False


class _NullSpan:
    __slots__ = ()

    def fence(self, value):
        return value

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


def span(name, cat="repro", args=None):
    """Open a traced span. Returns a no-op span when telemetry is off,
    so instrumented code paths cost one predicate when disabled."""
    if not _metrics.enabled():
        return _NULL_SPAN
    depth = getattr(_tls, "depth", 0)
    _tls.depth = depth + 1
    return Span(name, cat, args, depth, time.perf_counter_ns())


def trace_events():
    """Copy of the recorded trace events (Chrome-trace dicts)."""
    with _LOCK:
        return list(_EVENTS)


def clear_trace():
    with _LOCK:
        _EVENTS.clear()


def export_chrome_trace(path):
    """Write the span buffer as Chrome-trace JSON; returns ``path``.

    Load in Perfetto (ui.perfetto.dev) or chrome://tracing.
    """
    with _LOCK:
        events = list(_EVENTS)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def span_coverage(events=None, tid=None):
    """Fraction of the session window covered by top-level spans.

    The window is [earliest span start, latest span end] over the
    selected events; coverage is the union length of depth-0 spans in
    that window. ``tid`` restricts to one thread (e.g. the serve loop);
    by default all threads' top-level spans contribute to the union.
    Returns 0.0 when there are no events.
    """
    evs = trace_events() if events is None else events
    if tid is not None:
        evs = [e for e in evs if e["tid"] == tid]
    if not evs:
        return 0.0
    t_lo = min(e["ts"] for e in evs)
    t_hi = max(e["ts"] + e["dur"] for e in evs)
    if t_hi <= t_lo:
        return 0.0
    top = sorted((e["ts"], e["ts"] + e["dur"]) for e in evs
                 if e["args"].get("depth", 0) == 0)
    covered = 0.0
    cur_lo = cur_hi = None
    for lo, hi in top:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                covered += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        covered += cur_hi - cur_lo
    return covered / (t_hi - t_lo)

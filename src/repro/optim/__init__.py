"""repro.optim — optimizers, schedules, gradient transforms."""
from .optimizers import (OptState, adamw, sgd, clip_by_global_norm,
                         apply_updates, global_norm)
from .schedules import constant, warmup_cosine, warmup_linear
from .compression import (int8_compress, int8_decompress,
                          compressed_allreduce_terms, compress_payload,
                          wire_bytes, ErrorFeedbackState,
                          init_error_feedback, quantize_with_feedback)
from .precision import Precision, cast_tree, cast_logits

__all__ = [
    "OptState", "adamw", "sgd", "clip_by_global_norm", "apply_updates",
    "global_norm", "constant", "warmup_cosine", "warmup_linear",
    "int8_compress", "int8_decompress", "compressed_allreduce_terms",
    "compress_payload", "wire_bytes",
    "ErrorFeedbackState", "init_error_feedback", "quantize_with_feedback",
    "Precision", "cast_tree", "cast_logits",
]

"""Gradient compression for cross-pod data parallelism.

int8 block quantization with error feedback (EF-SGD style): before the
DP all-reduce, gradients are quantized to int8 with a per-block f32 scale;
the quantization residual is carried to the next step so the compression
is unbiased in the long run. At (pod=2, data=16) this cuts the
pod-axis all-reduce payload ~3.8× (int8 + 1 scale per 256 values vs f32)
— a distributed-optimization trick beyond the paper, measured on the
dry-run collective-bytes term (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads


def init_error_feedback(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray,
                    shape, dtype) -> jnp.ndarray:
    flat = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantize_with_feedback(g: jnp.ndarray, residual: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize g+residual; return (q, scale, new_residual)."""
    target = g.astype(jnp.float32) + residual
    q, scale = int8_compress(target)
    deq = int8_decompress(q, scale, g.shape, jnp.float32)
    return q, scale, target - deq


def compress_payload(x: jnp.ndarray, residual: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Straight-through int8 wire emulation for a differentiable payload.

    Returns ``(y, new_residual)`` where ``y`` carries the dequantized
    int8 values of ``x + residual`` in the forward pass but the
    *identity* adjoint in the backward pass (round/clip have useless
    gradients), and ``new_residual`` is the error-feedback carry —
    stop-gradiented so it can live in the train state without autodiff
    chasing it across steps.
    """
    target = x.astype(jnp.float32) + residual
    q, scale = int8_compress(target)
    deq = int8_decompress(q, scale, x.shape, jnp.float32)
    y = x + jax.lax.stop_gradient(deq.astype(x.dtype) - x)
    return y, jax.lax.stop_gradient(target - deq)


def wire_bytes(n: int, itemsize: int, comm: str) -> Tuple[int, int]:
    """(raw_bytes, wire_bytes) for ``n`` elements of ``itemsize`` under
    comm mode ``comm`` — the accounting the obs counters and the planner
    comm term share."""
    raw = n * itemsize
    if comm == "int8":
        return raw, n * 1 + (-(-n // BLOCK)) * 4
    return raw, raw


def compressed_allreduce_terms(params) -> Tuple[int, int]:
    """(raw_bytes, compressed_bytes) for a full-gradient all-reduce."""
    raw = 0
    comp = 0
    for p in jax.tree_util.tree_leaves(params):
        n = p.size
        raw += n * p.dtype.itemsize
        blocks = -(-n // BLOCK)
        comp += n * 1 + blocks * 4
    return raw, comp

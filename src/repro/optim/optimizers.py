"""Minimal optax-style optimizers as pure pytree transforms.

An optimizer is a pair ``(init_fn, update_fn)``:
  * ``init_fn(params) -> state``
  * ``update_fn(grads, state, params, step) -> (updates, new_state)``
and ``apply_updates(params, updates)`` adds them. States are plain pytrees
so they shard/checkpoint like parameters (ZeRO-style sharding happens at
the launch layer by giving state leaves the same PartitionSpec as their
parameter).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

OptState = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), n


class AdamState(NamedTuple):
    mu: Any
    nu: Any


def adamw(lr=1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0):
    """AdamW with decoupled weight decay. Moments kept in f32."""
    sched = _as_schedule(lr)

    def init_fn(params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update_fn(grads, state: AdamState, params, step):
        step = jnp.asarray(step, jnp.int32) + 1
        lr_t = sched(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / b1c
            vh = v / b2c
            u = -lr_t * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m, v

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        ups = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return ups, AdamState(mu=mu, nu=nu)

    return init_fn, update_fn


class SGDState(NamedTuple):
    mom: Any


def sgd(lr=1e-2, momentum: float = 0.9, nesterov: bool = False):
    sched = _as_schedule(lr)

    def init_fn(params) -> SGDState:
        return SGDState(mom=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update_fn(grads, state: SGDState, params, step):
        lr_t = sched(jnp.asarray(step, jnp.int32) + 1)

        def upd(g, m):
            g32 = g.astype(jnp.float32)
            m = momentum * m + g32
            d = g32 + momentum * m if nesterov else m
            return (-lr_t * d).astype(g.dtype), m

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mom)
        out = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        return (tdef.unflatten([o[0] for o in out]),
                SGDState(mom=tdef.unflatten([o[1] for o in out])))

    return init_fn, update_fn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)

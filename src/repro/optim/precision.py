"""Precision policy for training (DESIGN.md §12).

One small config object decides three things, independently:

  * ``compute``  — the dtype the forward/backward runs in. Parameters
    stay fp32 *master weights* (SplitSGD-style: optimizer moments and
    updates are fp32; only the copy used inside the loss is cast), so
    bf16 training changes the arithmetic of the model, never the
    update rule.
  * ``accum``    — the dtype reductions accumulate in. Aggregation
    norm weights and segment-reduce accumulators stay here (fp32)
    regardless of ``compute``; see core/partition.py.
  * ``comm``     — the wire format of cross-shard exchanges in the
    partitioned path: ``"none"`` ships raw features, ``"int8"`` ships
    blockwise int8 + per-block fp32 scales with an error-feedback
    residual carried in the train state (optim/compression.py).

``Precision.fp32()`` is the do-nothing default: every train loop
threads a policy, but at fp32/none the step is bit-identical to the
pre-policy code.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Precision", "cast_tree", "cast_logits"]

_COMM_MODES = ("none", "int8")


class Precision(NamedTuple):
    """compute/accumulation dtypes + comm compression mode."""
    compute: Any = jnp.float32
    accum: Any = jnp.float32
    comm: str = "none"

    @classmethod
    def fp32(cls) -> "Precision":
        return cls()

    @classmethod
    def bf16(cls, comm: str = "none") -> "Precision":
        return cls(compute=jnp.bfloat16, accum=jnp.float32, comm=comm)

    @classmethod
    def parse(cls, name: str, comm: str = "none") -> "Precision":
        if comm not in _COMM_MODES:
            raise ValueError(f"comm must be one of {_COMM_MODES}: {comm!r}")
        if name == "fp32":
            return cls(comm=comm)
        if name == "bf16":
            return cls.bf16(comm=comm)
        raise ValueError(f"unknown precision preset: {name!r}")

    @property
    def mixed(self) -> bool:
        return jnp.dtype(self.compute) != jnp.dtype(jnp.float32)

    def tag(self) -> str:
        """Short label for plan rows / bench json ("bf16+int8")."""
        p = jnp.dtype(self.compute).name.replace("float32", "fp32") \
            .replace("bfloat16", "bf16")
        return p if self.comm == "none" else f"{p}+{self.comm}"


def cast_tree(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype`` (ints pass)."""
    def cast(p):
        if jnp.issubdtype(jnp.asarray(p).dtype, jnp.floating):
            return jnp.asarray(p).astype(dtype)
        return p
    return jax.tree.map(cast, tree)


def cast_logits(logits):
    """Loss inputs always go back to fp32: softmax/CE in bf16 loses
    enough mantissa to visibly bend the loss trajectory."""
    return logits.astype(jnp.float32)

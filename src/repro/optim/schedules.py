"""Learning-rate schedules (step -> lr, step is 1-based)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_linear(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        decay = peak + (floor - peak) * frac
        return jnp.where(step < warmup_steps, warm, decay)
    return sched


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        decay = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, decay)
    return sched

"""Ambient-mesh sharding hints.

Model code calls ``shard_hint(x, "data", None, "model")`` with LOGICAL axis
names; if a mesh has been installed via ``ambient_mesh(mesh)`` the hint
becomes a real ``with_sharding_constraint`` (with "data" expanding to
("pod", "data") on multi-pod meshes), otherwise it is a no-op — so the
same model runs on 1 CPU device in tests and on the 512-chip mesh in the
dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def ambient_mesh(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def resolve_axis(mesh: Mesh, name):
    """Logical -> physical axes: 'data' covers ('pod','data') if present.

    Accepts a tuple of logical names for multi-axis dims (flattened)."""
    if name is None:
        return None
    if isinstance(name, tuple):
        flat = []
        for n in name:
            r = resolve_axis(mesh, n)
            if isinstance(r, tuple):
                flat.extend(r)
            elif r is not None:
                flat.append(r)
        return tuple(flat)
    if name == "data" and "pod" in mesh.axis_names:
        return ("pod", "data")
    return name


def make_spec(mesh: Mesh, *axes) -> P:
    return P(*[resolve_axis(mesh, a) for a in axes])


def shard_hint(x, *axes):
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, make_spec(mesh, *axes)))

"""repro.substrate — framework primitives (paper §4) + NN building blocks."""
from .batchnorm import batchnorm1d_init, batchnorm1d_apply, batchnorm1d_naive
from .embedding import embedding_init, embedding_lookup, embedding_lookup_naive
from .nn import (linear_init, linear_apply, dropout, leaky_relu,
                 glorot, he_normal, cross_entropy_loss, accuracy)

__all__ = [
    "batchnorm1d_init", "batchnorm1d_apply", "batchnorm1d_naive",
    "embedding_init", "embedding_lookup", "embedding_lookup_naive",
    "linear_init", "linear_apply", "dropout", "leaky_relu",
    "glorot", "he_normal", "cross_entropy_loss", "accuracy",
]

"""BatchNorm1d (paper §4).

The paper found PyTorch's CPU BatchNorm1d unoptimized (no MKLDNN path) and
wrote a parallel-over-samples, vectorized-over-features version worth 13×
in LGNN. In XLA the optimized form is a single fused normalization
expression (`batchnorm1d_apply`); we keep a deliberately de-optimized
`batchnorm1d_naive` (per-feature Python loop — serialized, the moral
equivalent of the unvectorized baseline) for the benchmark comparison.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def batchnorm1d_init(d: int) -> Dict[str, jnp.ndarray]:
    return {
        "scale": jnp.ones((d,), jnp.float32),
        "bias": jnp.zeros((d,), jnp.float32),
        "running_mean": jnp.zeros((d,), jnp.float32),
        "running_var": jnp.ones((d,), jnp.float32),
    }


def batchnorm1d_apply(state: Dict[str, jnp.ndarray], x: jnp.ndarray,
                      *, train: bool = True, momentum: float = 0.9,
                      eps: float = 1e-5
                      ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Fused batch norm over axis 0. Returns (y, new_state)."""
    if train:
        mean = jnp.mean(x, axis=0)
        var = jnp.var(x, axis=0)
        new_state = dict(state)
        new_state["running_mean"] = (momentum * state["running_mean"]
                                     + (1 - momentum) * mean)
        new_state["running_var"] = (momentum * state["running_var"]
                                    + (1 - momentum) * var)
    else:
        mean, var = state["running_mean"], state["running_var"]
        new_state = state
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * (inv * state["scale"]) + state["bias"]
    return y.astype(x.dtype), new_state


def batchnorm1d_naive(state: Dict[str, jnp.ndarray], x: jnp.ndarray,
                      *, eps: float = 1e-5) -> jnp.ndarray:
    """Baseline: one lane at a time (unrolled per-feature loop).

    Mirrors the pre-optimization PyTorch CPU kernel shape: feature-major
    serial normalization, no cross-feature vectorization.
    """
    cols = []
    for j in range(x.shape[1]):
        c = x[:, j]
        m = jnp.mean(c)
        v = jnp.var(c)
        cols.append((c - m) / jnp.sqrt(v + eps)
                    * state["scale"][j] + state["bias"][j])
    return jnp.stack(cols, axis=1)

"""Embedding with Copy-Reduce backward (paper §4).

The paper observes the Embedding primitive *is* aggregation: forward =
gather, backward = scatter-reduce of cotangents into the weight rows —
exactly Copy-Reduce. ``embedding_lookup`` wires that up explicitly with a
``custom_vjp`` whose backward uses the CR pull-segment strategy (sorted
segment reduction, owner-computes) instead of autodiff's naive
scatter-add; ``embedding_lookup_naive`` keeps autodiff's scatter for the
benchmark baseline.

This same primitive serves the LM stack: token embeddings with vocab up to
152k make the scatter-reduce backward a real hot spot (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.strategies import pull_segment


def embedding_init(key, vocab: int, d: int, scale: float = 0.02,
                   dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * scale).astype(dtype)


@jax.custom_vjp
def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def _emb_fwd(table, ids):
    # keep a zero-size view of the table so bwd knows vocab/dtype without
    # holding the full table live
    return jnp.take(table, ids, axis=0), (ids, table[:, :0])


def _emb_bwd(res, ct):
    ids, table_view = res
    vocab, dtype = table_view.shape[0], table_view.dtype
    flat_ids = ids.reshape(-1)
    flat_ct = ct.reshape(-1, ct.shape[-1])
    # CR: sort by destination row, then owner-computes segment-sum —
    # the paper's pull model applied to the embedding gradient.
    order = jnp.argsort(flat_ids)
    grad = pull_segment(jnp.take(flat_ct, order, axis=0),
                        jnp.take(flat_ids, order), vocab, "sum")
    return grad.astype(dtype), None


embedding_lookup.defvjp(_emb_fwd, _emb_bwd)


def embedding_lookup_naive(table: jnp.ndarray, ids: jnp.ndarray
                           ) -> jnp.ndarray:
    """Autodiff path: backward lowers to unsorted scatter-add (baseline)."""
    return jnp.take(table, ids, axis=0)

"""Small functional NN building blocks shared by GNN and LM stacks."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def he_normal(key, shape, dtype=jnp.float32):
    std = jnp.sqrt(2.0 / shape[0])
    return (jax.random.normal(key, shape) * std).astype(dtype)


def linear_init(key, d_in: int, d_out: int, bias: bool = True,
                dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    p = {"w": glorot(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def leaky_relu(x, slope: float = 0.2):
    return jnp.where(x >= 0, x, slope * x)


def dropout(key, x, rate: float, train: bool):
    if not train or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0).astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        m = mask.astype(nll.dtype)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(hit * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(hit)

"""Shared test fixtures/utilities.

NOTE: XLA_FLAGS device-count tricks are NOT set here — smoke tests and
benches must see the single real CPU device. Multi-device tests re-exec
themselves in a subprocess with their own XLA_FLAGS.
"""
import numpy as np
import pytest


def make_graph(rng, n_src, n_dst, nnz, *, unique=False):
    """Random COO graph (host arrays) + a repro.core Graph."""
    from repro.core import from_coo
    src = rng.integers(0, n_src, nnz)
    dst = rng.integers(0, n_dst, nnz)
    if unique:
        pairs = np.unique(np.stack([src, dst], 1), axis=0)
        src, dst = pairs[:, 0], pairs[:, 1]
    g = from_coo(src, dst, n_src=n_src, n_dst=n_dst)
    return g, src, dst


@pytest.fixture
def rng():
    return np.random.default_rng(0)

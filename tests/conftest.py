"""Shared test fixtures/utilities.

NOTE: XLA_FLAGS device-count tricks are NOT set here — smoke tests and
benches must see the single real CPU device. Multi-device tests re-exec
themselves in a subprocess with their own XLA_FLAGS (via
:func:`run_multidevice`, which converts platform crashes into skips).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

# Host-platform device emulation needs the crash convention below
# (signal death ⇒ negative returncode) to be observable — POSIX only.
# Core count is NOT a precondition: XLA's emulated devices are threads,
# so even a 1-CPU host runs 8 of them (slowly). The child env forces
# the emulated device count, so the guard never silently skips on
# small hosts (the PR-6 regression: 6 tests skipped on 1-CPU runners).
MULTIDEVICE_UNSUPPORTED = (
    "multi-device host-platform emulation needs a POSIX host (signal "
    "death must be observable as a negative returncode)"
    if os.name != "posix" else None)

MULTIDEVICE_FLAGS = "--xla_force_host_platform_device_count=8"


def run_multidevice(prog: str, *args: str, timeout: int = 900):
    """Run a multi-device-emulation program in a subprocess.

    XLA's forced host-platform device emulation is known to SIGSEGV
    inside collective compilation on some kernels/containers. A child
    killed by a signal is a platform precondition failure, not a code
    regression — skip. A child that exits nonzero (a real assertion
    inside the program) still FAILS the test.
    """
    if MULTIDEVICE_UNSUPPORTED:
        pytest.skip(MULTIDEVICE_UNSUPPORTED)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # force emulated devices from the OUTSIDE too: programs that set
    # XLA_FLAGS themselves before importing jax keep working, and ones
    # that don't still see 8 emulated devices on any host size
    env["XLA_FLAGS"] = MULTIDEVICE_FLAGS
    r = subprocess.run([sys.executable, "-c", prog, *args], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode < 0:
        pytest.skip(f"multi-device emulation subprocess died with signal "
                    f"{-r.returncode} (known host-platform emulation "
                    f"crash on this kernel) — skipping, not failing")
    return r


def make_graph(rng, n_src, n_dst, nnz, *, unique=False):
    """Random COO graph (host arrays) + a repro.core Graph.

    Back-compat alias of the shared generator in ``tests.graphgen``.
    """
    from tests.graphgen import random_graph
    return random_graph(rng, n_src, n_dst, nnz, unique=unique)


@pytest.fixture
def rng():
    return np.random.default_rng(0)

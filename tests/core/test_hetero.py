"""Relation-fused execution (core/hetero.py, DESIGN.md §8):
RelGraph structural invariants, hetero planning (cost rows, memoization,
autotune, pinning), and the relational-block fused op's VJP contract.

The cross-strategy differential harness proper lives in
tests/core/test_strategy_equivalence.py (check_hetero); these tests
cover the structure and the planner around it.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (from_coo, from_rels, from_typed, gspmm,
                        hetero_block_gspmm, hetero_gspmm, planner)
from repro.core.hetero import RelGraph


def _rels(rng, n, sizes):
    return [(rng.integers(0, n, s), rng.integers(0, n, s))
            for s in sizes]


# --------------------------------------------------------------------- #
# structure
# --------------------------------------------------------------------- #
def test_relgraph_invariants():
    rng = np.random.default_rng(0)
    sizes = [30, 0, 5, 17]          # skew + one empty relation
    rels = _rels(rng, 40, sizes)
    rg = from_rels(rels, n_src=40, n_dst=40)

    assert rg.n_rel == 4 and rg.rel_sizes == tuple(sizes)
    assert rg.n_edges == sum(sizes)
    assert rg.rel_ptr == (0, 30, 30, 35, 52)
    # canonical relation tags: slicing the rel-sorted view recovers each
    # relation's edge multiset
    rel = np.asarray(rg.rel)
    perm = np.asarray(rg.perm_rel)
    src = np.asarray(rg.g.src)
    dst = np.asarray(rg.g.dst)
    ptr = rg.rel_ptr
    for r, (s, d) in enumerate(rels):
        slots = perm[ptr[r]:ptr[r + 1]]
        assert (rel[slots] == r).all()
        got = sorted(zip(src[slots].tolist(), dst[slots].tolist()))
        want = sorted(zip(np.asarray(s).tolist(), np.asarray(d).tolist()))
        assert got == want
    # reverse view: (src, rel) keys non-decreasing -> the backward's
    # per-(src, rel) aggregate is a SORTED segment reduce
    key = (np.asarray(rg.rev_src) * rg.n_rel + np.asarray(rg.rev_rel))
    assert (np.diff(key) >= 0).all()
    # per-relation mean norms: within one relation, each destination's
    # incident weights sum to 1
    for r in range(4):
        slots = perm[ptr[r]:ptr[r + 1]]
        if not len(slots):
            continue
        sums = np.zeros(40)
        np.add.at(sums, dst[slots], np.asarray(rg.mean_norm)[slots])
        touched = np.unique(dst[slots])
        np.testing.assert_allclose(sums[touched], 1.0, rtol=1e-6)


def test_relgraph_caller_edge_order():
    """``e`` operands are indexed in relation-concatenated caller order."""
    rng = np.random.default_rng(1)
    rels = _rels(rng, 20, [10, 8])
    rg = from_rels(rels, n_src=20, n_dst=20)
    e = jnp.arange(rg.n_edges, dtype=jnp.float32)
    u = jnp.ones((20, 1), jnp.float32)
    out = hetero_gspmm(rg, u, e=e, strategy="fused")
    # reference over the merged caller-order edge list
    src = np.concatenate([s for s, _ in rels])
    dst = np.concatenate([d for _, d in rels])
    ref = np.zeros((20, 1), np.float32)
    np.add.at(ref, dst, np.asarray(e)[:, None])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)


def test_from_typed_matches_from_rels():
    rng = np.random.default_rng(2)
    rels = _rels(rng, 15, [6, 9, 3])
    rg_a = from_rels(rels, n_src=15, n_dst=15)
    src = np.concatenate([s for s, _ in rels])
    dst = np.concatenate([d for _, d in rels])
    rel = np.concatenate([np.full(len(s), r)
                          for r, (s, _) in enumerate(rels)])
    rg_b = from_typed(src, dst, rel, n_src=15, n_dst=15)
    u = jnp.asarray(rng.normal(size=(15, 4)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(3, 4, 2)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(hetero_gspmm(rg_a, u, w=W, reduce="mean")),
        np.asarray(hetero_gspmm(rg_b, u, w=W, reduce="mean")),
        rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# planning
# --------------------------------------------------------------------- #
def test_plan_hetero_cost_rows():
    """Many relations ⇒ fused-family (the loop's R dispatch overheads
    dominate); few relations × big edge set ⇒ the loop is competitive."""
    planner.clear_hetero_plans()
    try:
        many = planner.plan_hetero((5000, 5000, 40_000, 80),
                                   "u_w_mean_v", 16, stats=None)
        assert many == "fused"
        few = planner.plan_hetero((5000, 5000, 400_000, 2),
                                  "u_w_mean_v", 64, stats=None)
        assert few == "loop"
        # memoized: same signature returns the same decision
        assert planner.plan_hetero((5000, 5000, 40_000, 80),
                                   "u_w_mean_v", 16, stats=None) == many
        assert planner.last_plan("hetero:u_w_mean_v") == many
    finally:
        planner.clear_hetero_plans()


def test_plan_hetero_pins_and_fallback():
    planner.clear_hetero_plans()
    try:
        sig = (100, 100, 500, 4)
        for s in ("fused", "loop"):
            assert planner.plan_hetero(sig, "u_w_sum_v", 8,
                                       requested=s) == s
        # plain gspmm pins map onto the loop (push keeps the scatter)
        assert planner.plan_hetero(sig, "u_w_sum_v", 8,
                                   requested="push") == "push"
        assert planner.plan_hetero(sig, "u_w_sum_v", 8,
                                   requested="segment") == "loop"
        # pinned ell without a pack falls back with a one-time warning
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            assert planner.plan_hetero(sig, "u_w_sum_v", 8,
                                       requested="ell",
                                       ell_ok=False) == "fused"
        with pytest.raises(ValueError):
            planner.plan_hetero(sig, "u_w_sum_v", 8, requested="bogus")
    finally:
        planner.clear_hetero_plans()


def test_hetero_autotune_measures_and_caches():
    rng = np.random.default_rng(3)
    rels = _rels(rng, 60, [50, 30, 20])
    rg = from_rels(rels, n_src=60, n_dst=60)
    u = jnp.asarray(rng.normal(size=(60, 8)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(3, 8, 4)).astype(np.float32))
    ref = hetero_gspmm(rg, u, w=W, strategy="loop")
    planner.clear_hetero_plans()
    planner.set_mode("autotune")
    try:
        out = hetero_gspmm(rg, u, w=W)          # eager: measures
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        chosen = planner.last_plan("hetero:u_w_sum_v")
        assert chosen in planner.HETERO_STRATEGIES
        n_keys = len(planner._HETERO_PLANS)
        hetero_gspmm(rg, u, w=W)                # cached decision
        assert len(planner._HETERO_PLANS) == n_keys
        assert planner.last_plan("hetero:u_w_sum_v") == chosen
    finally:
        planner.set_mode("cost")
        planner.clear_hetero_plans()


def test_hetero_under_jit():
    """A RelGraph is a pytree: the fused op plans and executes inside a
    jitted function (static signature + cache-carried stats), matching
    the eager result."""
    rng = np.random.default_rng(4)
    rels = _rels(rng, 50, [40, 25])
    rg = from_rels(rels, n_src=50, n_dst=50)
    u = jnp.asarray(rng.normal(size=(50, 6)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(2, 6, 3)).astype(np.float32))
    ref = hetero_gspmm(rg, u, w=W, reduce="mean")
    out = jax.jit(lambda rg, u, W: hetero_gspmm(rg, u, w=W,
                                                reduce="mean"))(rg, u, W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_monet_krel_pack_memoized():
    """The K-relation RelGraph is a PlanCache pack: built once, reused,
    and the fused per-kernel aggregation equals the per-kernel loop."""
    rng = np.random.default_rng(5)
    src = rng.integers(0, 40, 150)
    dst = rng.integers(0, 40, 150)
    g = from_coo(src, dst, n_src=40, n_dst=40)
    cache = planner.get_plan_cache(g)
    before = planner.pack_build_totals().get("krel", 0)
    rg = cache.krel(3)
    assert rg is not None and rg.n_rel == 3
    assert cache.krel(3) is rg
    assert planner.pack_build_totals().get("krel", 0) == before + 1

    K, d = 3, 5
    z = jnp.asarray(rng.normal(size=(40, K, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(g.n_edges, K))
                    .astype(np.float32))
    fused = hetero_gspmm(rg, z, e=w.T.reshape(-1), strategy="fused")
    loop = sum(gspmm(g, "u_mul_e_add_v", u=z[:, k], e=w[:, k:k + 1],
                     strategy="segment") for k in range(K))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(loop),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------- #
# relational blocks
# --------------------------------------------------------------------- #
def _relational_block(rng, n=60, n_rel=4, nnz=200, fanout=4, batch=12):
    from repro.data import NeighborSampler

    src = rng.integers(0, n, nnz)
    dst = rng.integers(0, n, nnz)
    rel = rng.integers(0, n_rel, nnz)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    sampler = NeighborSampler(g, fanouts=[fanout], batch_size=batch,
                              seed=0, edge_rel=rel)
    seeds = rng.permutation(n)[:batch]
    mb = sampler.sample(seeds, np.zeros(batch, np.int64))
    return mb.blocks[0], n_rel


def test_hetero_block_matches_per_relation_reference():
    """Fused relational block aggregation (both backward paths) vs the
    explicit per-relation masked reference, outputs AND cotangents."""
    rng = np.random.default_rng(6)
    blk, n_rel = _relational_block(rng)
    bg = blk.bg
    d, o = 5, 3
    u = jnp.asarray(rng.normal(size=(bg.g.n_src, d)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(n_rel, d, o)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(bg.n_dst_real, o))
                     .astype(np.float32))

    # reference: per-edge masked messages reduced per destination row
    src_c = np.asarray(bg.g.src)[np.asarray(bg.g.eid_inv)]
    dst_c = np.asarray(bg.g.dst)[np.asarray(bg.g.eid_inv)]
    rel_c = np.asarray(blk.rel)
    norm_c = np.asarray(blk.rel_norm)

    def ref(u, W):
        msg = jnp.einsum("ed,edo->eo",
                         jnp.take(u, jnp.asarray(src_c), axis=0),
                         jnp.take(W, jnp.asarray(rel_c), axis=0))
        msg = msg * jnp.asarray(norm_c)[:, None]
        out = jax.ops.segment_sum(msg, jnp.asarray(dst_c),
                                  num_segments=bg.g.n_dst)
        return out[: bg.n_dst_real]

    r0 = ref(u, W)
    gr = jax.grad(lambda u, W: jnp.sum(ref(u, W) * ct),
                  argnums=(0, 1))(u, W)
    for strategy in ("segment", "ell", "auto"):
        for bwd in ("gather", "scatter"):
            out = hetero_block_gspmm(bg, blk.rel, u, W,
                                     norm=blk.rel_norm,
                                     strategy=strategy, bwd_strategy=bwd)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(r0), rtol=1e-4, atol=1e-4,
                err_msg=f"output via {strategy}+{bwd}")
            gu, gw = jax.grad(
                lambda u, W: jnp.sum(hetero_block_gspmm(
                    bg, blk.rel, u, W, norm=blk.rel_norm,
                    strategy=strategy, bwd_strategy=bwd) * ct),
                argnums=(0, 1))(u, W)
            np.testing.assert_allclose(np.asarray(gu), np.asarray(gr[0]),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"du via {strategy}+{bwd}")
            np.testing.assert_allclose(np.asarray(gw), np.asarray(gr[1]),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"dw via {strategy}+{bwd}")


def test_relational_sampler_norms():
    """Per-(dst, relation) sampled-mean weights: each real destination's
    incident weights sum to its number of DISTINCT sampled relations;
    pad edges carry weight 0 and relation 0."""
    rng = np.random.default_rng(7)
    blk, n_rel = _relational_block(rng, fanout=3)
    bg = blk.bg
    rel = np.asarray(blk.rel)
    norm = np.asarray(blk.rel_norm)
    dst_c = np.asarray(bg.g.dst)[np.asarray(bg.g.eid_inv)]
    real = dst_c < bg.n_dst_real
    assert (norm[~real] == 0).all() and (rel[~real] == 0).all()
    for j in np.unique(dst_c[real]):
        m = real & (dst_c == j)
        n_rel_here = len(np.unique(rel[m]))
        np.testing.assert_allclose(norm[m].sum(), n_rel_here, rtol=1e-5)


def test_skew_classes_split_and_match():
    """Size-skew-aware ell-per-relation-class: under a materially skewed
    relation-size distribution the ell route must split the fused edge
    set into per-size-class packs (so one giant relation doesn't set
    everyone's pad width) — partitioning the edges exactly, matching
    the loop reference on outputs AND gradients, and surviving jit with
    prebuilt classes."""
    from repro.core import hetero as H

    rng = np.random.default_rng(21)
    n = 50
    sizes = [900, 16, 11, 7, 4]
    src = np.concatenate([rng.integers(0, n, s) for s in sizes])
    dst = np.concatenate([rng.integers(0, n, s) for s in sizes])
    rel = np.concatenate([np.full(s, r) for r, s in enumerate(sizes)])
    rg = from_typed(src, dst, rel, n_src=n, n_dst=n, n_rel=5)

    classes = H._skew_classes(rg)
    assert classes is not None and len(classes) >= 2
    # the class slot sets partition the fused edge set exactly
    all_slots = np.concatenate([np.asarray(s) for _, s in classes])
    assert sorted(all_slots.tolist()) == list(range(rg.n_edges))
    # per-class packs are narrower than the fused graph's global one:
    # each class's max degree bounds its pad width
    degs = [int(np.asarray(cg.in_degrees).max()) for cg, _ in classes]
    assert min(degs) < max(degs)

    u = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(5, 6, 3)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    for red in ("sum", "mean"):
        ref = hetero_gspmm(rg, u, w=W, reduce=red, strategy="loop")
        out = hetero_gspmm(rg, u, w=W, reduce=red, strategy="ell")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"skew ell output ({red})")
    gu_e, gw_e = jax.grad(
        lambda a, b: jnp.sum(hetero_gspmm(rg, a, w=b, strategy="ell")
                             * ct), argnums=(0, 1))(u, W)
    gu_l, gw_l = jax.grad(
        lambda a, b: jnp.sum(hetero_gspmm(rg, a, w=b, strategy="loop")
                             * ct), argnums=(0, 1))(u, W)
    np.testing.assert_allclose(np.asarray(gu_e), np.asarray(gu_l),
                               rtol=1e-3, atol=1e-3, err_msg="skew du")
    np.testing.assert_allclose(np.asarray(gw_e), np.asarray(gw_l),
                               rtol=1e-3, atol=1e-3, err_msg="skew dw")

    # prebuilt classes are plain constants under jit
    f = jax.jit(lambda a, b: hetero_gspmm(rg, a, w=b, strategy="ell"))
    np.testing.assert_allclose(
        np.asarray(f(u, W)),
        np.asarray(hetero_gspmm(rg, u, w=W, strategy="loop")),
        rtol=1e-4, atol=1e-4, err_msg="skew ell under jit")

    # near-uniform sizes must NOT split
    sizes2 = [40, 37, 41, 39]
    src2 = np.concatenate([rng.integers(0, n, s) for s in sizes2])
    dst2 = np.concatenate([rng.integers(0, n, s) for s in sizes2])
    rel2 = np.concatenate([np.full(s, r) for r, s in enumerate(sizes2)])
    rg2 = from_typed(src2, dst2, rel2, n_src=n, n_dst=n, n_rel=4)
    assert H._skew_classes(rg2) is None

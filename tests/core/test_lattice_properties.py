"""Property-based tests (hypothesis) for the BR/CR lattice invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis installed")
from hypothesis import given, settings

from repro.core import (from_coo, gspmm, copy_reduce, build_ell, build_tiles,
                        reverse, parse_op)
from tests.graphgen import graphs


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_strategies_agree(data):
    """push / segment / ell / onehot / pallas all compute the same CR."""
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    x = jnp.asarray(rng.normal(size=(n_u, 9)).astype(np.float32))
    outs = {s: np.asarray(copy_reduce(g, x, "sum", strategy=s))
            for s in ("push", "segment", "ell", "onehot", "pallas")}
    base = outs.pop("segment")
    for name, o in outs.items():
        np.testing.assert_allclose(o, base, rtol=1e-4, atol=1e-4,
                                   err_msg=name)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_edge_order_invariance(data):
    """CR must not depend on the caller's edge ordering."""
    src, dst, n_u, n_v, rng = data
    x = jnp.asarray(rng.normal(size=(n_u, 5)).astype(np.float32))
    g1 = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    perm = rng.permutation(len(src))
    g2 = from_coo(src[perm], dst[perm], n_src=n_u, n_dst=n_v)
    np.testing.assert_allclose(np.asarray(copy_reduce(g1, x)),
                               np.asarray(copy_reduce(g2, x)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_linearity_of_sum_reduce(data):
    """CR_sum(a·x + b·y) == a·CR_sum(x) + b·CR_sum(y)."""
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    x = jnp.asarray(rng.normal(size=(n_u, 4)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n_u, 4)).astype(np.float32))
    lhs = copy_reduce(g, 2.0 * x + 3.0 * y)
    rhs = 2.0 * copy_reduce(g, x) + 3.0 * copy_reduce(g, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_mean_equals_sum_over_degree(data):
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    x = jnp.asarray(rng.normal(size=(n_u, 3)).astype(np.float32))
    s = np.asarray(copy_reduce(g, x, "sum"))
    m = np.asarray(copy_reduce(g, x, "mean"))
    deg = np.asarray(g.in_degrees)[:, None]
    np.testing.assert_allclose(m, s / np.maximum(deg, 1), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_reverse_transpose_identity(data):
    """CR on G == push-to-u on reverse(G): A @ x == (Aᵀ)ᵀ @ x."""
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    rg = reverse(g)
    x = jnp.asarray(rng.normal(size=(n_u, 4)).astype(np.float32))
    a = np.asarray(copy_reduce(g, x))
    b = np.asarray(gspmm(rg, "v_copy_add_u", v=x))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(graphs(max_n=25, max_e=80))
def test_max_min_reductions_bound_sum(data):
    """max ≥ mean ≥ min wherever degree > 0."""
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    x = jnp.asarray(rng.normal(size=(n_u, 3)).astype(np.float32))
    mx = np.asarray(copy_reduce(g, x, "max"))
    mn = np.asarray(copy_reduce(g, x, "min"))
    mean = np.asarray(copy_reduce(g, x, "mean"))
    has = np.asarray(g.in_degrees) > 0
    assert (mx[has] + 1e-5 >= mean[has]).all()
    assert (mean[has] + 1e-5 >= mn[has]).all()


def test_parse_round_trip():
    for name in ["u_copy_add_v", "e_copy_max_v", "u_mul_e_add_v",
                 "u_dot_v_add_e", "u_add_v_copy_e", "e_sub_v_copy_e",
                 "e_div_v_copy_e", "v_mul_e_copy_e", "u_copy_mean_v"]:
        spec = parse_op(name)
        # round trip through the canonical name parser again
        assert parse_op(spec.name) == spec


def test_parse_rejects_garbage():
    for bad in ["u_copy_v", "x_mul_e_add_v", "u_pow_e_add_v",
                "u_mul_e_median_v", "u_mul_e_add_x"]:
        with pytest.raises(ValueError):
            parse_op(bad)


@settings(max_examples=10, deadline=None)
@given(graphs(max_n=30, max_e=100))
def test_training_op_gradients_match_autodiff(data):
    """weighted_copy_reduce custom VJP == autodiff of the segment path."""
    import jax
    from repro.core.training_ops import (make_training_graph,
                                         weighted_copy_reduce)
    src, dst, n_u, n_v, rng = data
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    tg = make_training_graph(g)
    x = jnp.asarray(rng.normal(size=(n_u, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(g.n_edges, 1)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n_v, 5)).astype(np.float32))

    def f_custom(x, w):
        return jnp.sum(weighted_copy_reduce(tg, x, w) * ct)

    def f_ref(x, w):
        msg = jnp.take(x, g.src, axis=0) \
            * jnp.take(w[:, 0], g.eid)[:, None]
        return jnp.sum(jax.ops.segment_sum(
            msg, g.dst, num_segments=n_v) * ct)

    gx1, gw1 = jax.grad(f_custom, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                               rtol=1e-4, atol=1e-4)

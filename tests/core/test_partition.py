"""Partitioned-graph subsystem: host-side plan invariants, the emulated
ring against the single-device apps, and the delayed-halo semantics.

Everything here runs on one real device (``mesh=None`` → the emulated
ring, which shares the bucket math and the transposed-ring custom VJP
with the multi-device path). The multi-device forms of the same checks
live in tests/launch/test_partitioned_train.py.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import from_coo, gspmm
from repro.core.edge_softmax import edge_softmax_fused
from repro.core.partition import (PARTITION_MODES, build_partition,
                                  bucket_softmax, local_gspmm,
                                  offdiag_weights, ring_edge_values,
                                  ring_gspmm, ring_gspmm_delayed,
                                  ring_reference)
from repro.core.planner import get_plan_cache
from repro.models.gnn import gat, gcn, sage
from repro.models.gnn.common import (make_bundle, make_partitioned_bundle)
from repro.substrate.nn import cross_entropy_loss
from tests.graphgen import random_graph


def _square_graph(rng, n=48, nnz=300):
    g, src, dst = random_graph(rng, n, n, nnz)
    return g


@pytest.mark.parametrize("mode", PARTITION_MODES)
@pytest.mark.parametrize("n_shards", [1, 2, 3, 5])
def test_build_partition_invariants(mode, n_shards):
    rng = np.random.default_rng(0)
    g = _square_graph(rng, 41, 260)
    pg = build_partition(g, n_shards, mode)
    n = pg.n
    to_pad = np.asarray(pg.to_pad)
    from_pad = np.asarray(pg.from_pad)
    # bijection between vertices and non-pad padded slots
    assert len(np.unique(to_pad)) == n
    assert (from_pad[to_pad] == np.arange(n)).all()
    assert ((from_pad == -1).sum()) == pg.n_pad - n
    # every edge lands in exactly one bucket slot; the bucket-local
    # endpoints reconstruct the original edge multiset
    mask = np.asarray(pg.mask)
    assert mask.sum() == g.n_edges
    sl = np.asarray(pg.src_local)
    dl = np.asarray(pg.dst_local)
    eid = np.asarray(pg.eid)
    S, rows = pg.n_shards, pg.rows
    i, j, k = np.nonzero(mask)
    gsrc = from_pad[j * rows + sl[i, j, k]]
    gdst = from_pad[i * rows + dl[i, j, k]]
    assert (gsrc >= 0).all() and (gdst >= 0).all()
    got = sorted(zip(gsrc.tolist(), gdst.tolist()))
    src_np, dst_np, eid_np = g.numpy_coo()
    want = sorted(zip(src_np.tolist(), dst_np.tolist()))
    assert got == want
    # caller-order edge ids are a permutation
    assert sorted(eid[i, j, k].tolist()) == list(range(g.n_edges))
    # stats
    st = pg.stats
    assert st.n_edges == g.n_edges
    assert 0.0 <= st.cut_fraction <= 1.0
    assert st.pad_ratio >= 1.0
    assert st.balance >= 1.0 - 1e-9
    if n_shards == 1:
        assert st.cut_fraction == 0.0


def test_scatter_gather_roundtrips():
    rng = np.random.default_rng(1)
    g = _square_graph(rng)
    pg = build_partition(g, 3, "contiguous")
    x = jnp.asarray(rng.normal(size=(g.n_src, 5)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(pg.gather_nodes(pg.scatter_nodes(x))), np.asarray(x))
    e = jnp.asarray(rng.normal(size=(g.n_edges, 2)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(pg.gather_edges(pg.scatter_edges(e))), np.asarray(e))


def test_ring_reference_is_the_bucket_oracle():
    rng = np.random.default_rng(2)
    g = _square_graph(rng)
    x = jnp.asarray(rng.normal(size=(g.n_src, 4)).astype(np.float32))
    ref = gspmm(g, "u_copy_add_v", u=x, strategy="segment")
    for S in (1, 2, 4):
        pg = build_partition(g, S)
        out = pg.gather_nodes(ring_reference(pg, pg.scatter_nodes(x)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_partition_memoized_in_plan_cache():
    rng = np.random.default_rng(3)
    g = _square_graph(rng)
    cache = get_plan_cache(g)
    a = cache.partition(3, "contiguous")
    b = cache.partition(3, "contiguous")
    assert a is b
    assert cache.peek_partition(3, "contiguous") is a
    assert cache.peek_partition(4, "contiguous") is None
    assert cache.partition(3, "hash") is not a


def test_delayed_halo_semantics():
    """refresh=True is exact; refresh=False reuses the stale remote and
    routes gradients through the local part only."""
    rng = np.random.default_rng(4)
    g = _square_graph(rng)
    pg = build_partition(g, 3, "contiguous")
    x = jnp.asarray(rng.normal(size=(g.n_src, 4)).astype(np.float32))
    w = pg.scatter_edges(jnp.ones((g.n_edges,), jnp.float32))
    xp = pg.scatter_nodes(x)
    exact = ring_gspmm(pg, xp, w)
    out, stale = ring_gspmm_delayed(pg, xp, w, jnp.zeros_like(xp), True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               rtol=1e-5, atol=1e-6)
    # local + offdiag decomposition is exact
    np.testing.assert_allclose(
        np.asarray(local_gspmm(pg, xp, w)
                   + ring_gspmm(pg, xp, offdiag_weights(pg, w))),
        np.asarray(exact), rtol=1e-5, atol=1e-6)
    # stale step: output = local(new x) + old remote, and the gradient
    # equals the local-only gradient (remote detached)
    x2 = xp * 2.0
    out2, stale2 = ring_gspmm_delayed(pg, x2, w, stale, False)
    np.testing.assert_allclose(
        np.asarray(out2),
        np.asarray(local_gspmm(pg, x2, w) + stale), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(stale2), np.asarray(stale))
    g_stale = jax.grad(lambda xx: jnp.sum(
        ring_gspmm_delayed(pg, xx, w, stale, False)[0]))(x2)
    g_local = jax.grad(lambda xx: jnp.sum(local_gspmm(pg, xx, w)))(x2)
    np.testing.assert_allclose(np.asarray(g_stale), np.asarray(g_local),
                               rtol=1e-5, atol=1e-6)


def test_bucket_softmax_matches_edge_softmax():
    rng = np.random.default_rng(5)
    g = _square_graph(rng)
    pg = build_partition(g, 3, "hash")
    H = 3
    el = jnp.asarray(rng.normal(size=(g.n_src, H)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(g.n_dst, H)).astype(np.float32))
    logits = gspmm(g, "u_add_v_copy_e", u=el, v=er)
    lb = ring_edge_values(pg, pg.scatter_nodes(el), pg.scatter_nodes(er))
    np.testing.assert_allclose(np.asarray(pg.gather_edges(lb)),
                               np.asarray(logits), rtol=1e-4, atol=1e-5)
    alpha = bucket_softmax(pg, lb)
    np.testing.assert_allclose(np.asarray(pg.gather_edges(alpha)),
                               np.asarray(edge_softmax_fused(g, logits)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mod", [gcn, sage, gat], ids=["gcn", "sage", "gat"])
def test_partitioned_forward_and_grads_match_emulated(mod):
    """The partitioned app forwards (emulated ring) must match the
    standard full-graph forward — outputs and parameter gradients —
    across shard counts. The identical check runs on real emulated
    devices in tests/launch/test_partitioned_train.py."""
    rng = np.random.default_rng(6)
    n, d, nc = 52, 8, 3
    g = _square_graph(rng, n, 320)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, nc, n).astype(np.int32))
    mask = jnp.asarray(rng.random(n) < 0.6)
    bundle = make_bundle(g)
    params = mod.init(jax.random.PRNGKey(0), d, 8, nc)
    ref = mod.forward(params, bundle, x)
    gref = ravel_pytree(jax.grad(lambda p: cross_entropy_loss(
        mod.forward(p, bundle, x), labels, mask))(params))[0]
    for S in (2, 3):
        pb = make_partitioned_bundle(g, S)
        pg = pb.pg
        xp = pg.scatter_nodes(x)
        out, _ = mod.forward_partitioned(params, pb, xp)
        np.testing.assert_allclose(np.asarray(pg.gather_nodes(out)),
                                   np.asarray(ref), rtol=2e-4, atol=2e-4)
        yp = pg.scatter_nodes(labels)
        mp = pg.scatter_nodes(mask)
        gp = ravel_pytree(jax.grad(lambda p: cross_entropy_loss(
            mod.forward_partitioned(p, pb, xp)[0], yp, mp))(params))[0]
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gref),
                                   rtol=2e-4, atol=2e-4)

"""Planner layer: auto strategy selection, fallback, and pack caching.

These tests are hypothesis-free on purpose — they must run on the bare
tier-1 environment (seeded numpy loops instead of property search).
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (from_coo, gspmm, copy_reduce, edge_softmax,
                        parse_op, planner)

# the exact configurations from the paper's Table 2
TABLE2 = [
    "u_copy_add_v",        # GCN/SAGE/GCMC/LGNN/RGCN
    "u_mul_e_add_v",       # MoNet, GAT
    "e_copy_add_v",        # GAT
    "e_copy_max_v",        # GAT
    "u_add_v_copy_e",      # GAT
    "e_sub_v_copy_e",      # GAT
    "e_div_v_copy_e",      # GAT
    "v_mul_e_copy_e",      # GAT
    "u_dot_v_add_e",       # GCMC
]

REDUCERS = ["add", "max", "min", "mul", "mean"]


def _graph(rng, n_u, n_v, nnz):
    src = rng.integers(0, n_u, nnz)
    dst = rng.integers(0, n_v, nnz)
    return from_coo(src, dst, n_src=n_u, n_dst=n_v)


def _operands(rng, n_u, n_v, nnz, d):
    """Values bounded away from 0 so div/prod stay well-conditioned."""
    def draw(shape):
        x = rng.uniform(0.5, 1.5, size=shape).astype(np.float32)
        sgn = np.where(rng.random(shape) < 0.5, -1.0, 1.0).astype(np.float32)
        return jnp.asarray(x * sgn)
    return draw((n_u, d)), draw((n_v, d)), draw((nnz, d))


def _assert_matches_segment(g, name, U, V, E, **kw):
    out = gspmm(g, name, u=U, v=V, e=E, **kw)
    ref = gspmm(g, name, u=U, v=V, e=E, strategy="segment")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_auto_matches_segment_table2(seed):
    """strategy='auto' (the default) is numerically the segment answer
    for every Table-2 config, across random graph shapes."""
    rng = np.random.default_rng(seed)
    n_u, n_v, nnz = [(30, 20, 120), (80, 80, 1200), (200, 150, 3000)][seed]
    g = _graph(rng, n_u, n_v, nnz)
    U, V, E = _operands(rng, n_u, n_v, g.n_edges, 7)
    for name in TABLE2:
        _assert_matches_segment(g, name, U, V, E)   # no strategy argument


def test_auto_matches_segment_all_reducers():
    rng = np.random.default_rng(7)
    g = _graph(rng, 60, 40, 700)
    U, V, E = _operands(rng, 60, 40, g.n_edges, 5)
    for red in REDUCERS:
        _assert_matches_segment(g, f"u_copy_{red}_v", U, V, E)
        _assert_matches_segment(g, f"u_mul_e_{red}_v", U, V, E)
        _assert_matches_segment(g, f"e_copy_{red}_v", U, V, E)


def test_pinned_unsupported_falls_back_not_raises():
    """Pallas/onehot specs they can't run fall back down the chain."""
    rng = np.random.default_rng(3)
    g = _graph(rng, 40, 30, 200)
    U, V, E = _operands(rng, 40, 30, g.n_edges, 6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        # max reducer: no pallas kernel
        _assert_matches_segment(g, "u_copy_max_v", U, V, E,
                                strategy="pallas")
        # dot ⊗: no pallas kernel, no onehot formulation
        _assert_matches_segment(g, "u_dot_v_add_v", U, V, E,
                                strategy="pallas")
        # onehot needs lhs on source nodes
        _assert_matches_segment(g, "e_copy_add_v", U, V, E,
                                strategy="onehot")
        # min reducer via onehot
        _assert_matches_segment(g, "u_copy_min_v", U, V, E,
                                strategy="onehot")
        # ell cannot reduce to source nodes -> generic path
        _assert_matches_segment(g, "v_copy_add_u", U, V, E,
                                strategy="ell")


def test_fallback_warns_once():
    rng = np.random.default_rng(4)
    g = _graph(rng, 25, 25, 100)
    U, _, _ = _operands(rng, 25, 25, g.n_edges, 4)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        gspmm(g, "u_copy_prod_v", u=U, strategy="pallas")
        gspmm(g, "u_copy_prod_v", u=U, strategy="pallas")
    ours = [x for x in w if "falling back" in str(x.message)]
    assert len(ours) <= 1


def test_packs_built_at_most_once_per_graph():
    """Repeated auto calls + direct cache hits build each pack once."""
    rng = np.random.default_rng(5)
    # big enough (and wide enough) that the cost model picks ell
    g = _graph(rng, 1000, 1000, 6000)
    X = jnp.asarray(rng.normal(size=(1000, 64)).astype(np.float32))
    before = planner.pack_build_totals().get("ell", 0)
    for _ in range(3):
        out = copy_reduce(g, X)                       # default: auto
    cache = planner.get_plan_cache(g)
    assert cache.ell() is not None                    # direct hit, no build
    after = planner.pack_build_totals().get("ell", 0)
    assert after - before == 1
    assert planner.last_plan("u_copy_add_v") == "ell"
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(copy_reduce(g, X, strategy="segment")),
        rtol=1e-4, atol=1e-4)


def test_auto_under_jit_with_cache():
    """A bundle-carried PlanCache lets the planner run inside a trace:
    static stats drive the cost model, traced packs feed the kernels."""
    rng = np.random.default_rng(6)
    g = _graph(rng, 800, 800, 5000)
    X = jnp.asarray(rng.normal(size=(800, 64)).astype(np.float32))
    cache = planner.get_plan_cache(g)
    cache.ell()
    f = jax.jit(lambda g, c, x: gspmm(g, "u_copy_add_v", u=x, cache=c))
    out = f(g, cache, X)
    ref = copy_reduce(g, X, strategy="segment")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # traced graph with NO cache: planner degrades to segment, still right
    f2 = jax.jit(lambda g, x: gspmm(g, "u_copy_add_v", u=x))
    np.testing.assert_allclose(np.asarray(f2(g, X)), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_edge_softmax_auto_matches_pinned():
    rng = np.random.default_rng(8)
    g = _graph(rng, 50, 50, 400)
    logits = jnp.asarray(rng.normal(size=(g.n_edges, 4)).astype(np.float32))
    a = edge_softmax(g, logits)                       # auto
    b = edge_softmax(g, logits, strategy="segment")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_autotune_mode_matches_segment():
    rng = np.random.default_rng(9)
    g = _graph(rng, 300, 300, 2500)
    X = jnp.asarray(rng.normal(size=(300, 16)).astype(np.float32))
    ref = copy_reduce(g, X, strategy="segment")
    planner.set_mode("autotune")
    try:
        out1 = copy_reduce(g, X)
        out2 = copy_reduce(g, X)                      # cached decision
    finally:
        planner.set_mode("cost")
    np.testing.assert_allclose(np.asarray(out1), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_block_autotune_measures_once_and_matches_segment():
    """Autotune mode extends to block plans: an eager call measures the
    candidates once per shape signature; the cached winner then serves
    jitted calls of the same configuration."""
    from repro.core import block_gspmm
    from repro.data import NeighborSampler

    rng = np.random.default_rng(11)
    g = _graph(rng, 40, 40, 300)
    sampler = NeighborSampler(g, fanouts=[4], batch_size=8, seed=0)
    mb = sampler.sample(rng.permutation(40)[:8], np.zeros(8, np.int64))
    bg = mb.blocks[0].bg
    u = jnp.asarray(rng.normal(size=(bg.g.n_src, 6)).astype(np.float32))
    ref = block_gspmm(bg, "u_copy_mean_v", u=u, strategy="segment")

    planner.clear_block_plans()
    planner.set_mode("autotune")
    try:
        # a traced call first (the normal training path: planning
        # happens inside the jitted step) must NOT pin its cost-model
        # stand-in — the later eager call still gets to measure
        jitted0 = jax.jit(lambda bg, u: block_gspmm(bg, "u_copy_mean_v",
                                                    u=u))
        np.testing.assert_allclose(np.asarray(jitted0(bg, u)),
                                   np.asarray(ref), rtol=1e-4, atol=1e-5)
        assert not [k for k in planner._BLOCK_PLANS if k[3] == "auto"]
        out = block_gspmm(bg, "u_copy_mean_v", u=u)       # eager: measures
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        chosen = planner.last_plan("block:u_copy_mean_v")
        assert chosen in ("ell", "segment")
        # the measured decision is keyed on the existing shape
        # signature — a second (jitted, traced) call reuses it
        n_before = len(planner._BLOCK_PLANS)
        jitted = jax.jit(lambda bg, u: block_gspmm(bg, "u_copy_mean_v",
                                                   u=u))
        np.testing.assert_allclose(np.asarray(jitted(bg, u)),
                                   np.asarray(ref), rtol=1e-4, atol=1e-5)
        assert len(planner._BLOCK_PLANS) == n_before
        assert planner.last_plan("block:u_copy_mean_v") == chosen
    finally:
        planner.set_mode("cost")
        planner.clear_block_plans()


def test_ring_pinned_falls_back_without_mesh():
    """A pinned 'ring' with no active use_ring() context degrades down
    the single-device chain (blocked pull first) and stays correct."""
    rng = np.random.default_rng(12)
    g = _graph(rng, 30, 30, 150)
    U, V, E = _operands(rng, 30, 30, g.n_edges, 4)
    assert planner.active_ring() is None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        _assert_matches_segment(g, "u_copy_add_v", U, V, E,
                                strategy="ring")
    assert planner.last_plan("u_copy_add_v", "ring") in ("ell", "segment")
    # and auto never picks ring without a mesh
    _assert_matches_segment(g, "u_copy_add_v", U, V, E)
    assert planner.last_plan("u_copy_add_v") != "ring"


def test_stats_and_cost_model_sanity():
    rng = np.random.default_rng(10)
    g = _graph(rng, 100, 100, 900)
    stats = planner.get_plan_cache(g).stats
    assert stats.n_edges == g.n_edges
    assert stats.ell_padded_slots >= stats.n_edges
    assert stats.pad_ratio >= 1.0
    # every strategy costs something, and costs grow with feature width
    for s in planner.STRATEGIES:
        assert planner.estimate_cost(s, stats, 8) > 0
        assert (planner.estimate_cost(s, stats, 128)
                > planner.estimate_cost(s, stats, 8))


def test_supports_predicates():
    spec2 = parse_op("u_mul_e_add_v")
    x = jnp.zeros((4, 3))
    e1 = jnp.zeros((5, 1))
    assert planner.supports("onehot", spec2, x, e1)
    assert planner.supports("pallas", spec2, x, e1)
    # 3-D operands are segment/ell territory
    x3 = jnp.zeros((4, 2, 3))
    e3 = jnp.zeros((5, 2, 1))
    assert planner.supports("ell", spec2, x3, e3)
    assert not planner.supports("onehot", spec2, x3, e3)
    assert not planner.supports("pallas", spec2, x3, e3)
    # max reducer never hits the MXU formulations
    specmax = parse_op("u_copy_max_v")
    assert not planner.supports("pallas", specmax, x, None)
    assert not planner.supports("onehot", specmax, x, None)
    assert planner.supports("ell", specmax, x, None)

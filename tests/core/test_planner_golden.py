"""Golden-plan regression: the block planner's chosen forward
(``block:<op>``) and backward (``block_bwd:<op>``) strategies across the
Table-2 block shape grid, snapshotted.

The cost model is deterministic, so any diff here is a REAL behavior
change of the planner — a deliberate cost-model tweak should update the
snapshot; an accidental one should fail loudly instead of silently
shifting every sampled train step onto a different kernel.

Regenerate after an intentional planner change with:

    PYTHONPATH=src python -c \
        "from tests.core.test_planner_golden import print_golden; \
         print_golden()"

and paste the output over ``GOLDEN``.
"""
import jax
import pytest

from repro.core import parse_op, planner

# (batch, fanout) grid of the Fig. 3 sweep × the block-relevant Table-2
# configs × the feature widths the apps run (hidden/input/wide). The
# 8192×15 row is the products-like outer-block scale (~123k edge
# slots): past the backward cost model's collision crossover, so the
# snapshot pins BOTH sides of the gather-vs-scatter decision.
SHAPES = [(64, 5), (64, 10), (256, 10), (512, 15), (8192, 15)]
OPS = ["u_copy_add_v", "u_copy_mean_v", "u_mul_e_add_v",
       "e_copy_add_v", "e_copy_max_v"]
WIDTHS = [16, 64, 256]

GOLDEN = {
    "b64_f5_u_copy_add_v_d16": "segment+scatter",
    "b64_f5_u_copy_add_v_d64": "segment+scatter",
    "b64_f5_u_copy_add_v_d256": "ell+scatter",
    "b64_f5_u_copy_mean_v_d16": "segment+scatter",
    "b64_f5_u_copy_mean_v_d64": "segment+scatter",
    "b64_f5_u_copy_mean_v_d256": "ell+scatter",
    "b64_f5_u_mul_e_add_v_d16": "segment+scatter",
    "b64_f5_u_mul_e_add_v_d64": "segment+scatter",
    "b64_f5_u_mul_e_add_v_d256": "ell+scatter",
    "b64_f5_e_copy_add_v_d16": "segment+scatter",
    "b64_f5_e_copy_add_v_d64": "segment+scatter",
    "b64_f5_e_copy_add_v_d256": "ell+scatter",
    "b64_f5_e_copy_max_v_d16": "segment+scatter",
    "b64_f5_e_copy_max_v_d64": "segment+scatter",
    "b64_f5_e_copy_max_v_d256": "ell+scatter",
    "b64_f10_u_copy_add_v_d16": "segment+scatter",
    "b64_f10_u_copy_add_v_d64": "ell+scatter",
    "b64_f10_u_copy_add_v_d256": "ell+scatter",
    "b64_f10_u_copy_mean_v_d16": "segment+scatter",
    "b64_f10_u_copy_mean_v_d64": "ell+scatter",
    "b64_f10_u_copy_mean_v_d256": "ell+scatter",
    "b64_f10_u_mul_e_add_v_d16": "segment+scatter",
    "b64_f10_u_mul_e_add_v_d64": "ell+scatter",
    "b64_f10_u_mul_e_add_v_d256": "ell+scatter",
    "b64_f10_e_copy_add_v_d16": "segment+scatter",
    "b64_f10_e_copy_add_v_d64": "ell+scatter",
    "b64_f10_e_copy_add_v_d256": "ell+scatter",
    "b64_f10_e_copy_max_v_d16": "segment+scatter",
    "b64_f10_e_copy_max_v_d64": "ell+scatter",
    "b64_f10_e_copy_max_v_d256": "ell+scatter",
    "b256_f10_u_copy_add_v_d16": "ell+scatter",
    "b256_f10_u_copy_add_v_d64": "ell+scatter",
    "b256_f10_u_copy_add_v_d256": "ell+scatter",
    "b256_f10_u_copy_mean_v_d16": "ell+scatter",
    "b256_f10_u_copy_mean_v_d64": "ell+scatter",
    "b256_f10_u_copy_mean_v_d256": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d16": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d64": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d256": "ell+scatter",
    "b256_f10_e_copy_add_v_d16": "ell+scatter",
    "b256_f10_e_copy_add_v_d64": "ell+scatter",
    "b256_f10_e_copy_add_v_d256": "ell+scatter",
    "b256_f10_e_copy_max_v_d16": "ell+scatter",
    "b256_f10_e_copy_max_v_d64": "ell+scatter",
    "b256_f10_e_copy_max_v_d256": "ell+scatter",
    "b512_f15_u_copy_add_v_d16": "ell+scatter",
    "b512_f15_u_copy_add_v_d64": "ell+scatter",
    "b512_f15_u_copy_add_v_d256": "ell+scatter",
    "b512_f15_u_copy_mean_v_d16": "ell+scatter",
    "b512_f15_u_copy_mean_v_d64": "ell+scatter",
    "b512_f15_u_copy_mean_v_d256": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d16": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d64": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d256": "ell+scatter",
    "b512_f15_e_copy_add_v_d16": "ell+scatter",
    "b512_f15_e_copy_add_v_d64": "ell+scatter",
    "b512_f15_e_copy_add_v_d256": "ell+scatter",
    "b512_f15_e_copy_max_v_d16": "ell+scatter",
    "b512_f15_e_copy_max_v_d64": "ell+scatter",
    "b512_f15_e_copy_max_v_d256": "ell+scatter",
    "b8192_f15_u_copy_add_v_d16": "ell+gather",
    "b8192_f15_u_copy_add_v_d64": "ell+gather",
    "b8192_f15_u_copy_add_v_d256": "ell+gather",
    "b8192_f15_u_copy_mean_v_d16": "ell+gather",
    "b8192_f15_u_copy_mean_v_d64": "ell+gather",
    "b8192_f15_u_copy_mean_v_d256": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d16": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d64": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d256": "ell+gather",
    "b8192_f15_e_copy_add_v_d16": "ell+gather",
    "b8192_f15_e_copy_add_v_d64": "ell+gather",
    "b8192_f15_e_copy_add_v_d256": "ell+gather",
    "b8192_f15_e_copy_max_v_d16": "ell+scatter",
    "b8192_f15_e_copy_max_v_d64": "ell+scatter",
    "b8192_f15_e_copy_max_v_d256": "ell+scatter",
}


def compute_plans() -> dict:
    """``{grid key: "<fwd>+<bwd>"}`` under the cost-model planner."""
    prev = planner.get_mode()
    planner.set_mode("cost")
    planner.clear_block_plans()
    try:
        out = {}
        for batch, fanout in SHAPES:
            sig = (batch * (fanout + 1), batch, batch * fanout, fanout)
            for op in OPS:
                spec = parse_op(op)
                for d in WIDTHS:
                    fwd = planner.plan_block_gspmm(sig, spec, d)
                    bwd = planner.plan_block_vjp(sig, spec, d)
                    out[f"b{batch}_f{fanout}_{op}_d{d}"] = f"{fwd}+{bwd}"
        return out
    finally:
        planner.clear_block_plans()     # drop cost-mode pins
        planner.set_mode(prev)


def print_golden() -> None:             # the regen helper
    print("GOLDEN = {")
    for k, v in compute_plans().items():
        print(f'    "{k}": "{v}",')
    print("}")


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="golden plans snapshotted for the cpu "
                           "throughput table")
def test_block_plans_match_golden():
    plans = compute_plans()
    drift = {k: (GOLDEN.get(k), v) for k, v in plans.items()
             if GOLDEN.get(k) != v}
    assert plans.keys() == GOLDEN.keys() and not drift, (
        f"block plan drift on {len(drift)} grid point(s): "
        f"{dict(list(drift.items())[:8])} — if this cost-model change is "
        f"intentional, regen the snapshot: PYTHONPATH=src python -c "
        f'"from tests.core.test_planner_golden import print_golden; '
        f'print_golden()"')

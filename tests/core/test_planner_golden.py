"""Golden-plan regression: the block planner's chosen forward
(``block:<op>``) and backward (``block_bwd:<op>``) strategies across the
Table-2 block shape grid, snapshotted — plus the edge-output planner's
``sddmm:<op>`` rows and the fused-attention ``attn:fused`` rows over
the shapes the GAT/GCMC/LGNN apps actually plan.

The cost model is deterministic, so any diff here is a REAL behavior
change of the planner — a deliberate cost-model tweak should update the
snapshot; an accidental one should fail loudly instead of silently
shifting every sampled train step onto a different kernel.

Regenerate after an intentional planner change with:

    PYTHONPATH=src python -c \
        "from tests.core.test_planner_golden import print_golden; \
         print_golden()"

and paste the output over ``GOLDEN``.
"""
import dataclasses

import jax
import pytest

from repro.core import parse_op, planner

# (batch, fanout) grid of the Fig. 3 sweep × the block-relevant Table-2
# configs × the feature widths the apps run (hidden/input/wide). The
# 8192×15 row is the products-like outer-block scale (~123k edge
# slots): past the backward cost model's collision crossover, so the
# snapshot pins BOTH sides of the gather-vs-scatter decision.
SHAPES = [(64, 5), (64, 10), (256, 10), (512, 15), (8192, 15)]
OPS = ["u_copy_add_v", "u_copy_mean_v", "u_mul_e_add_v",
       "e_copy_add_v", "e_copy_max_v"]
WIDTHS = [16, 64, 256]

GOLDEN = {
    "b64_f5_u_copy_add_v_d16": "segment+scatter",
    "b64_f5_u_copy_add_v_d64": "segment+scatter",
    "b64_f5_u_copy_add_v_d256": "ell+scatter",
    "b64_f5_u_copy_mean_v_d16": "segment+scatter",
    "b64_f5_u_copy_mean_v_d64": "segment+scatter",
    "b64_f5_u_copy_mean_v_d256": "ell+scatter",
    "b64_f5_u_mul_e_add_v_d16": "segment+scatter",
    "b64_f5_u_mul_e_add_v_d64": "segment+scatter",
    "b64_f5_u_mul_e_add_v_d256": "ell+scatter",
    "b64_f5_e_copy_add_v_d16": "segment+scatter",
    "b64_f5_e_copy_add_v_d64": "segment+scatter",
    "b64_f5_e_copy_add_v_d256": "ell+scatter",
    "b64_f5_e_copy_max_v_d16": "segment+scatter",
    "b64_f5_e_copy_max_v_d64": "segment+scatter",
    "b64_f5_e_copy_max_v_d256": "ell+scatter",
    "b64_f10_u_copy_add_v_d16": "segment+scatter",
    "b64_f10_u_copy_add_v_d64": "ell+scatter",
    "b64_f10_u_copy_add_v_d256": "ell+scatter",
    "b64_f10_u_copy_mean_v_d16": "segment+scatter",
    "b64_f10_u_copy_mean_v_d64": "ell+scatter",
    "b64_f10_u_copy_mean_v_d256": "ell+scatter",
    "b64_f10_u_mul_e_add_v_d16": "segment+scatter",
    "b64_f10_u_mul_e_add_v_d64": "ell+scatter",
    "b64_f10_u_mul_e_add_v_d256": "ell+scatter",
    "b64_f10_e_copy_add_v_d16": "segment+scatter",
    "b64_f10_e_copy_add_v_d64": "ell+scatter",
    "b64_f10_e_copy_add_v_d256": "ell+scatter",
    "b64_f10_e_copy_max_v_d16": "segment+scatter",
    "b64_f10_e_copy_max_v_d64": "ell+scatter",
    "b64_f10_e_copy_max_v_d256": "ell+scatter",
    "b256_f10_u_copy_add_v_d16": "ell+scatter",
    "b256_f10_u_copy_add_v_d64": "ell+scatter",
    "b256_f10_u_copy_add_v_d256": "ell+scatter",
    "b256_f10_u_copy_mean_v_d16": "ell+scatter",
    "b256_f10_u_copy_mean_v_d64": "ell+scatter",
    "b256_f10_u_copy_mean_v_d256": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d16": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d64": "ell+scatter",
    "b256_f10_u_mul_e_add_v_d256": "ell+scatter",
    "b256_f10_e_copy_add_v_d16": "ell+scatter",
    "b256_f10_e_copy_add_v_d64": "ell+scatter",
    "b256_f10_e_copy_add_v_d256": "ell+scatter",
    "b256_f10_e_copy_max_v_d16": "ell+scatter",
    "b256_f10_e_copy_max_v_d64": "ell+scatter",
    "b256_f10_e_copy_max_v_d256": "ell+scatter",
    "b512_f15_u_copy_add_v_d16": "ell+scatter",
    "b512_f15_u_copy_add_v_d64": "ell+scatter",
    "b512_f15_u_copy_add_v_d256": "ell+scatter",
    "b512_f15_u_copy_mean_v_d16": "ell+scatter",
    "b512_f15_u_copy_mean_v_d64": "ell+scatter",
    "b512_f15_u_copy_mean_v_d256": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d16": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d64": "ell+scatter",
    "b512_f15_u_mul_e_add_v_d256": "ell+scatter",
    "b512_f15_e_copy_add_v_d16": "ell+scatter",
    "b512_f15_e_copy_add_v_d64": "ell+scatter",
    "b512_f15_e_copy_add_v_d256": "ell+scatter",
    "b512_f15_e_copy_max_v_d16": "ell+scatter",
    "b512_f15_e_copy_max_v_d64": "ell+scatter",
    "b512_f15_e_copy_max_v_d256": "ell+scatter",
    "b8192_f15_u_copy_add_v_d16": "ell+gather",
    "b8192_f15_u_copy_add_v_d64": "ell+gather",
    "b8192_f15_u_copy_add_v_d256": "ell+gather",
    "b8192_f15_u_copy_mean_v_d16": "ell+gather",
    "b8192_f15_u_copy_mean_v_d64": "ell+gather",
    "b8192_f15_u_copy_mean_v_d256": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d16": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d64": "ell+gather",
    "b8192_f15_u_mul_e_add_v_d256": "ell+gather",
    "b8192_f15_e_copy_add_v_d16": "ell+gather",
    "b8192_f15_e_copy_add_v_d64": "ell+gather",
    "b8192_f15_e_copy_add_v_d256": "ell+gather",
    "b8192_f15_e_copy_max_v_d16": "ell+gather",
    "b8192_f15_e_copy_max_v_d64": "ell+gather",
    "b8192_f15_e_copy_max_v_d256": "ell+gather",
}


def compute_plans() -> dict:
    """``{grid key: "<fwd>+<bwd>"}`` under the cost-model planner."""
    prev = planner.get_mode()
    planner.set_mode("cost")
    planner.clear_block_plans()
    try:
        out = {}
        for batch, fanout in SHAPES:
            sig = (batch * (fanout + 1), batch, batch * fanout, fanout)
            for op in OPS:
                spec = parse_op(op)
                for d in WIDTHS:
                    fwd = planner.plan_block_gspmm(sig, spec, d)
                    bwd = planner.plan_block_vjp(sig, spec, d)
                    out[f"b{batch}_f{fanout}_{op}_d{d}"] = f"{fwd}+{bwd}"
        return out
    finally:
        planner.clear_block_plans()     # drop cost-mode pins
        planner.set_mode(prev)


def print_golden() -> None:             # the regen helper
    print("GOLDEN = {")
    for k, v in compute_plans().items():
        print(f'    "{k}": "{v}",')
    print("}")


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="golden plans snapshotted for the cpu "
                           "throughput table")
def test_block_plans_match_golden():
    plans = compute_plans()
    drift = {k: (GOLDEN.get(k), v) for k, v in plans.items()
             if GOLDEN.get(k) != v}
    assert plans.keys() == GOLDEN.keys() and not drift, (
        f"block plan drift on {len(drift)} grid point(s): "
        f"{dict(list(drift.items())[:8])} — if this cost-model change is "
        f"intentional, regen the snapshot: PYTHONPATH=src python -c "
        f'"from tests.core.test_planner_golden import print_golden; '
        f'print_golden()"')


# --------------------------------------------------------------------- #
# edge-output (sddmm:<op>) + fused-attention (attn:fused) golden rows
# --------------------------------------------------------------------- #
# Size grid: cora-scale and the products-like outer-block edge count.
# Each op is planned with pallas-qualifying operands (rank-2 float) and
# with a pallas-disqualifying 3-D operand stream, pinning BOTH sides of
# the support predicate; widths cover the scalar-logit and hidden cases.
SDDMM_SHAPES = [(2708, 2708, 10556), (131072, 8192, 122880)]
SDDMM_OPS = ["u_add_v_copy_e", "u_dot_v_copy_e", "u_mul_e_copy_e"]
ATTN_SHAPES = [(2708, 2708, 10556, 4, 16), (19717, 19717, 88651, 8, 8)]
# power-law (R-MAT 2^15 / 180k-edge) degree-tail rows: same shape, two
# slot estimates — the ragged per-class count (~1.4× E, what the
# PlanCache's ragged pack actually costs) must route auto onto the
# pallas megakernel, while the row-complete max-width envelope (~38× E,
# the pre-ragged accounting) must still veto it. Pins the tentpole
# planner behavior on BOTH sides of the per-class slot formula.
ATTN_POWERLAW = [
    ("E180000_h4_f16_ragged", (32768, 32768, 180000), 4, 16, 247_000),
    ("E180000_h4_f16_rowcomplete", (32768, 32768, 180000), 4, 16,
     6_850_000),
]

SDDMM_GOLDEN = {
    "E10556_u_add_v_copy_e_d1": "gather",
    "E10556_u_add_v_copy_e_d1_nopallas": "gather",
    "E10556_u_add_v_copy_e_d16": "gather",
    "E10556_u_add_v_copy_e_d16_nopallas": "gather",
    "E10556_u_dot_v_copy_e_d1": "gather",
    "E10556_u_dot_v_copy_e_d1_nopallas": "gather",
    "E10556_u_dot_v_copy_e_d16": "gather",
    "E10556_u_dot_v_copy_e_d16_nopallas": "gather",
    "E10556_u_mul_e_copy_e_d1": "gather",
    "E10556_u_mul_e_copy_e_d1_nopallas": "gather",
    "E10556_u_mul_e_copy_e_d16": "gather",
    "E10556_u_mul_e_copy_e_d16_nopallas": "gather",
    "E122880_u_add_v_copy_e_d1": "gather",
    "E122880_u_add_v_copy_e_d1_nopallas": "gather",
    "E122880_u_add_v_copy_e_d16": "gather",
    "E122880_u_add_v_copy_e_d16_nopallas": "gather",
    "E122880_u_dot_v_copy_e_d1": "gather",
    "E122880_u_dot_v_copy_e_d1_nopallas": "gather",
    "E122880_u_dot_v_copy_e_d16": "gather",
    "E122880_u_dot_v_copy_e_d16_nopallas": "gather",
    "E122880_u_mul_e_copy_e_d1": "gather",
    "E122880_u_mul_e_copy_e_d1_nopallas": "gather",
    "E122880_u_mul_e_copy_e_d16": "gather",
    "E122880_u_mul_e_copy_e_d16_nopallas": "gather",
}

ATTN_GOLDEN = {
    "E10556_h4_f16": "fused",
    "E10556_h4_f16_pack": "fused",
    "E88651_h8_f8": "fused",
    "E88651_h8_f8_pack": "fused",
    "E180000_h4_f16_ragged": "pallas",
    "E180000_h4_f16_rowcomplete": "fused",
}


def compute_sddmm_plans() -> dict:
    import jax.numpy as jnp

    prev = planner.get_mode()
    planner.set_mode("cost")
    planner.clear_sddmm_plans()
    try:
        out = {}
        for sig in SDDMM_SHAPES:
            for op in SDDMM_OPS:
                spec = parse_op(op)
                for d in (1, 16):
                    lhs = jnp.zeros((1, d), jnp.float32)
                    rhs = (None if spec.rhs is None
                           else jnp.zeros((1, d), jnp.float32))
                    out[f"E{sig[2]}_{op}_d{d}"] = planner.plan_sddmm(
                        sig, spec, d, lhs_data=lhs, rhs_data=rhs)
                    # 3-D streams disqualify the tiled kernel
                    lhs3 = jnp.zeros((1, 2, d), jnp.float32)
                    out[f"E{sig[2]}_{op}_d{d}_nopallas"] = \
                        planner.plan_sddmm(sig, spec, d, lhs_data=lhs3,
                                           rhs_data=rhs)
        for n_src, n_dst, n_edges, h, f in ATTN_SHAPES:
            sig = (n_src, n_dst, n_edges)
            out[f"E{n_edges}_h{h}_f{f}"] = planner.plan_attention(
                sig, h, f, pallas_ok=False)
            out[f"E{n_edges}_h{h}_f{f}_pack"] = planner.plan_attention(
                sig, h, f, pallas_ok=True, padded_slots=n_edges * 4)
        for key, sig, h, f, slots in ATTN_POWERLAW:
            out[key] = planner.plan_attention(sig, h, f, pallas_ok=True,
                                              padded_slots=slots)
        return out
    finally:
        planner.clear_sddmm_plans()
        planner.set_mode(prev)


def print_sddmm_golden() -> None:       # the regen helper
    plans = compute_sddmm_plans()
    print("SDDMM_GOLDEN = {")
    for k, v in plans.items():
        if "_h" not in k:
            print(f'    "{k}": "{v}",')
    print("}")
    print("ATTN_GOLDEN = {")
    for k, v in plans.items():
        if "_h" in k:
            print(f'    "{k}": "{v}",')
    print("}")


def test_ring_cost_prices_ragged_buckets():
    """The ring estimate must charge the ragged diagonal schedule, not
    the dense S²·eb envelope: skewed buckets lower the slot-work term,
    trailing all-empty diagonals lower the comm term, and hand-built
    stats without ragged fields (the defaults) fall back to dense
    accounting exactly."""
    from repro.core.partition import PartitionStats
    from repro.core.planner import GraphStats, estimate_cost

    gs = GraphStats(n_src=4096, n_dst=4096, n_edges=60_000,
                    avg_in_deg=14.6, max_in_deg=512, skew=35.0,
                    ell_padded_slots=120_000, ell_n_classes=4,
                    pad_ratio=2.0)
    S, eb = 8, 8_000
    dense = PartitionStats(n_shards=S, rows_per_shard=512, eb=eb,
                           n_edges=60_000, cut_fraction=0.5,
                           pad_ratio=S * S * eb / 60_000, balance=1.1)
    ragged = dataclasses.replace(dense, ragged_slots=S * 8 * 2_000,
                                 ragged_stages=S - 1)
    truncated = dataclasses.replace(ragged, ragged_stages=S - 3)
    c_dense = estimate_cost("ring", gs, 16, backend="cpu",
                            ring_stats=dense)
    c_ragged = estimate_cost("ring", gs, 16, backend="cpu",
                             ring_stats=ragged)
    c_trunc = estimate_cost("ring", gs, 16, backend="cpu",
                            ring_stats=truncated)
    assert c_ragged < c_dense          # skewed buckets → less slot work
    assert c_trunc < c_ragged          # empty diagonals → less traffic
    # the dense fallback (ragged_slots=0, ragged_stages=-1) must price
    # identically to explicit dense-equivalent ragged fields
    explicit = dataclasses.replace(dense, ragged_slots=S * S * eb,
                                   ragged_stages=S - 1)
    assert estimate_cost("ring", gs, 16, backend="cpu",
                         ring_stats=explicit) == c_dense


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="golden plans snapshotted for the cpu "
                           "throughput table")
def test_sddmm_and_attention_plans_match_golden():
    plans = compute_sddmm_plans()
    golden = {**SDDMM_GOLDEN, **ATTN_GOLDEN}
    drift = {k: (golden.get(k), v) for k, v in plans.items()
             if golden.get(k) != v}
    assert plans.keys() == golden.keys() and not drift, (
        f"sddmm/attn plan drift on {len(drift)} grid point(s): "
        f"{dict(list(drift.items())[:8])} — regen with "
        f'print_sddmm_golden() if intentional')

"""Property tests for the serving-tier hot-node cache (DESIGN.md §10).

The cache's accounting is pinned against a brute-force oracle: a plain
dict replaying the same lookup/update trace. Hypothesis drives the
traces when installed; the same properties run over seeded random
traces otherwise (the tier-1 environment has no hypothesis), so these
tests never silently skip.
"""
import numpy as np
import pytest

from repro.core.serving import CacheStats, FeatureCache, hot_node_ids

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_ROWS, DIM = 32, 3


class OracleCache:
    """Reference LRU-with-pinned-set: a dict and a recency list, no
    cleverness — exactly the accounting FeatureCache must reproduce."""

    def __init__(self, store, capacity, pinned):
        self.store = store
        self.capacity = capacity
        self.pinned = set(int(i) for i in pinned)
        self.order = []                    # LRU order, oldest first
        self.hits = self.misses = self.evictions = self.pinned_hits = 0

    def lookup(self, ids):
        for i in ids:
            i = int(i)
            if i in self.pinned:
                self.hits += 1
                self.pinned_hits += 1
            elif i in self.order:
                self.hits += 1
                self.order.remove(i)
                self.order.append(i)
            else:
                self.misses += 1
                if self.capacity > 0:
                    self.order.append(i)
                    if len(self.order) > self.capacity:
                        self.order.pop(0)
                        self.evictions += 1


def random_trace(rng, n_ops=60):
    """A mixed lookup/update trace over a skewed id distribution (so
    hits, misses, AND evictions all actually occur)."""
    ops = []
    for _ in range(n_ops):
        if rng.random() < 0.8:
            k = int(rng.integers(1, 6))
            # zipf-ish skew: half the traffic on the first few rows
            hot = rng.integers(0, 4, k)
            cold = rng.integers(0, N_ROWS, k)
            ids = np.where(rng.random(k) < 0.5, hot, cold)
            ops.append(("lookup", ids))
        else:
            ids = rng.integers(0, N_ROWS, int(rng.integers(1, 4)))
            ops.append(("update", ids))
    return ops


def replay(ops, capacity, n_pinned):
    store = np.arange(N_ROWS * DIM, dtype=np.float32).reshape(N_ROWS, DIM)
    pinned = np.arange(n_pinned)
    cache = FeatureCache(store.copy(), capacity, pinned=pinned)
    oracle = OracleCache(store.copy(), capacity, pinned)
    bump = 0.0
    for kind, ids in ops:
        if kind == "lookup":
            got = cache.lookup(ids)
            oracle.lookup(ids)
            # served values always equal the CURRENT store rows
            np.testing.assert_array_equal(got, oracle.store[ids])
        else:
            bump += 1.0
            rows = oracle.store[ids] + bump
            cache.update(ids, rows)
            oracle.store[ids] = rows
    return cache, oracle


def assert_matches_oracle(cache, oracle):
    s = cache.stats()
    assert (s.hits, s.misses, s.evictions, s.pinned_hits) == (
        oracle.hits, oracle.misses, oracle.evictions, oracle.pinned_hits)
    assert s.size == len(oracle.order)
    # same resident set, same LRU order ⇒ identical future behavior
    assert list(cache._lru) == oracle.order
    assert s.hit_ratio == pytest.approx(
        oracle.hits / max(oracle.hits + oracle.misses, 1))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("capacity,n_pinned", [(4, 0), (4, 3), (0, 2),
                                               (100, 5)])
def test_accounting_matches_oracle_seeded(seed, capacity, n_pinned):
    rng = np.random.default_rng(seed)
    cache, oracle = replay(random_trace(rng), capacity, n_pinned)
    assert_matches_oracle(cache, oracle)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.integers(0, 6),
           st.integers(0, 5))
    @settings(max_examples=40, deadline=None)
    def test_accounting_matches_oracle_hypothesis(seed, capacity, n_pinned):
        rng = np.random.default_rng(seed)
        cache, oracle = replay(random_trace(rng), capacity, n_pinned)
        assert_matches_oracle(cache, oracle)


def test_lru_eviction_order_exact():
    store = np.eye(8, dtype=np.float32)
    c = FeatureCache(store, capacity=3)
    c.lookup([0, 1, 2])          # resident: 0,1,2 (0 oldest)
    c.lookup([0])                # refreshes 0 → 1 is now oldest
    c.lookup([3])                # evicts 1
    assert c.evictions == 1
    assert list(c._lru) == [2, 0, 3]
    c.lookup([1])                # 1 is a miss again, evicts 2
    assert c.misses == 5 and c.evictions == 2
    assert list(c._lru) == [0, 3, 1]


def test_update_never_serves_stale_rows():
    store = np.zeros((6, 2), np.float32)
    c = FeatureCache(store, capacity=4, pinned=[0])
    c.lookup([0, 1, 2])          # 0 pinned-resident, 1/2 LRU-resident
    c.update([0, 1, 5], np.ones((3, 2), np.float32))
    got = c.lookup([0, 1, 5, 2])
    np.testing.assert_array_equal(got[0], [1, 1])    # pinned refreshed
    np.testing.assert_array_equal(got[1], [1, 1])    # resident refreshed
    np.testing.assert_array_equal(got[2], [1, 1])    # non-resident
    np.testing.assert_array_equal(got[3], [0, 0])    # untouched row
    # explicit invalidation also re-reads the store
    c.invalidate()
    assert c.stats().size == 0
    np.testing.assert_array_equal(c.lookup([1])[0], [1, 1])


def test_replace_store_refreshes_residents():
    c = FeatureCache(np.zeros((4, 2), np.float32), capacity=2, pinned=[3])
    c.lookup([1, 3])
    c.replace_store(np.full((4, 2), 7, np.float32))
    hits_before = c.hits
    got = c.lookup([1, 3])
    np.testing.assert_array_equal(got, np.full((2, 2), 7, np.float32))
    assert c.hits == hits_before + 2     # still resident — refresh, not drop


def test_pinned_set_never_evicted():
    rng = np.random.default_rng(0)
    store = rng.standard_normal((N_ROWS, DIM)).astype(np.float32)
    pinned = [0, 7, 13]
    c = FeatureCache(store, capacity=2, pinned=pinned)
    for _ in range(50):
        c.lookup(rng.integers(0, N_ROWS, 5))
        for p in pinned:
            assert c.resident(p)
    assert c.stats().pinned == 3
    # pinned traffic never counts as misses after construction
    h0 = c.pinned_hits
    c.lookup(pinned * 3)
    assert c.pinned_hits == h0 + 9 and c.stats().pinned == 3


def test_duplicate_ids_hit_on_second_occurrence():
    c = FeatureCache(np.eye(4, dtype=np.float32), capacity=2)
    c.lookup([2, 2, 2])
    assert c.misses == 1 and c.hits == 2


def test_hot_node_ids_degree_ordered():
    deg = np.array([5, 9, 1, 9, 0])
    np.testing.assert_array_equal(hot_node_ids(deg, 3), [1, 3, 0])
    assert hot_node_ids(deg, 0).size == 0
    assert hot_node_ids(deg, 99).shape == (5,)


def test_stats_is_a_pytree():
    import jax
    s = CacheStats(hits=3, misses=1, size=2, capacity=4)
    leaves = jax.tree_util.tree_leaves(s)
    assert 3 in leaves and s.hit_ratio == 0.75
    doubled = jax.tree_util.tree_map(lambda x: x * 2, s)
    assert doubled.hits == 6

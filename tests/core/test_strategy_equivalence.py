"""Cross-strategy differential harness.

For random graphs, every execution strategy that *supports* a
(op, reducer) node-output config must produce (a) the same gspmm output
and (b) the same VJPs w.r.t. every differentiable operand as the
segment reference. This is the contract that lets the planner swap
strategies freely inside differentiated train steps — including the
pallas kernels, whose adjoint is the segment path by construction
(``core.binary_reduce._gspmm_pallas_diff``), and the ring strategy,
whose emulated single-device path (same bucket math, same
transposed-ring custom VJP as the multi-device form) joins the harness
here so the partitioned subsystem is held to the identical differential
contract as the other five strategies.

The BLOCK harness (:func:`check_block_vjps`) holds the sampled-minibatch
path to the same contract: every block strategy (push/segment/ell) ×
reducer × backward path (the reverse-table gather VJP AND the autodiff
scatter) must match the segment-path adjoint on outputs and cotangents,
on blocks that contain pad rows and a fully-padded degree-0 destination.

The SDDMM harness (:func:`check_gsddmm`) holds the edge-output lattice
to the same contract: every ``gsddmm`` strategy (canonical/gather/
pallas) × edge-output op must match a caller-order composition oracle
on outputs AND VJPs, including 1-D operand widening, isolated
(zero-degree) nodes, and the pad edges block graphs carry
(:func:`test_gsddmm_block_pad_edges`).

The HETERO harness (:func:`check_hetero`) holds the relation-fused path
(DESIGN.md §8) to the same contract: ``hetero_gspmm`` — every strategy
(fused/loop/ell) × reducer (sum/mean/max) × operand form (relation
weights W, basis decomposition, per-relation 3-D features + edge
weights) — must match the per-relation ``gspmm`` loop reference on
outputs AND VJPs, over skewed relation partitions that include an empty
relation.

Graphs come from the shared generator in ``tests.graphgen`` (unique
edges: parallel duplicate edges tie max/min subgradients, which
strategies may legitimately break differently). The checks run twice:
hypothesis-generated graphs when hypothesis is installed, and a seeded
fallback sweep that always runs on the bare tier-1 environment.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (BINARY_OPS, block_gspmm, from_coo, from_rels,
                        gsddmm, gspmm, hetero_gspmm, parse_op, planner)
from repro.core.partition import build_partition, ring_gspmm
from tests.graphgen import random_graph

try:
    from hypothesis import given, settings
    from tests.graphgen import graphs
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

STRATEGIES = ("push", "ell", "onehot", "pallas")   # vs segment reference

# node-output templates × reducers; {} is filled with the reducer name
OP_TEMPLATES = ("u_copy_{}_v", "u_mul_e_{}_v", "e_copy_{}_v",
                "u_add_v_{}_v", "u_dot_v_{}_v")
REDUCERS = ("add", "max", "min", "mul", "mean")


def _operands(rng, g, d=5):
    """Well-conditioned operands: bounded away from 0 (div/prod), edge
    data scalar-width so the MXU strategies qualify."""
    def draw(shape):
        x = rng.uniform(0.5, 1.5, size=shape).astype(np.float32)
        sgn = np.where(rng.random(shape) < 0.5, -1.0,
                       1.0).astype(np.float32)
        return jnp.asarray(x * sgn)
    return {"u": draw((g.n_src, d)), "v": draw((g.n_dst, d)),
            "e": draw((g.n_edges, 1))}


def _value_and_grads(g, name, spec, operands, ct, strategy):
    """gspmm output + VJPs w.r.t. the spec's present operands."""
    keys = [spec.lhs] + ([spec.rhs] if spec.rhs else [])
    args = {k: operands[k] for k in keys}

    def f(a):
        return jnp.sum(gspmm(g, name, **a, strategy=strategy) * ct)

    val = gspmm(g, name, **args, strategy=strategy)
    grads = jax.grad(f)(args)
    return val, grads


def check_all_strategies(src, dst, n_u, n_v, rng):
    """The differential property proper (shared by both entry points)."""
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v)
    operands = _operands(rng, g)
    ct = jnp.asarray(rng.normal(size=(g.n_dst, 5)).astype(np.float32))

    for template in OP_TEMPLATES:
        for red in REDUCERS:
            name = template.format(red)
            spec = parse_op(name)
            lhs = operands[spec.lhs]
            rhs = operands[spec.rhs] if spec.rhs else None
            ct_d = ct[:, :1] if spec.op == "dot" else ct
            # jax implements no scatter/segment-prod transpose for
            # duplicate indices — the prod reducer is forward-only for
            # EVERY strategy, so its differential check is output-only
            diff = red != "mul"
            args = {k: operands[k]
                    for k in [spec.lhs] + ([spec.rhs] if spec.rhs else [])}
            if diff:
                ref, ref_g = _value_and_grads(g, name, spec, operands,
                                              ct_d, "segment")
            else:
                ref = gspmm(g, name, **args, strategy="segment")
            for s in STRATEGIES:
                if not planner.supports(s, spec, lhs, rhs):
                    continue   # pinned call would fall back, not execute
                tag = f"{name} via {s}"
                if diff:
                    out, out_g = _value_and_grads(g, name, spec, operands,
                                                  ct_d, s)
                    for k in ref_g:
                        np.testing.assert_allclose(
                            np.asarray(out_g[k]), np.asarray(ref_g[k]),
                            rtol=1e-4, atol=1e-4,
                            err_msg=f"d/d{k}: {tag}")
                else:
                    out = gspmm(g, name, **args, strategy=s)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(ref), rtol=1e-4,
                    atol=1e-4, err_msg=f"output: {tag}")


SDDMM_STRATEGIES = ("canonical", "gather", "pallas")
# edge-output configs: the attention logits (u_add_v), the softmax
# chain's shift/divide shapes (e_sub_v, e_div_v), GCMC's bilinear
# decode (u_dot_v, both reduce spellings), weighting (u_mul_e) and the
# degenerate copies
SDDMM_OPS = ("u_add_v_copy_e", "u_sub_v_copy_e", "u_mul_v_copy_e",
             "u_div_v_copy_e", "u_dot_v_copy_e", "u_dot_v_add_e",
             "e_sub_v_copy_e", "e_div_v_copy_e", "u_mul_e_copy_e",
             "u_copy_copy_e", "e_copy_copy_e")


def _sddmm_reference(g, spec, args):
    """Caller-order composition oracle: plain gathers + the ⊗ table."""
    src_c = jnp.take(g.src, g.eid_inv)
    dst_c = jnp.take(g.dst, g.eid_inv)

    def fetch(t):
        d = args[t]
        d = d if d.ndim >= 2 else d[:, None]
        if t == "u":
            return jnp.take(d, src_c, axis=0)
        if t == "v":
            return jnp.take(d, dst_c, axis=0)
        return d

    lhs = fetch(spec.lhs)
    if spec.rhs is None:
        return lhs
    return BINARY_OPS[spec.op](lhs, fetch(spec.rhs))


def check_gsddmm(src, dst, n_u, n_v, rng):
    """Every SDDMM strategy × edge-output op must match the caller-order
    composition oracle on outputs AND VJPs w.r.t. every operand. The
    graph gets one extra isolated node on each side (zero-degree rows
    ride through the canonical permutes), and the 1-D operand form must
    widen to the oracle's (nnz, 1)."""
    g = from_coo(src, dst, n_src=n_u + 1, n_dst=n_v + 1)
    operands = _operands(rng, g)

    for name in SDDMM_OPS:
        spec = parse_op(name)
        keys = [spec.lhs] + ([spec.rhs] if spec.rhs else [])
        args = {k: operands[k] for k in keys}
        ref = _sddmm_reference(g, spec, args)
        ct = jnp.asarray(rng.normal(size=ref.shape).astype(np.float32))

        def ref_loss(a):
            return jnp.sum(_sddmm_reference(g, spec, a) * ct)

        ref_g = jax.grad(ref_loss)(args)
        for s in SDDMM_STRATEGIES:
            if not planner.sddmm_supports(s, spec, args[spec.lhs],
                                          args.get(spec.rhs)):
                continue
            tag = f"{name} via {s}"
            kw = {k: args[k] for k in keys}
            out = gsddmm(g, name, **kw, strategy=s)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"output: {tag}")

            def loss(a):
                return jnp.sum(gsddmm(g, name, **a, strategy=s) * ct)

            out_g = jax.grad(loss)(args)
            for k in ref_g:
                np.testing.assert_allclose(
                    np.asarray(out_g[k]), np.asarray(ref_g[k]),
                    rtol=1e-4, atol=1e-4, err_msg=f"d/d{k}: {tag}")

    # 1-D logits (the GAT single-head form): widened to (nnz, 1)
    u1 = operands["u"][:, 0]
    v1 = operands["v"][:, 0]
    ref1 = _sddmm_reference(g, parse_op("u_add_v_copy_e"),
                            {"u": u1, "v": v1})
    for s in SDDMM_STRATEGIES:
        out = gsddmm(g, "u_add_v_copy_e", u=u1, v=v1, strategy=s)
        assert out.shape == (g.n_edges, 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref1),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"1-D logits via {s}")


BLOCK_STRATEGIES = ("push", "segment", "ell")
BLOCK_TEMPLATES = ("u_copy_{}_v", "u_mul_e_{}_v", "e_copy_{}_v",
                   "u_add_v_{}_v")


def check_block_vjps(src, dst, n_u, n_v, rng):
    """Every block strategy × reducer × BACKWARD path must match the
    segment-path adjoint (segment forward + autodiff scatter) on outputs
    AND cotangents. The sampled block deliberately contains pad rows
    (destinations under fanout) and one appended degree-0 destination
    whose row is ALL pad slots."""
    from repro.data import NeighborSampler

    # extra isolated destination: no in-edges anywhere in the graph
    g = from_coo(src, dst, n_src=n_u, n_dst=n_v + 1)
    maxdeg = int(np.asarray(g.in_degrees).max())
    fanout = max(2, maxdeg // 2)
    batch = min(6, g.n_dst)
    sampler = NeighborSampler(g, fanouts=[fanout], batch_size=batch,
                              seed=0)
    seeds = np.concatenate([[n_v], rng.permutation(n_v)[: batch - 1]])
    mb = sampler.sample(seeds, np.zeros(len(seeds), np.int64))
    bg = mb.blocks[0].bg
    assert int(np.asarray(bg.real_deg)[0]) == 0   # degree-0 dst in batch
    assert bg.has_reverse                         # sampler emits the table

    d = 4
    operands = {
        "u": jnp.asarray(rng.normal(size=(bg.g.n_src, d))
                         .astype(np.float32)),
        "v": jnp.asarray(rng.normal(size=(bg.g.n_dst, d))
                         .astype(np.float32)),
        "e": jnp.asarray(rng.uniform(0.5, 1.5, size=(bg.g.n_edges, 1))
                         .astype(np.float32)),
    }

    def value_and_grads(name, args, ct, strategy, bwd):
        def f(a):
            return jnp.sum(block_gspmm(bg, name, **a, strategy=strategy,
                                       bwd_strategy=bwd) * ct)

        val = block_gspmm(bg, name, **args, strategy=strategy,
                          bwd_strategy=bwd)
        return val, jax.grad(f)(args)

    for template in BLOCK_TEMPLATES:
        for red in REDUCERS:
            name = template.format(red)
            spec = parse_op(name)
            keys = [spec.lhs] + ([spec.rhs] if spec.rhs else [])
            args = {k: operands[k] for k in keys}
            out_w = 1 if spec.lhs == "e" and spec.rhs is None else d
            ct = jnp.asarray(rng.normal(size=(bg.n_dst_real, out_w))
                             .astype(np.float32))
            # prod: no scatter/segment-prod transpose in jax —
            # forward-only for every strategy (same caveat as full-graph)
            diff = red != "mul"
            # the gather VJP serves the linear reducers AND — via the
            # recorded arg-extrema table — max/min; prod stays on the
            # autodiff scatter (block_bwd_supports)
            bwds = (("gather", "scatter")
                    if diff and red in ("add", "mean", "max", "min")
                    else ("scatter",))
            if diff:
                ref, ref_g = value_and_grads(name, args, ct, "segment",
                                             "scatter")
            else:
                ref = block_gspmm(bg, name, **args, strategy="segment")
            for s in BLOCK_STRATEGIES:
                for bwd in bwds:
                    tag = f"{name} via {s}+{bwd}"
                    if diff:
                        out, out_g = value_and_grads(name, args, ct, s,
                                                     bwd)
                        for k in ref_g:
                            np.testing.assert_allclose(
                                np.asarray(out_g[k]),
                                np.asarray(ref_g[k]),
                                rtol=1e-4, atol=1e-4,
                                err_msg=f"d/d{k}: {tag}")
                    else:
                        out = block_gspmm(bg, name, **args, strategy=s)
                    np.testing.assert_allclose(
                        np.asarray(out), np.asarray(ref), rtol=1e-4,
                        atol=1e-4, err_msg=f"output: {tag}")


def check_ring_strategy(src, dst, n_u, n_v, rng):
    """The emulated ring — same bucket math + custom VJP as the
    multi-device path — must match segment outputs AND VJPs for every
    ring-supported config, across shard counts and partition modes.
    Ring shards one vertex space, so the graph is squared to
    max(n_u, n_v)."""
    n = max(n_u, n_v)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    d = 4
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    e = jnp.asarray(rng.uniform(0.5, 1.5,
                                size=(g.n_edges,)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    deg = jnp.maximum(g.in_degrees, 1).astype(jnp.float32)
    inv_deg_caller = 1.0 / jnp.take(deg, jnp.take(g.dst, g.eid_inv))

    # the weighted-CR forms the ring supports: copy/mul ⊗ sum/mean
    configs = [("u_copy_add_v", jnp.ones_like(e)),
               ("u_mul_e_add_v", e),
               ("u_copy_mean_v", inv_deg_caller),
               ("u_mul_e_mean_v", e * inv_deg_caller)]
    for S in (2, 3):
        for mode in ("contiguous", "hash"):
            pg = build_partition(g, S, mode)
            ctp = pg.scatter_nodes(ct)
            for name, w in configs:
                spec = parse_op(name)
                args = {"u": x}
                if spec.rhs == "e":
                    args["e"] = e[:, None]

                def f_ring(xx, ww):
                    out = ring_gspmm(pg, pg.scatter_nodes(xx),
                                     pg.scatter_edges(ww))
                    return jnp.sum(out * ctp)

                def f_seg(xx, ee):
                    a = dict(args, u=xx)
                    if "e" in a:
                        a["e"] = ee[:, None]
                    return jnp.sum(gspmm(g, name, **a,
                                         strategy="segment") * ct)

                tag = f"{name} via ring S={S} {mode}"
                out = pg.gather_nodes(
                    ring_gspmm(pg, pg.scatter_nodes(x),
                               pg.scatter_edges(w)))
                ref = gspmm(g, name, **args, strategy="segment")
                np.testing.assert_allclose(np.asarray(out),
                                           np.asarray(ref), rtol=1e-4,
                                           atol=1e-4,
                                           err_msg=f"output: {tag}")
                gx_r, gw_r = jax.grad(f_ring, argnums=(0, 1))(x, w)
                gx_s, ge_s = jax.grad(f_seg, argnums=(0, 1))(x, e)
                np.testing.assert_allclose(np.asarray(gx_r),
                                           np.asarray(gx_s), rtol=1e-4,
                                           atol=1e-4,
                                           err_msg=f"d/du: {tag}")
                if spec.rhs == "e" and spec.reduce == "sum":
                    # ring's ∂w is the per-edge <x, ct> dot — for the
                    # plain weighted sum it IS the segment ∂e
                    np.testing.assert_allclose(
                        np.asarray(gw_r), np.asarray(ge_s), rtol=1e-4,
                        atol=1e-4, err_msg=f"d/de: {tag}")


HETERO_STRATEGIES = ("fused", "loop", "ell")


def check_hetero(src, dst, n_u, n_v, rng):
    """``hetero_gspmm`` (every strategy) vs the per-relation ``gspmm``
    loop reference, outputs AND VJPs, on a skewed relation partition of
    the edge set that includes an EMPTY relation."""
    nnz = len(src)
    # skewed partition: one big relation, a few small, one empty
    n_rel = 4
    cuts = sorted(rng.integers(0, nnz + 1, size=2))
    sizes = [cuts[0], 0, cuts[1] - cuts[0], nnz - cuts[1]]
    order = rng.permutation(nnz)
    rels, ptr = [], 0
    for sz in sizes:
        sel = order[ptr:ptr + sz]
        rels.append((src[sel], dst[sel]))
        ptr += sz
    rg = from_rels(rels, n_src=n_u, n_dst=n_v)
    gs = [from_coo(s, d, n_src=n_u, n_dst=n_v) if len(s) else None
          for s, d in rels]
    off = np.cumsum([0] + sizes)

    d_in, d_out = 5, 3
    u = jnp.asarray(rng.normal(size=(n_u, d_in)).astype(np.float32))
    u3 = jnp.asarray(rng.normal(size=(n_u, n_rel, d_out))
                     .astype(np.float32))
    W = jnp.asarray(rng.normal(size=(n_rel, d_in, d_out))
                    .astype(np.float32))
    basis = jnp.asarray(rng.normal(size=(2, d_in, d_out))
                        .astype(np.float32))
    coeff = jnp.asarray(rng.normal(size=(n_rel, 2)).astype(np.float32))
    e = jnp.asarray(rng.uniform(0.5, 1.5, size=(nnz,)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n_v, d_out)).astype(np.float32))

    def ref(reduce, args):
        """Σ_r gspmm over the surviving relations (segment pinned) —
        the pre-refactor per-relation loop, linear reducers."""
        red = {"sum": "add"}.get(reduce, reduce)
        acc = jnp.zeros((n_v, d_out), jnp.float32)
        for r, g in enumerate(gs):
            if g is None:
                continue
            if "w" in args:
                ur = args["u"] @ args["w"][r]
            elif "basis" in args:
                ur = args["u"] @ jnp.einsum(
                    "b,bdo->do", args["coeff"][r], args["basis"])
            else:
                ur = args["u"][:, r, :]
            kw = {"u": ur}
            name = f"u_copy_{red}_v"
            if "e" in args:
                kw["e"] = args["e"][off[r]:off[r + 1], None]
                name = f"u_mul_e_{red}_v"
            acc = acc + gspmm(g, name, **kw, strategy="segment")
        return acc

    forms = [
        ({"u": u, "w": W}, ("sum", "mean")),
        ({"u": u, "basis": basis, "coeff": coeff}, ("sum", "mean")),
        ({"u": u3, "e": e}, ("sum",)),
    ]
    for args, reduces in forms:
        for reduce in reduces:
            r0 = ref(reduce, args)

            def ref_loss(vals):
                return jnp.sum(ref(reduce, {**args, **vals}) * ct)

            ref_g = jax.grad(ref_loss)({k: args[k] for k in args})
            for st in HETERO_STRATEGIES:
                tag = f"hetero {list(args)} {reduce} via {st}"
                out = hetero_gspmm(rg, strategy=st, reduce=reduce,
                                   **args)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(r0), rtol=1e-4,
                    atol=1e-4, err_msg=f"output: {tag}")

                def loss(vals):
                    return jnp.sum(hetero_gspmm(rg, strategy=st,
                                                reduce=reduce, **vals)
                                   * ct)

                out_g = jax.grad(loss)({k: args[k] for k in args})
                for k in ref_g:
                    np.testing.assert_allclose(
                        np.asarray(out_g[k]), np.asarray(ref_g[k]),
                        rtol=1e-4, atol=1e-4, err_msg=f"d/d{k}: {tag}")

    # max reducer: flat extremum over the fused edge set vs the merged
    # graph's gspmm (forward + autodiff VJP)
    gm = from_coo(np.concatenate([s for s, _ in rels]),
                  np.concatenate([d for _, d in rels]),
                  n_src=n_u, n_dst=n_v)
    um = jnp.asarray(rng.normal(size=(n_u, d_out)).astype(np.float32))
    ref_max = gspmm(gm, "u_copy_max_v", u=um, strategy="segment")
    gmax_r = jax.grad(lambda x: jnp.sum(
        gspmm(gm, "u_copy_max_v", u=x, strategy="segment") * ct))(um)
    for st in HETERO_STRATEGIES:
        out = hetero_gspmm(rg, um, reduce="max", strategy=st)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_max),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"output: hetero max via {st}")
        gmax = jax.grad(lambda x: jnp.sum(
            hetero_gspmm(rg, x, reduce="max", strategy=st) * ct))(um)
        np.testing.assert_allclose(np.asarray(gmax), np.asarray(gmax_r),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"d/du: hetero max via {st}")


def _skewed_coo(rng, n, nnz):
    """Power-law-ish degree draw: zipf destinations pile edges onto a
    few hub rows — the degree tail the ragged formats exist for."""
    src = rng.integers(0, n, nnz)
    dst = (rng.zipf(1.5, size=nnz) - 1) % n
    return src.astype(np.int64), dst.astype(np.int64)


def check_ragged_attention(src, dst, n_u, n_v, rng):
    """``fused_attention(strategy='pallas')`` — the ragged per-class ELL
    megakernel with its stripe-recompute backward — must match the
    canonical jnp 'fused' form on outputs AND VJPs w.r.t. el/er/z. The
    graph gets an extra isolated destination (degree-0 rows must stay
    exactly zero through the per-class scatter-back)."""
    from repro.core import fused_attention
    from repro.core.planner import get_plan_cache

    g = from_coo(src, dst, n_src=n_u, n_dst=n_v + 1)
    pack = get_plan_cache(g).ell_ragged()    # host-side, memoized

    # pack invariants: whole rows only, disjoint across classes,
    # power-of-two class widths with rows in their tightest class
    deg = np.asarray(g.in_degrees)
    rows_seen = np.concatenate(
        [np.asarray(c.chunk_row) for c in pack.classes])
    if g.n_edges:
        assert sorted(rows_seen.tolist()) == np.nonzero(deg)[0].tolist()
    for c in pack.classes:
        assert c.width & (c.width - 1) == 0
        ln = np.asarray(c.chunk_mask).sum(axis=1)
        assert (ln <= c.width).all()
        if c.width > 1:
            assert (ln > c.width // 2).all()

    H, F = 3, 4
    el = jnp.asarray(rng.normal(size=(g.n_src, H)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(g.n_dst, H)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(g.n_src, H, F)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(g.n_dst, H, F)).astype(np.float32))

    ref = fused_attention(g, el, er, z, strategy="fused")
    out = fused_attention(g, el, er, z, strategy="pallas")
    assert np.asarray(out)[deg == 0].sum() == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4,
                               err_msg="output: attention pallas-ragged")

    def loss(a, s):
        return jnp.sum(fused_attention(g, a["el"], a["er"], a["z"],
                                       strategy=s) * ct)

    args = {"el": el, "er": er, "z": z}
    ref_g = jax.grad(lambda a: loss(a, "fused"))(args)
    out_g = jax.grad(lambda a: loss(a, "pallas"))(args)
    for k in ref_g:
        np.testing.assert_allclose(
            np.asarray(out_g[k]), np.asarray(ref_g[k]), rtol=1e-4,
            atol=1e-4, err_msg=f"d/d{k}: attention pallas-ragged")


# ---------------- seeded sweep: always runs on tier-1 ----------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_outputs_and_vjps_agree_seeded(seed):
    rng = np.random.default_rng(seed)
    n_u, n_v, nnz = [(18, 12, 60), (24, 24, 90), (7, 30, 45)][seed]
    g, src, dst = random_graph(rng, n_u, n_v, nnz, unique=True)
    check_all_strategies(src, dst, n_u, n_v, rng)


@pytest.mark.parametrize("seed", [3, 4])
def test_block_vjps_match_segment_adjoint_seeded(seed):
    rng = np.random.default_rng(seed)
    g, src, dst = random_graph(rng, 20, 15, 60, unique=True)
    check_block_vjps(src, dst, 20, 15, rng)


@pytest.mark.parametrize("seed", [5, 6])
def test_ring_matches_segment_seeded(seed):
    rng = np.random.default_rng(seed)
    n_u, n_v, nnz = [(22, 22, 90), (14, 27, 70)][seed - 5]
    g, src, dst = random_graph(rng, n_u, n_v, nnz, unique=True)
    check_ring_strategy(src, dst, n_u, n_v, rng)


@pytest.mark.parametrize("seed", [9, 10])
def test_gsddmm_matches_oracle_seeded(seed):
    rng = np.random.default_rng(seed)
    n_u, n_v, nnz = [(20, 14, 70), (26, 26, 100)][seed - 9]
    g, src, dst = random_graph(rng, n_u, n_v, nnz, unique=True)
    check_gsddmm(src, dst, n_u, n_v, rng)


def test_gsddmm_block_pad_edges():
    """Block graphs carry PAD edges (dummy dst row, repeated src): every
    sddmm strategy must emit identical caller-order edge values across
    real and pad slots — the downstream block softmax depends on pads
    landing in the dummy row with finite values."""
    from repro.data import NeighborSampler

    rng = np.random.default_rng(11)
    g0, src, dst = random_graph(rng, 20, 16, 60, unique=True)
    g = from_coo(src, dst, n_src=20, n_dst=16)
    sampler = NeighborSampler(g, fanouts=[3], batch_size=6, seed=0)
    seeds = rng.permutation(16)[:6]
    mb = sampler.sample(seeds, np.zeros(len(seeds), np.int64))
    bg = mb.blocks[0].bg
    assert bg.g.n_edges > int(np.asarray(bg.real_deg).sum())  # has pads

    el = jnp.asarray(rng.normal(size=(bg.g.n_src, 3)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(bg.g.n_dst, 3)).astype(np.float32))
    spec = parse_op("u_add_v_copy_e")
    ref = _sddmm_reference(bg.g, spec, {"u": el, "v": er})
    assert bool(jnp.all(jnp.isfinite(ref)))
    for s in SDDMM_STRATEGIES:
        out = gsddmm(bg.g, "u_add_v_copy_e", u=el, v=er, strategy=s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"block pad edges via {s}")


@pytest.mark.parametrize("seed", [12, 13])
def test_ragged_attention_matches_fused_seeded(seed):
    """Uniform draw (seed 12) and skewed hub draw (seed 13) — the
    latter spreads the pack across several degree classes."""
    rng = np.random.default_rng(seed)
    if seed == 12:
        g, src, dst = random_graph(rng, 18, 14, 70, unique=True)
        check_ragged_attention(src, dst, 18, 14, rng)
    else:
        src, dst = _skewed_coo(rng, 24, 130)
        check_ragged_attention(src, dst, 24, 24, rng)


def test_ragged_ring_bucket_widths():
    """Per-bucket ``eb_ij`` bookkeeping: widths match the real bucket
    fills, the diagonal schedule's slot count is consistent and strictly
    beats the dense max-width layout on a skewed hash partition, and
    the ragged ring still matches segment outputs AND VJPs."""
    rng = np.random.default_rng(14)
    n = 32
    src, dst = _skewed_coo(rng, n, 170)
    g = from_coo(src, dst, n_src=n, n_dst=n)
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    ref = gspmm(g, "u_copy_add_v", u=x, strategy="segment")
    ref_gx = jax.grad(lambda xx: jnp.sum(
        gspmm(g, "u_copy_add_v", u=xx, strategy="segment") * ct))(x)

    for S, mode in [(4, "hash"), (3, "contiguous")]:
        pg = build_partition(g, S, mode)
        st = pg.stats
        mask = np.asarray(pg.mask)
        assert len(pg.eb_ij) == S and all(len(r) == S for r in pg.eb_ij)
        for i in range(S):
            for j in range(S):
                fill = int(mask[i, j].sum())
                assert pg.eb_ij[i][j] == fill
                assert pg.bucket_width(i, j) == fill <= pg.eb
                # bucket fill is contiguous from slot 0 (static-slice
                # contract of the ragged ring)
                assert not mask[i, j, fill:].any()
        ws = [max(pg.eb_ij[(j + s) % S][j] for j in range(S))
              for s in range(S)]
        assert st.ragged_slots == S * sum(ws)
        assert st.ragged_slots <= S * S * st.eb
        if mode == "hash":     # hub scatter → skewed buckets → savings
            assert st.ragged_slots < S * S * st.eb

        ctp = pg.scatter_nodes(ct)
        w = pg.scatter_edges(jnp.ones((g.n_edges,), jnp.float32))
        out = pg.gather_nodes(ring_gspmm(pg, pg.scatter_nodes(x), w))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"ragged ring S={S} {mode}")
        gx = jax.grad(lambda xx: jnp.sum(
            ring_gspmm(pg, pg.scatter_nodes(xx), w) * ctp))(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(ref_gx),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"d/du ragged ring S={S} {mode}")


def test_hetero_skew_max_min():
    """The size-skew per-class packs must now serve max/min too: on a
    relation partition skewed enough to trigger the skew classes, every
    strategy's max AND min must match the merged-graph gspmm on outputs
    and VJPs."""
    from repro.core import hetero as _hetero

    rng = np.random.default_rng(15)
    n = 40
    sizes = [400, 11, 9, 7]
    # globally unique (src, dst) pairs: parallel edges tie extrema,
    # which strategies may legitimately break differently (see module
    # docstring)
    pairs = rng.choice(n * n, size=sum(sizes), replace=False)
    s_all, d_all = pairs // n, pairs % n
    rels, off = [], 0
    for sz in sizes:
        rels.append((s_all[off:off + sz], d_all[off:off + sz]))
        off += sz
    rg = from_rels(rels, n_src=n, n_dst=n)
    assert _hetero._skew_classes(rg) is not None   # the gate fires
    gm = from_coo(np.concatenate([s for s, _ in rels]),
                  np.concatenate([d for _, d in rels]), n_src=n, n_dst=n)
    u = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    for red, name in (("max", "u_copy_max_v"), ("min", "u_copy_min_v")):
        ref = gspmm(gm, name, u=u, strategy="segment")
        ref_g = jax.grad(lambda x: jnp.sum(
            gspmm(gm, name, u=x, strategy="segment") * ct))(u)
        for st in HETERO_STRATEGIES:
            out = hetero_gspmm(rg, u, reduce=red, strategy=st)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4,
                err_msg=f"output: skew hetero {red} via {st}")
            out_g = jax.grad(lambda x: jnp.sum(
                hetero_gspmm(rg, x, reduce=red, strategy=st) * ct))(u)
            np.testing.assert_allclose(
                np.asarray(out_g), np.asarray(ref_g), rtol=1e-4,
                atol=1e-4, err_msg=f"d/du: skew hetero {red} via {st}")


@pytest.mark.parametrize("seed", [7, 8])
def test_hetero_matches_loop_reference_seeded(seed):
    rng = np.random.default_rng(seed)
    n_u, n_v, nnz = [(20, 16, 70), (25, 25, 110)][seed - 7]
    g, src, dst = random_graph(rng, n_u, n_v, nnz, unique=True)
    check_hetero(src, dst, n_u, n_v, rng)


# ---------------- hypothesis search: richer shapes -------------------- #
if HAS_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(graphs(max_n=24, max_e=90, unique=True))
    def test_outputs_and_vjps_agree_hypothesis(data):
        check_all_strategies(*data)

    @settings(max_examples=4, deadline=None)
    @given(graphs(max_n=20, max_e=60, unique=True))
    def test_block_vjps_match_segment_adjoint_hypothesis(data):
        check_block_vjps(*data)

    @settings(max_examples=4, deadline=None)
    @given(graphs(max_n=20, max_e=60, unique=True))
    def test_ring_matches_segment_hypothesis(data):
        check_ring_strategy(*data)

    @settings(max_examples=4, deadline=None)
    @given(graphs(max_n=20, max_e=60, unique=True))
    def test_hetero_matches_loop_reference_hypothesis(data):
        check_hetero(*data)

    @settings(max_examples=4, deadline=None)
    @given(graphs(max_n=20, max_e=60, unique=True))
    def test_gsddmm_matches_oracle_hypothesis(data):
        check_gsddmm(*data)

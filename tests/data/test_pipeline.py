"""Prefetcher / signature-tracker unit tests (host pipeline)."""
import time

import pytest

from repro.data import Prefetcher, SignatureTracker, prefetch


def test_prefetch_preserves_sequence():
    assert list(prefetch(iter(range(20)), depth=2)) == list(range(20))


def test_prefetch_exhausted_keeps_raising_stopiteration():
    it = prefetch(iter(range(3)), depth=2)
    assert list(it) == [0, 1, 2]
    # iterator protocol: further next() calls must raise again, not hang
    assert next(it, None) is None
    assert next(it, None) is None


def test_prefetch_propagates_producer_exception():
    def gen():
        yield 1
        yield 2
        raise ValueError("boom")

    it = prefetch(gen(), depth=2)
    assert next(it) == 1
    assert next(it) == 2
    with pytest.raises(ValueError, match="boom"):
        next(it)


def test_prefetch_runs_ahead():
    """With depth 2 the producer works ahead of the consumer."""
    produced = []

    def gen():
        for i in range(5):
            produced.append(i)
            yield i

    it = prefetch(gen(), depth=2)
    first = next(it)
    assert first == 0
    deadline = time.time() + 2.0
    while len(produced) < 3 and time.time() < deadline:
        time.sleep(0.01)    # producer should fill the buffer unprompted
    assert len(produced) >= 3
    it.close()


def test_close_stops_producer_early():
    state = {"n": 0}

    def gen():
        while True:
            state["n"] += 1
            yield state["n"]

    it = Prefetcher(gen(), depth=2)
    next(it)
    it.close()
    n_after_close = state["n"]
    time.sleep(0.1)
    assert state["n"] == n_after_close   # producer actually stopped
    # a closed iterator is exhausted — never a hang or a stale item
    with pytest.raises(StopIteration):
        next(it)


def test_signature_tracker_trips_on_shape_drift():
    t = SignatureTracker(limit=2)
    assert t.observe(("a",)) is True
    assert t.observe(("a",)) is False
    t.observe(("b",))
    t.assert_bounded()
    t.observe(("c",))
    with pytest.raises(RuntimeError, match="shape signatures"):
        t.assert_bounded()

"""Request micro-batching tests (DESIGN.md §10).

Three contracts: signature-class assignment is a pure function of the
request sizes (deterministic), pad rows are structurally unreachable
from any response, and a steady-state replay of 100 batches observes
zero new compile signatures after the first batch per class.
"""
import numpy as np
import pytest

from repro.core import from_coo
from repro.core.blocks import serve_block_signature
from repro.core.serving import MicroBatcher
from repro.data import (NeighborSampler, RequestQueue, SignatureTracker,
                        prefetch)
from repro.data.pipeline import ServeRequest
from repro.data.synthetic import rmat_graph


# --------------------------------------------------------------------- #
# class assignment
# --------------------------------------------------------------------- #
def test_class_assignment_deterministic():
    b = MicroBatcher(classes=(8, 32, 128))
    assert [b.assign_class(n) for n in (1, 8, 9, 32, 33, 128, 500)] == \
        [8, 8, 32, 32, 128, 128, 128]
    # same requests → identical batches, run twice
    reqs = [(0, [3, 1]), (1, [4]), (2, list(range(40)))]
    a, c = b.coalesce(reqs), b.coalesce(reqs)
    assert [(x.cls, x.n_real, x.spans) for x in a] == \
        [(x.cls, x.n_real, x.spans) for x in c]
    for x, y in zip(a, c):
        np.testing.assert_array_equal(x.ids, y.ids)


def test_classes_validated():
    with pytest.raises(ValueError):
        MicroBatcher(classes=())
    with pytest.raises(ValueError):
        MicroBatcher(classes=(4, 4))
    with pytest.raises(ValueError):
        MicroBatcher(classes=(0, 8))
    with pytest.raises(ValueError):
        MicroBatcher().assign_class(0)


def test_coalesce_packs_and_flushes():
    b = MicroBatcher(classes=(4, 8))
    batches = b.coalesce([(0, [1, 2, 3]), (1, [4, 5, 6]),   # 6 → class 8
                          (2, [7, 8, 9])])                  # overflow → new
    assert [x.cls for x in batches] == [8, 4]
    assert batches[0].n_real == 6 and batches[1].n_real == 3
    # ids laid out in arrival order, pad tail is -1
    np.testing.assert_array_equal(batches[0].ids,
                                  [1, 2, 3, 4, 5, 6, -1, -1])


def test_oversize_request_splits_into_chunks():
    b = MicroBatcher(classes=(4,))
    batches = b.coalesce([(7, np.arange(10))])
    assert [x.cls for x in batches] == [4, 4, 4]
    assert [x.n_real for x in batches] == [4, 4, 2]
    got = np.concatenate([x.ids[:x.n_real] for x in batches])
    np.testing.assert_array_equal(got, np.arange(10))


def test_rejects_bad_requests():
    b = MicroBatcher()
    with pytest.raises(ValueError):
        b.coalesce([(0, [])])
    with pytest.raises(ValueError):
        b.coalesce([(0, [3, -1])])


# --------------------------------------------------------------------- #
# pad rows never leak
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(5))
def test_pad_rows_never_leak_into_responses(seed):
    rng = np.random.default_rng(seed)
    b = MicroBatcher(classes=(4, 16, 64))
    reqs = [(rid, rng.integers(0, 100, rng.integers(1, 9)))
            for rid in range(12)]
    sizes = {rid: len(ids) for rid, ids in reqs}
    for batch in b.coalesce(reqs):
        # poison every pad row; real rows carry their global id
        vals = np.full((batch.cls, 2), np.nan, np.float32)
        vals[:batch.n_real] = batch.ids[:batch.n_real, None]
        out = b.unpack(batch, vals)
        for rid, rows in out.items():
            assert np.isfinite(rows).all(), "pad row leaked into response"
            assert rows.shape[0] <= sizes[rid]
    # spans tile [0, n_real) exactly — no gaps, no overlap, no pad reach
    for batch in b.coalesce(reqs):
        edges = sorted(batch.spans, key=lambda s: s[1])
        assert edges[0][1] == 0 and edges[-1][2] == batch.n_real
        for (_, _, stop), (_, start, _) in zip(edges, edges[1:]):
            assert stop == start


# --------------------------------------------------------------------- #
# steady state: zero recompiles over a 100-batch replay
# --------------------------------------------------------------------- #
def test_steady_state_replay_zero_recompiles():
    rng = np.random.default_rng(0)
    src, dst, n = rmat_graph(6, 400, seed=3)   # power-law-ish degrees
    g = from_coo(src, dst, n_src=n, n_dst=n)
    fanout = int(np.asarray(g.in_degrees).max())
    classes = (4, 16)
    samplers = {c: NeighborSampler(g, [fanout, fanout], batch_size=c,
                                   seed=0)
                for c in classes}
    batcher = MicroBatcher(classes=classes)
    tracker = SignatureTracker(limit=len(classes))
    compiles = []
    for i in range(100):
        k = int(rng.integers(1, 17))
        reqs = [(i, rng.integers(0, g.n_src, k))]
        for batch in batcher.coalesce(reqs):
            mb = samplers[batch.cls].sample(
                batch.ids[:batch.n_real],
                np.zeros(batch.n_real, np.int64))
            sig = (batch.cls,) + mb.shape_signature()
            if tracker.observe(sig):
                compiles.append(i)
            tracker.assert_bounded()
            # the predicted signature IS the sampled one — the serving
            # tier can pre-register compiles without sampling
            assert mb.shape_signature() == serve_block_signature(
                batch.cls, fanout, 2)
    # every distinct signature appeared in the warmup prefix, none later
    assert len(tracker.seen) == len(classes)
    assert all(i < 10 for i in compiles), \
        f"recompile after steady state: batches {compiles}"


# --------------------------------------------------------------------- #
# the request queue
# --------------------------------------------------------------------- #
def test_request_queue_windows_and_futures():
    rq = RequestQueue(max_wait=0.01)
    r1 = rq.submit([1, 2])
    r2 = rq.submit([3])
    window = next(iter(rq))
    assert [r.rid for r in window] == [r1.rid, r2.rid]
    np.testing.assert_array_equal(window[0].ids, [1, 2])
    r1.set_result("a")
    assert r1.result(timeout=1) == "a" and r1.done() and not r2.done()
    r2.set_error(RuntimeError("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        r2.result(timeout=1)


def test_request_queue_close_drains_through_prefetcher():
    rq = RequestQueue(max_wait=0.001)
    reqs = [rq.submit([i]) for i in range(5)]
    rq.close()
    with pytest.raises(RuntimeError):
        rq.submit([9])
    seen = [r for window in prefetch(rq, depth=2) for r in window]
    assert {r.rid for r in seen} == {r.rid for r in reqs}
    # a closed-and-drained queue stays exhausted
    assert next(iter(rq), None) is None


def test_request_queue_window_caps_at_max_nodes():
    rq = RequestQueue(max_nodes=4, max_wait=5.0)   # long window: the cap
    for i in range(4):                             # must cut it, not time
        rq.submit([i, 100 + i])
    w1 = next(iter(rq))
    assert sum(len(r.ids) for r in w1) >= 4
    assert len(w1) < 4


def test_request_future_first_resolution_wins():
    rq = RequestQueue(max_wait=0.001)
    r = rq.submit([1])
    assert r.set_result("served") is True
    # a late close-time error must not clobber the delivered result
    assert r.set_error(RuntimeError("queue closed")) is False
    assert r.result(timeout=1) == "served"
    r2 = rq.submit([2])
    assert r2.set_error(RuntimeError("queue closed")) is True
    assert r2.set_result("late") is False
    with pytest.raises(RuntimeError, match="queue closed"):
        r2.result(timeout=1)


def test_request_queue_close_cancel_pending_resolves_futures():
    """Regression: close() used to leave queued-but-unserved requests
    with unresolved futures — a blocked result() call hung forever."""
    rq = RequestQueue(max_wait=0.001)
    reqs = [rq.submit([i]) for i in range(3)]
    rq.close(cancel_pending=True)
    for r in reqs:
        assert r.done()
        with pytest.raises(RuntimeError, match="queue closed"):
            r.result(timeout=1)
    assert next(iter(rq), None) is None     # still exhausted


def test_request_queue_shutdown_errors_raced_in_requests():
    """A request that lands in the queue behind the shutdown sentinel is
    resolved with the close error once iteration ends — not abandoned
    with its requester blocked in result() forever."""
    rq = RequestQueue(max_wait=0.001)
    rq.close()
    straggler = ServeRequest(999, np.asarray([2], np.int64))
    rq._q.put(straggler)                    # simulate the submit race
    assert list(rq) == []                   # iteration just ends ...
    assert straggler.done()                 # ... but the future resolves
    with pytest.raises(RuntimeError, match="queue closed"):
        straggler.result(timeout=1)


def test_request_queue_close_after_serving_is_noop_for_done_requests():
    rq = RequestQueue(max_wait=0.001)
    r = rq.submit([1])
    (w,) = [next(iter(rq))]
    w[0].set_result("ok")
    rq.close(cancel_pending=True)
    assert r.result(timeout=1) == "ok"

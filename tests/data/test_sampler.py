"""Sampler property tests (paper Fig. 3 preconditions).

The properties the minibatch subsystem leans on:

* determinism — same seed ⇒ bit-identical batches;
* padding hygiene — pad slots/edges contribute EXACTLY zero to mean
  aggregation, even when the dummy feature row is poisoned;
* fanout bounds — no destination ever receives more than ``fanout``
  sampled in-edges, and sampling is without replacement;
* exactness — with fanout ≥ max in-degree the blocks contain every
  in-edge, so the sampled forward equals the full-graph forward for
  every app on the shared block path (SAGE, GCN, GAT).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import block_gspmm
from repro.data import NeighborSampler, make_node_dataset
from repro.models.gnn import gat, gcn, sage
from repro.models.gnn.common import (block_features, make_bundle,
                                     pad_features)
from tests.graphgen import random_graph


@pytest.fixture(scope="module")
def tiny():
    return make_node_dataset("tiny")


def _batches(sampler, ids, labels, n=3):
    out = []
    for i, mb in enumerate(sampler.batches(ids, labels)):
        out.append(mb)
        if i + 1 >= n:
            break
    return out


def test_seed_determinism(tiny):
    g, feats, labels, tm, vm, nc = tiny
    ids = np.nonzero(tm)[0]
    a = _batches(NeighborSampler(g, [4, 4], 16, seed=7), ids, labels[ids])
    b = _batches(NeighborSampler(g, [4, 4], 16, seed=7), ids, labels[ids])
    c = _batches(NeighborSampler(g, [4, 4], 16, seed=8), ids, labels[ids])
    for mb_a, mb_b in zip(a, b):
        assert (np.asarray(mb_a.seed_ids) == np.asarray(mb_b.seed_ids)).all()
        for blk_a, blk_b in zip(mb_a.blocks, mb_b.blocks):
            np.testing.assert_array_equal(np.asarray(blk_a.bg.nbr),
                                          np.asarray(blk_b.bg.nbr))
            np.testing.assert_array_equal(np.asarray(blk_a.src_ids),
                                          np.asarray(blk_b.src_ids))
            np.testing.assert_array_equal(np.asarray(blk_a.bg.g.src),
                                          np.asarray(blk_b.bg.g.src))
    assert any((np.asarray(x.seed_ids) != np.asarray(y.seed_ids)).any()
               for x, y in zip(a, c))


def test_vectorized_draw_identical_streams_same_seed():
    """The batched without-replacement draw must be stream-deterministic:
    two samplers with one seed (and one sampler after reset()) emit
    bit-identical minibatches — every array, every block, every batch."""
    rng = np.random.default_rng(11)
    g, src, dst = random_graph(rng, 50, 50, 400)
    ids = np.arange(g.n_dst)
    labels = rng.integers(0, 3, g.n_dst)

    def stream(sampler):
        return _batches(sampler, ids, labels, n=4)

    a = stream(NeighborSampler(g, [3, 5], 8, seed=42))
    b = stream(NeighborSampler(g, [3, 5], 8, seed=42))
    s = NeighborSampler(g, [3, 5], 8, seed=42)
    first = stream(s)
    s.reset()
    replay = stream(s)
    for other in (b, first, replay):
        for mb_a, mb_o in zip(a, other):
            np.testing.assert_array_equal(np.asarray(mb_a.seed_ids),
                                          np.asarray(mb_o.seed_ids))
            np.testing.assert_array_equal(np.asarray(mb_a.labels),
                                          np.asarray(mb_o.labels))
            for blk_a, blk_o in zip(mb_a.blocks, mb_o.blocks):
                for fa, fo in [(blk_a.bg.nbr, blk_o.bg.nbr),
                               (blk_a.bg.nbr_eid, blk_o.bg.nbr_eid),
                               (blk_a.bg.nbr_mask, blk_o.bg.nbr_mask),
                               (blk_a.src_ids, blk_o.src_ids),
                               (blk_a.gcn_norm, blk_o.gcn_norm),
                               (blk_a.bg.g.src, blk_o.bg.g.src),
                               (blk_a.bg.g.dst, blk_o.bg.g.dst)]:
                    np.testing.assert_array_equal(np.asarray(fa),
                                                  np.asarray(fo))


def test_reset_replays_stream(tiny):
    g, feats, labels, tm, vm, nc = tiny
    ids = np.nonzero(tm)[0]
    s = NeighborSampler(g, [3], 8, seed=5)
    first = _batches(s, ids, labels[ids], n=2)
    s.reset()
    again = _batches(s, ids, labels[ids], n=2)
    for mb1, mb2 in zip(first, again):
        np.testing.assert_array_equal(np.asarray(mb1.input_ids),
                                      np.asarray(mb2.input_ids))


@pytest.mark.parametrize("strategy", ["ell", "segment", "push"])
def test_pad_rows_contribute_zero_to_mean(strategy):
    """Poisoning every PAD source slot's features must not change any
    real row of the mean aggregation, for every block strategy."""
    rng = np.random.default_rng(0)
    g, src, dst = random_graph(rng, 40, 40, 160)
    sampler = NeighborSampler(g, fanouts=[3], batch_size=8, seed=1)
    seeds = rng.permutation(g.n_dst)[:8]
    mb = sampler.sample(seeds, np.zeros(8, np.int64))
    blk = mb.blocks[0]
    bg = blk.bg
    feats = rng.normal(size=(g.n_src, 6)).astype(np.float32)
    h = block_features(pad_features(feats), blk.src_ids)
    poison = np.asarray(h).copy()
    poison[np.asarray(blk.src_ids) < 0] = 1e9      # garbage in pad slots
    clean = block_gspmm(bg, "u_copy_mean_v", u=h, strategy=strategy)
    dirty = block_gspmm(bg, "u_copy_mean_v", u=jnp.asarray(poison),
                        strategy=strategy)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))
    # and the mean denominator is the REAL degree, not the padded width
    s = block_gspmm(bg, "u_copy_add_v", u=h, strategy=strategy)
    deg = np.maximum(np.asarray(bg.real_deg), 1)[:, None]
    np.testing.assert_allclose(np.asarray(clean), np.asarray(s) / deg,
                               rtol=1e-5, atol=1e-6)


def test_fanout_bounds_and_no_replacement():
    rng = np.random.default_rng(2)
    # unique edges: without-replacement sampling repeats a neighbor only
    # through parallel edges, which a simple graph rules out
    g, src, dst = random_graph(rng, 60, 60, 600, unique=True)
    fanout = 5
    sampler = NeighborSampler(g, fanouts=[fanout], batch_size=16, seed=3)
    indptr = np.asarray(g.indptr_dst)
    gsrc = np.asarray(g.src)
    for mb in _batches(sampler, np.arange(g.n_dst),
                       np.zeros(g.n_dst, np.int64), n=3):
        blk = mb.blocks[0]
        bg = blk.bg
        real_deg = np.asarray(bg.real_deg)
        mask = np.asarray(bg.nbr_mask)
        assert (real_deg <= fanout).all()
        assert (mask.sum(1) == real_deg).all()
        seeds = np.asarray(mb.seed_ids)
        src_ids = np.asarray(blk.src_ids)
        nbr = np.asarray(bg.nbr)
        for j, node in enumerate(seeds):
            if node < 0:
                continue
            in_deg = indptr[node + 1] - indptr[node]
            # never more than min(fanout, degree) samples
            assert real_deg[j] == min(fanout, in_deg)
            neigh = src_ids[nbr[j][mask[j]]]
            # without replacement: sampled globals are distinct, and all
            # are true in-neighbors of the seed
            true_nb = gsrc[indptr[node]:indptr[node + 1]]
            assert len(set(neigh.tolist())) == len(neigh)
            assert set(neigh.tolist()) <= set(true_nb.tolist())


def test_short_final_batch_padded_and_masked(tiny):
    g, feats, labels, tm, vm, nc = tiny
    ids = np.nonzero(tm)[0][:37]        # 37 = 2×16 + 5 tail
    sampler = NeighborSampler(g, [3], 16, seed=0)
    mbs = list(sampler.batches(ids, labels[ids], drop_last=False))
    assert len(mbs) == 3
    for mb in mbs:
        assert mb.seed_ids.shape == (16,)
        assert mb.labels.shape == (16,)
    tail = mbs[-1]
    assert int(tail.label_mask.sum()) == 5
    assert (np.asarray(tail.seed_ids)[np.asarray(~tail.label_mask)]
            == -1).all()
    # padded batch keeps the one static shape signature
    assert tail.shape_signature() == mbs[0].shape_signature()


# ------------------------------------------------------------------ #
# reverse table (the gather backward's lookup structure, DESIGN.md §7)
# ------------------------------------------------------------------ #
def _forward_edge_set(bg):
    """{(src_slot, dst_row, caller_eid)} of the REAL edges, from the
    forward neighbor table."""
    nbr = np.asarray(bg.nbr)
    eid = np.asarray(bg.nbr_eid)
    mask = np.asarray(bg.nbr_mask)
    jj, kk = np.nonzero(mask)
    return set(zip(nbr[jj, kk].tolist(), jj.tolist(),
                   eid[jj, kk].tolist()))


def _reverse_edge_set(bg):
    """Same triple set rebuilt from the reverse table (real edges are
    the ones whose destination is a real row, not the dummy)."""
    rs = np.asarray(bg.rev_src)
    rd = np.asarray(bg.rev_dst)
    re = np.asarray(bg.rev_eid)
    real = rd < bg.n_dst_real
    return set(zip(rs[real].tolist(), rd[real].tolist(),
                   re[real].tolist()))


def test_reverse_table_round_trip():
    """forward table ↦ reverse table ↦ forward: the reverse table is a
    src-sorted permutation of exactly the same edges, every layer."""
    rng = np.random.default_rng(9)
    g, src, dst = random_graph(rng, 40, 40, 200)
    sampler = NeighborSampler(g, fanouts=[3, 4], batch_size=8, seed=2)
    mb = sampler.sample(rng.permutation(g.n_dst)[:8],
                        np.zeros(8, np.int64))
    for blk in mb.blocks:
        bg = blk.bg
        assert bg.has_reverse
        rev_src = np.asarray(bg.rev_src)
        rev_eid = np.asarray(bg.rev_eid)
        # a permutation of ALL edge slots, sorted by source slot
        assert sorted(rev_eid.tolist()) == list(range(bg.g.n_edges))
        assert (np.diff(rev_src) >= 0).all()
        # real-edge triples agree exactly with the forward table
        assert _reverse_edge_set(bg) == _forward_edge_set(bg)
        # pad edges: dummy source slot AND dummy destination row only
        rd = np.asarray(bg.rev_dst)
        pad = rd >= bg.n_dst_real
        assert (rev_src[pad] == bg.g.n_src - 1).all()
        assert (rd[pad] == bg.n_dst_real).all()


def test_reverse_table_deterministic_per_seed():
    rng = np.random.default_rng(13)
    g, src, dst = random_graph(rng, 30, 30, 180)
    ids = np.arange(g.n_dst)
    labels = np.zeros(g.n_dst, np.int64)
    a = _batches(NeighborSampler(g, [4], 8, seed=21), ids, labels)
    b = _batches(NeighborSampler(g, [4], 8, seed=21), ids, labels)
    for mb_a, mb_b in zip(a, b):
        for blk_a, blk_b in zip(mb_a.blocks, mb_b.blocks):
            for fa, fb in [(blk_a.bg.rev_src, blk_b.bg.rev_src),
                           (blk_a.bg.rev_dst, blk_b.bg.rev_dst),
                           (blk_a.bg.rev_eid, blk_b.bg.rev_eid)]:
                np.testing.assert_array_equal(np.asarray(fa),
                                              np.asarray(fb))


def test_reverse_backward_pad_poison_invariance():
    """Poisoning every PAD source slot's features AND every pad edge's
    weight must not change any gradient of the gather backward: pad
    edges pull the dummy destination's zero cotangent row."""
    rng = np.random.default_rng(3)
    g, src, dst = random_graph(rng, 40, 40, 160)
    sampler = NeighborSampler(g, fanouts=[3], batch_size=8, seed=1)
    mb = sampler.sample(rng.permutation(g.n_dst)[:8], np.zeros(8, np.int64))
    blk = mb.blocks[0]
    bg = blk.bg
    n_real = int(np.asarray(bg.real_deg).sum())
    u = jnp.asarray(rng.normal(size=(bg.g.n_src, 6)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(bg.g.n_edges, 1)).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(bg.n_dst_real, 6)).astype(np.float32))

    def grads(u, e):
        def f(u, e):
            return jnp.sum(block_gspmm(bg, "u_mul_e_add_v", u=u, e=e,
                                       bwd_strategy="gather") * ct)
        return jax.grad(f, argnums=(0, 1))(u, e)

    pu = np.asarray(u).copy()
    pu[np.asarray(blk.src_ids) < 0] = 1e9          # poison pad src slots
    pe = np.asarray(e).copy()
    pe[n_real:] = -1e9                             # poison pad edges
    du, de = grads(u, e)
    du_p, de_p = grads(jnp.asarray(pu), jnp.asarray(pe))
    np.testing.assert_array_equal(np.asarray(du), np.asarray(du_p))
    # real edges' ∂e unchanged; pad edges' ∂e is exactly zero both ways
    np.testing.assert_array_equal(np.asarray(de)[:n_real],
                                  np.asarray(de_p)[:n_real])
    np.testing.assert_array_equal(np.asarray(de_p)[n_real:], 0.0)


@pytest.mark.parametrize("mod", [sage, gcn, gat],
                         ids=["sage", "gcn", "gat"])
def test_sampled_equals_full_when_fanout_covers_degree(tiny, mod):
    """fanout ≥ max in-degree ⇒ blocks hold every in-edge ⇒ the sampled
    forward must equal the full-graph forward on the seed rows."""
    g, feats, labels, tm, vm, nc = tiny
    maxdeg = int(np.asarray(g.in_degrees).max())
    sampler = NeighborSampler(g, fanouts=[maxdeg, maxdeg], batch_size=16,
                              seed=4)
    ids = np.nonzero(tm)[0][:16]
    mb = sampler.sample(ids, labels[ids])
    bundle = make_bundle(g)
    params = mod.init(jax.random.PRNGKey(0), feats.shape[1], 16, nc)
    full = mod.forward(params, bundle, jnp.asarray(feats))
    x = block_features(pad_features(feats), mb.input_ids)
    sampled = mod.forward_blocks(params, mb.blocks, x)
    ref = np.asarray(full)[np.asarray(mb.seed_ids)]
    np.testing.assert_allclose(np.asarray(sampled), ref,
                               rtol=2e-4, atol=2e-5)

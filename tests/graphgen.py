"""Shared random-graph generators for the test suite.

Two flavours:

* :func:`random_graph` — plain seeded-numpy generator, usable on the
  bare tier-1 environment (no hypothesis).
* :func:`graphs` — a hypothesis composite strategy emitting
  ``(src, dst, n_u, n_v, rng)`` tuples. Only defined when hypothesis is
  installed; test files that need it must ``pytest.importorskip`` first.

Both support ``unique=True`` (dedup (src, dst) pairs), which the
differential VJP tests rely on: duplicate parallel edges make max/min
reductions tie between identical messages, and different strategies may
then route the subgradient to different edges.
"""
import numpy as np


def random_edges(rng, n_src, n_dst, nnz, *, unique=False):
    """Random COO arrays; ``unique`` dedups (src, dst) pairs — the ONE
    place that rule lives, shared by both generator flavours."""
    src = rng.integers(0, n_src, nnz)
    dst = rng.integers(0, n_dst, nnz)
    if unique:
        pairs = np.unique(np.stack([src, dst], 1), axis=0)
        src, dst = pairs[:, 0], pairs[:, 1]
    return src, dst


def random_graph(rng, n_src, n_dst, nnz, *, unique=False):
    """Random COO arrays + a repro.core Graph built from them."""
    from repro.core import from_coo
    src, dst = random_edges(rng, n_src, n_dst, nnz, unique=unique)
    g = from_coo(src, dst, n_src=n_src, n_dst=n_dst)
    return g, src, dst


try:
    from hypothesis import strategies as st

    @st.composite
    def graphs(draw, max_n=40, max_e=150, unique=False):
        """(src, dst, n_u, n_v, rng): random graph + its seeded rng."""
        n_u = draw(st.integers(1, max_n))
        n_v = draw(st.integers(1, max_n))
        nnz = draw(st.integers(1, max_e))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        src, dst = random_edges(rng, n_u, n_v, nnz, unique=unique)
        return src, dst, n_u, n_v, rng
except ImportError:      # hypothesis is optional on tier-1
    graphs = None

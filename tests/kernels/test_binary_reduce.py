"""Fused Binary-Reduce Pallas kernel vs oracle — binop/shape sweep."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.binary_reduce.ops import binary_reduce
from repro.kernels.binary_reduce.ref import binary_reduce_ref

from ..conftest import make_graph


@pytest.mark.parametrize("binop", ["add", "sub", "mul", "div"])
def test_binop_sweep(binop):
    rng = np.random.default_rng(11)
    g, _, _ = make_graph(rng, 150, 90, 700)
    B = jnp.asarray(rng.normal(size=(150, 96)).astype(np.float32))
    E = jnp.asarray((rng.normal(size=(700, 96)) + 3).astype(np.float32))
    out = binary_reduce(g, B, E, binop=binop)
    ref = binary_reduce_ref(g.src, g.dst, B, jnp.take(E, g.eid, axis=0),
                            90, binop)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_u,n_v,nnz,d", [
    (60, 60, 300, 128), (301, 77, 999, 17), (33, 400, 1000, 256)])
def test_shape_sweep(n_u, n_v, nnz, d):
    rng = np.random.default_rng(n_u)
    g, _, _ = make_graph(rng, n_u, n_v, nnz)
    B = jnp.asarray(rng.normal(size=(n_u, d)).astype(np.float32))
    E = jnp.asarray(rng.normal(size=(nnz, d)).astype(np.float32))
    out = binary_reduce(g, B, E, binop="mul")
    ref = binary_reduce_ref(g.src, g.dst, B, jnp.take(E, g.eid, axis=0),
                            n_v, "mul")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_scalar_edge_broadcast():
    rng = np.random.default_rng(5)
    g, _, _ = make_graph(rng, 100, 100, 500)
    B = jnp.asarray(rng.normal(size=(100, 32)).astype(np.float32))
    Es = jnp.asarray(rng.normal(size=(500, 1)).astype(np.float32))
    out = binary_reduce(g, B, Es, binop="mul")
    Efull = jnp.broadcast_to(Es, (500, 32))
    ref = binary_reduce_ref(g.src, g.dst, B,
                            jnp.take(Efull, g.eid, axis=0), 100, "mul")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_mean_reduce():
    rng = np.random.default_rng(6)
    g, _, _ = make_graph(rng, 80, 70, 400)
    B = jnp.asarray(rng.normal(size=(80, 40)).astype(np.float32))
    E = jnp.asarray(rng.normal(size=(400, 40)).astype(np.float32))
    out = binary_reduce(g, B, E, binop="add", reduce_op="mean")
    ref = binary_reduce_ref(g.src, g.dst, B, jnp.take(E, g.eid, axis=0),
                            70, "add")
    deg = np.zeros(70); np.add.at(deg, np.asarray(g.dst), 1)
    ref = np.asarray(ref) / np.maximum(deg, 1)[:, None]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)

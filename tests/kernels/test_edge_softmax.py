"""Fused edge-softmax Pallas kernel vs oracle + composition property."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import edge_softmax as edge_softmax_composed
from repro.kernels.edge_softmax.ops import edge_softmax
from repro.kernels.edge_softmax.ref import edge_softmax_ref

from ..conftest import make_graph


@pytest.mark.parametrize("n_u,n_v,nnz,H", [
    (100, 80, 600, 1), (100, 80, 600, 4), (40, 200, 900, 8),
    (300, 10, 1500, 2)])
def test_edge_softmax_matches_ref(n_u, n_v, nnz, H):
    rng = np.random.default_rng(nnz + H)
    g, _, _ = make_graph(rng, n_u, n_v, nnz)
    logits = jnp.asarray((rng.normal(size=(nnz, H)) * 3).astype(np.float32))
    out = edge_softmax(g, logits)
    ref_canon = edge_softmax_ref(g.dst, jnp.take(logits, g.eid, axis=0), n_v)
    ref = np.zeros((nnz, H), np.float32)
    ref[np.asarray(g.eid)] = np.asarray(ref_canon)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)


def test_rows_sum_to_one():
    rng = np.random.default_rng(1)
    g, src, dst = make_graph(rng, 50, 60, 400)
    logits = jnp.asarray(rng.normal(size=(400, 3)).astype(np.float32))
    out = np.asarray(edge_softmax(g, logits))
    sums = np.zeros((60, 3))
    np.add.at(sums, dst, out)
    deg = np.zeros(60); np.add.at(deg, dst, 1)
    np.testing.assert_allclose(sums[deg > 0], 1.0, rtol=1e-5)


def test_matches_composed_primitive_chain():
    """Fused kernel == the 5-primitive BR chain from the paper's Table 2."""
    rng = np.random.default_rng(2)
    g, _, _ = make_graph(rng, 70, 70, 500)
    logits = jnp.asarray(rng.normal(size=(500, 2)).astype(np.float32))
    fused = edge_softmax(g, logits)
    composed = edge_softmax_composed(g, logits)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed),
                               rtol=1e-5, atol=1e-6)


def test_1d_logits():
    rng = np.random.default_rng(3)
    g, _, _ = make_graph(rng, 30, 30, 150)
    logits = jnp.asarray(rng.normal(size=(150,)).astype(np.float32))
    out = edge_softmax(g, logits)
    assert out.shape == (150,)
    assert np.isfinite(np.asarray(out)).all()

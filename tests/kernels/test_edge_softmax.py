"""Fused edge-softmax Pallas kernel vs oracle + composition property,
plus the fused-attention megakernel (logits+softmax+aggregate as one
pass, DESIGN.md §9) against its oracle and the multipass composition."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import edge_softmax as edge_softmax_composed
from repro.core import from_coo
from repro.core.edge_softmax import edge_softmax_fused, fused_attention
from repro.kernels.edge_softmax.ops import edge_softmax
from repro.kernels.edge_softmax.ops import \
    fused_attention as fused_attention_kernel
from repro.kernels.edge_softmax.ref import (edge_softmax_ref,
                                            fused_attention_ref)

from ..conftest import make_graph


@pytest.mark.parametrize("n_u,n_v,nnz,H", [
    (100, 80, 600, 1), (100, 80, 600, 4), (40, 200, 900, 8),
    (300, 10, 1500, 2)])
def test_edge_softmax_matches_ref(n_u, n_v, nnz, H):
    rng = np.random.default_rng(nnz + H)
    g, _, _ = make_graph(rng, n_u, n_v, nnz)
    logits = jnp.asarray((rng.normal(size=(nnz, H)) * 3).astype(np.float32))
    out = edge_softmax(g, logits)
    ref_canon = edge_softmax_ref(g.dst, jnp.take(logits, g.eid, axis=0), n_v)
    ref = np.zeros((nnz, H), np.float32)
    ref[np.asarray(g.eid)] = np.asarray(ref_canon)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)


def test_rows_sum_to_one():
    rng = np.random.default_rng(1)
    g, src, dst = make_graph(rng, 50, 60, 400)
    logits = jnp.asarray(rng.normal(size=(400, 3)).astype(np.float32))
    out = np.asarray(edge_softmax(g, logits))
    sums = np.zeros((60, 3))
    np.add.at(sums, dst, out)
    deg = np.zeros(60); np.add.at(deg, dst, 1)
    np.testing.assert_allclose(sums[deg > 0], 1.0, rtol=1e-5)


def test_matches_composed_primitive_chain():
    """Fused kernel == the 5-primitive BR chain from the paper's Table 2."""
    rng = np.random.default_rng(2)
    g, _, _ = make_graph(rng, 70, 70, 500)
    logits = jnp.asarray(rng.normal(size=(500, 2)).astype(np.float32))
    fused = edge_softmax(g, logits)
    composed = edge_softmax_composed(g, logits)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed),
                               rtol=1e-5, atol=1e-6)


def test_1d_logits():
    rng = np.random.default_rng(3)
    g, _, _ = make_graph(rng, 30, 30, 150)
    logits = jnp.asarray(rng.normal(size=(150,)).astype(np.float32))
    out = edge_softmax(g, logits)
    assert out.shape == (150,)
    assert np.isfinite(np.asarray(out)).all()


def test_zero_degree_rows_differential():
    """Composed chain vs single-pass form on a graph with zero-degree
    destinations: both must stay NaN-free through forward AND backward
    and agree everywhere — the composed max-shift carries the same
    ``where(isfinite)`` guard as the fused path, so empty rows never
    inject -inf into the subtract."""
    rng = np.random.default_rng(4)
    live = np.asarray([i for i in range(12) if i not in (5, 11)])
    src = rng.integers(0, 12, 80)
    dst = rng.choice(live, 80)
    g = from_coo(src, dst, n_src=12, n_dst=12)     # dst 5, 11 empty
    logits = jnp.asarray(rng.normal(size=(80, 3)).astype(np.float32))

    a = edge_softmax_composed(g, logits)
    b = edge_softmax_fused(g, logits)
    assert np.isfinite(np.asarray(a)).all()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)

    ga = jax.grad(lambda l: jnp.sum(edge_softmax_composed(g, l) ** 2))(
        logits)
    gb = jax.grad(lambda l: jnp.sum(edge_softmax_fused(g, l) ** 2))(
        logits)
    assert np.isfinite(np.asarray(ga)).all()
    assert np.isfinite(np.asarray(gb)).all()
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------- #
# fused attention (logits + leaky-relu + softmax + aggregate, one pass)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n,nnz,H,F", [(40, 200, 2, 8), (25, 90, 1, 4)])
def test_fused_attention_megakernel_matches_ref(n, nnz, H, F):
    rng = np.random.default_rng(nnz)
    g, _, _ = make_graph(rng, n, n, nnz)
    el = jnp.asarray(rng.normal(size=(n, H)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(n, H)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(n, H, F)).astype(np.float32))
    out = fused_attention_kernel(g, el, er, z)
    src_c = np.asarray(g.src)[np.asarray(g.eid_inv)]
    dst_c = np.asarray(g.dst)[np.asarray(g.eid_inv)]
    ref = fused_attention_ref(src_c, dst_c, el, er, z, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_fused_attention_strategies_match_multipass():
    """core.fused_attention (fused AND pallas) == the multipass
    composition (gsddmm logits → leaky → softmax → weighted gspmm),
    forward and backward, including zero-degree destinations."""
    from repro.core import gsddmm, gspmm
    from repro.substrate.nn import leaky_relu

    rng = np.random.default_rng(7)
    n, nnz, H, F = 30, 140, 2, 4
    live = np.asarray([i for i in range(n) if i != 13])
    src = rng.integers(0, n, nnz)
    dst = rng.choice(live, nnz)
    g = from_coo(src, dst, n_src=n, n_dst=n)       # dst 13 empty
    el = jnp.asarray(rng.normal(size=(n, H)).astype(np.float32))
    er = jnp.asarray(rng.normal(size=(n, H)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(n, H, F)).astype(np.float32))

    def multipass(el, er, z):
        logits = gsddmm(g, "u_add_v_copy_e", u=el, v=er)
        alpha = edge_softmax_composed(g, leaky_relu(logits))
        return gspmm(g, "u_mul_e_add_v", u=z, e=alpha[:, :, None])

    ref = multipass(el, er, z)
    ref_g = jax.grad(lambda a: jnp.sum(multipass(*a) ** 2))((el, er, z))
    for st in ("fused", "pallas"):
        out = fused_attention(g, el, er, z, strategy=st)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"output via {st}")
        out_g = jax.grad(lambda a: jnp.sum(
            fused_attention(g, *a, strategy=st) ** 2))((el, er, z))
        for got, want, nm in zip(out_g, ref_g, ("el", "er", "z")):
            assert np.isfinite(np.asarray(got)).all()
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"d/d{nm} via {st}")

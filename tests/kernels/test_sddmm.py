"""Tiled SDDMM Pallas kernel (kernels/sddmm) vs its ⊗-table oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.sddmm.ops import sddmm
from repro.kernels.sddmm.ref import sddmm_ref

OPS = ("add", "sub", "mul", "div", "dot", "copy")


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("E,d", [(100, 8), (257, 5), (16, 1)])
def test_sddmm_matches_ref(op, E, d):
    rng = np.random.default_rng(E + d)
    lhs = jnp.asarray(rng.uniform(0.5, 1.5, (E, d)).astype(np.float32))
    rhs = (None if op == "copy"
           else jnp.asarray(rng.uniform(0.5, 1.5, (E, d))
                            .astype(np.float32)))
    out = sddmm(lhs, rhs, op)
    ref = sddmm_ref(lhs, rhs, op)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("op", ("add", "mul", "div"))
def test_sddmm_width1_broadcast(op):
    """Width-1 operands broadcast against the wide side — the α-weight
    and softmax-divide shapes — with div-safe ones padding."""
    rng = np.random.default_rng(9)
    E, d = 77, 6
    lhs = jnp.asarray(rng.uniform(0.5, 1.5, (E, d)).astype(np.float32))
    rhs = jnp.asarray(rng.uniform(0.5, 1.5, (E, 1)).astype(np.float32))
    out = sddmm(lhs, rhs, op)
    ref = sddmm_ref(lhs, rhs, op)
    assert out.shape == (E, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_sddmm_dot_keepdims():
    rng = np.random.default_rng(10)
    lhs = jnp.asarray(rng.normal(size=(50, 7)).astype(np.float32))
    rhs = jnp.asarray(rng.normal(size=(50, 7)).astype(np.float32))
    out = sddmm(lhs, rhs, "dot")
    assert out.shape == (50, 1)
    np.testing.assert_allclose(
        np.asarray(out),
        np.sum(np.asarray(lhs) * np.asarray(rhs), axis=-1,
               keepdims=True), rtol=1e-5, atol=1e-5)

"""Pallas SpMM (Copy-Reduce) kernel vs pure-jnp oracle — shape/dtype sweep."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import from_coo, build_tiles
from repro.kernels.spmm.ops import spmm
from repro.kernels.spmm.ref import spmm_ref

from ..conftest import make_graph

SHAPES = [
    (300, 200, 1500, 64),    # generic rectangular
    (64, 64, 200, 128),      # single tile pair
    (257, 130, 901, 33),     # ragged, non-tile-aligned everything
    (16, 16, 40, 300),       # wide features (multi N-tile)
    (500, 10, 2000, 8),      # high in-degree (bucket splitting)
    (10, 500, 400, 16),      # scatter-heavy
]


@pytest.mark.parametrize("n_u,n_v,nnz,d", SHAPES)
@pytest.mark.parametrize("reduce_op", ["sum", "mean"])
def test_spmm_matches_ref(n_u, n_v, nnz, d, reduce_op):
    rng = np.random.default_rng(42 + n_u)
    g, _, _ = make_graph(rng, n_u, n_v, nnz)
    B = jnp.asarray(rng.normal(size=(n_u, d)).astype(np.float32))
    out = spmm(g, B, reduce_op)
    ref = spmm_ref(g.src, g.dst, B, n_v, reduce_op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    rng = np.random.default_rng(7)
    g, _, _ = make_graph(rng, 130, 90, 600)
    B = jnp.asarray(rng.normal(size=(130, 64)), dtype=dtype)
    out = spmm(g, B, "sum")
    ref = spmm_ref(g.src, g.dst, B.astype(jnp.float32), 90, "sum")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_spmm_weighted():
    rng = np.random.default_rng(3)
    g, _, _ = make_graph(rng, 200, 150, 1200)
    B = jnp.asarray(rng.normal(size=(200, 48)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1200,)).astype(np.float32))
    out = spmm(g, B, "sum", weight=w)
    ref = spmm_ref(g.src, g.dst, B, 150, "sum", weight=jnp.take(w, g.eid))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_spmm_custom_tile_geometry():
    """Block-shape sweep: kernel must be correct for any tile geometry."""
    rng = np.random.default_rng(9)
    g, _, _ = make_graph(rng, 300, 300, 2500)
    B = jnp.asarray(rng.normal(size=(300, 70)).astype(np.float32))
    ref = spmm_ref(g.src, g.dst, B, 300, "sum")
    for (bm, bk, eb) in [(64, 64, 64), (128, 256, 512), (256, 128, 128),
                         (8, 8, 16)]:
        tiles = build_tiles(g, bm=bm, bk=bk, eb=eb)
        out = spmm(g, B, "sum", tiles=tiles)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"bm={bm} bk={bk} eb={eb}")


def test_spmm_empty_rows_zero():
    """Nodes with no incoming edges must read 0 (DGL semantics)."""
    g = from_coo([0, 1], [2, 2], n_src=3, n_dst=5)
    B = jnp.ones((3, 8), jnp.float32)
    out = np.asarray(spmm(g, B, "sum"))
    np.testing.assert_allclose(out[2], 2.0)
    np.testing.assert_allclose(out[[0, 1, 3, 4]], 0.0)

"""Kernel geometry must fit the v5e VMEM budget, and the autotuned
geometry must stay numerically correct."""
import numpy as np
import jax.numpy as jnp

from repro.core import from_coo, build_tiles
from repro.kernels.spmm.ops import spmm
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.vmem import (VMEM_BYTES, spmm_vmem_bytes, br_vmem_bytes,
                                edge_softmax_vmem_bytes,
                                pick_spmm_geometry)

from ..conftest import make_graph


def test_default_geometry_fits_vmem():
    # the ops.py defaults: bm=bk=128, eb=256, nd=128
    assert spmm_vmem_bytes(128, 128, 256, 128) < VMEM_BYTES // 2
    assert br_vmem_bytes(128, 128, 256, 128) < VMEM_BYTES // 2
    assert edge_softmax_vmem_bytes(8, 1024, 8) < VMEM_BYTES // 2


def test_autotuner_respects_budget():
    for d in (32, 128, 512, 2048):
        g = pick_spmm_geometry(d)
        assert spmm_vmem_bytes(g["bm"], g["bk"], g["eb"], g["nd"]) \
            <= VMEM_BYTES // 2


def test_autotuned_geometry_correct():
    rng = np.random.default_rng(21)
    g, _, _ = make_graph(rng, 400, 300, 2000)
    B = jnp.asarray(rng.normal(size=(400, 96)).astype(np.float32))
    geo = pick_spmm_geometry(96)
    tiles = build_tiles(g, bm=geo["bm"], bk=geo["bk"], eb=geo["eb"])
    out = spmm(g, B, "sum", tiles=tiles, nd=geo["nd"])
    ref = spmm_ref(g.src, g.dst, B, 300, "sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

"""Checkpoint manager: atomic save, latest-good discovery, corruption
recovery, elastic restore semantics."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    s = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(s, 10)
    restored, step = mgr.restore_latest(jax.tree.map(jnp.zeros_like, s))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_good_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s1, s2 = _state(1), _state(2)
    mgr.save(s1, 1)
    mgr.save(s2, 2)
    # corrupt the newest checkpoint's weight file
    f = tmp_path / "step_2" / "params.w.npy"
    f.write_bytes(b"garbage")
    restored, step = mgr.restore_latest(jax.tree.map(jnp.zeros_like, s1))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s1["params"]["w"]))


def test_incomplete_checkpoint_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(s, 5)
    # simulate a crash mid-save: manifest says incomplete
    man = tmp_path / "step_9" ; man.mkdir()
    (man / "manifest.json").write_text(json.dumps({"complete": False,
                                                   "leaves": {}}))
    restored, step = mgr.restore_latest(jax.tree.map(jnp.zeros_like, s))
    assert step == 5


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for i in (1, 2, 3, 4):
        mgr.save(s, i)
    assert mgr.steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    s = _state()
    save_pytree(s, str(tmp_path / "x"))
    bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        load_pytree(bad, str(tmp_path / "x"))


def test_train_resume_cli(tmp_path):
    """End-to-end: train 6 steps, kill, resume from checkpoint, finish."""
    import subprocess, sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "llama3p2_3b", "--smoke", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--log-every", "1"]
    r1 = subprocess.run(base + ["--steps", "4"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(base + ["--steps", "6"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout
    assert "step=5" in r2.stdout

"""Multi-device tests (ring Copy-Reduce, sharded train step).

These re-exec themselves in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps seeing the single real CPU device. The runner converts
emulation crashes (signal death) into skips — see tests/conftest.py.
"""
import pytest

from tests.conftest import run_multidevice

_RING_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import from_coo
from repro.core.partition import (build_partition, ring_gspmm,
                                  ring_reference)
from repro.kernels.spmm.ref import spmm_ref

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
n, nnz, d = 64, 400, 16
src = rng.integers(0, n, nnz); dst = rng.integers(0, n, nnz)
g = from_coo(src, dst, n_src=n, n_dst=n)
# uniform mode: the historical id // rows layout, padded row i == vertex i
plan = build_partition(g, 8, "uniform")
w = jnp.where(plan.mask, 1.0, 0.0).astype(jnp.float32)   # CR-sum weights
x = np.zeros((plan.n_pad, d), np.float32)
x[:n] = rng.normal(size=(n, d))
out = ring_gspmm(plan, jnp.asarray(x), w, mesh=mesh)
ref = ring_reference(plan, jnp.asarray(x))
err = np.abs(np.asarray(out) - np.asarray(ref)).max()
assert err < 1e-4, f"ring vs padded-oracle err={err}"
oracle = spmm_ref(g.src, g.dst, jnp.asarray(x[:n]), n, "sum")
err2 = np.abs(np.asarray(out)[:n] - np.asarray(oracle)).max()
assert err2 < 1e-4, f"ring vs graph-oracle err={err2}"
hlo = jax.jit(lambda x: ring_gspmm(plan, x, w, mesh=mesh)).lower(
    jnp.asarray(x)).compile().as_text()
assert "collective-permute" in hlo, "ring must lower to collective-permute"
print("RING_OK")
"""

_SHARDED_TRAIN_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.launch import shardings as SR
from repro.launch.steps import TrainState, make_train_step, init_state
from repro.launch.train import synthetic_batch
from repro.pjit_utils import ambient_mesh

cfg = get_smoke_config("qwen2_7b")
mesh = make_mesh((2, 4), ("data", "model"))
state = init_state(jax.random.PRNGKey(0), cfg)
specs = SR.param_specs(state.params, cfg, mesh)
sh = SR.to_named(TrainState(specs, specs, specs,
                            jax.sharding.PartitionSpec()), mesh)
state = jax.device_put(state, sh)
step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
with ambient_mesh(mesh):
    losses = []
    for i in range(3):
        batch = synthetic_batch(cfg, i, 4, 32)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
assert all(np.isfinite(l) for l in losses), losses
# single-device reference: same math, no mesh
state1 = init_state(jax.random.PRNGKey(0), cfg)
step1 = jax.jit(make_train_step(cfg), donate_argnums=(0,))
l1 = []
for i in range(3):
    batch = synthetic_batch(cfg, i, 4, 32)
    state1, m1 = step1(state1, batch)
    l1.append(float(m1["loss"]))
err = max(abs(a - b) for a, b in zip(losses, l1))
assert err < 5e-2, f"sharded vs single-device loss drift {err}: {losses} {l1}"
print("SHARDED_TRAIN_OK")
"""


def test_ring_copy_reduce_8dev():
    r = run_multidevice(_RING_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RING_OK" in r.stdout


def test_sharded_train_matches_single_device():
    r = run_multidevice(_SHARDED_TRAIN_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_TRAIN_OK" in r.stdout

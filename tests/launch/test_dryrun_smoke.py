"""Dry-run integration smoke: lower+compile representative cells on a
debug mesh (subprocess; full configs, 8 fake devices)."""
import json
import os
import subprocess
import sys

import pytest

CASES = [
    ("llama3p2_3b", "train_4k", []),           # dense train
    ("mamba2_1p3b", "long_500k", []),          # SSM long-context decode
    ("whisper_medium", "prefill_32k", []),     # enc-dec serve
    ("mixtral_8x22b", "decode_32k", []),       # MoE + SWA decode
]


@pytest.mark.parametrize("arch,shape,extra", CASES,
                         ids=[c[0] + ":" + c[1] for c in CASES])
def test_cell_compiles_on_debug_mesh(tmp_path, arch, shape, extra):
    out = tmp_path / "cell.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["REPRO_DRYRUN_MESH"] = "2x4"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(out)] + extra
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    cell = json.loads(out.read_text())
    assert cell["ok"]
    assert cell["tripaware"]["flops_hlo"] > 0
    assert cell["cost_analysis"].get("flops", 0) > 0


def test_hlo_analysis_trip_counts():
    """The analyzer must multiply while-loop bodies by their trip count."""
    import jax, jax.numpy as jnp
    from repro.launch import hlo_analysis

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    txt = (jax.jit(f)
           .lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
           .compile().as_text())
    res = hlo_analysis.analyze(txt)
    expect = 7 * 2 * 64 * 64 * 64
    assert abs(res["flops_hlo"] - expect) / expect < 0.05, res["flops_hlo"]

"""Elastic restart (mesh-shape change across restore) + grad compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.compression import (int8_compress, int8_decompress,
                                     quantize_with_feedback,
                                     compressed_allreduce_terms)
from tests.conftest import run_multidevice


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 3)
    q, s = int8_compress(x)
    y = int8_decompress(q, s, x.shape, jnp.float32)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.01  # blockwise int8: <1% relative error on gaussians


def test_error_feedback_unbiased_over_time():
    """With EF, the accumulated applied updates converge to the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    applied = np.zeros(512, np.float32)
    resid = jnp.zeros(512, jnp.float32)
    for step in range(30):
        g = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
        true_sum += np.asarray(g)
        q, s, resid = quantize_with_feedback(g, resid)
        applied += np.asarray(int8_decompress(q, s, g.shape, jnp.float32))
    # applied = true_sum - residual  (residual bounded, doesn't grow)
    err = np.abs(true_sum - applied).max()
    assert err < 0.5, err
    assert float(jnp.abs(resid).max()) < 0.5


def test_compression_ratio():
    params = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((1024,))}
    raw, comp = compressed_allreduce_terms(params)
    assert raw / comp > 3.8  # int8 + one f32 scale per 256 values


def test_compression_ratio_mixed_dtypes():
    """Regression: raw bytes must follow each leaf's itemsize (the old
    accounting hardcoded 4 bytes/element, overstating bf16 savings 2x)."""
    n = 1024 * 256
    raw_bf16, comp = compressed_allreduce_terms(
        {"w": jnp.zeros((n,), jnp.bfloat16)})
    assert raw_bf16 == 2 * n
    raw_f32, _ = compressed_allreduce_terms({"w": jnp.zeros((n,))})
    assert raw_f32 == 4 * n
    # same wire format either way: int8 payload + per-block f32 scales
    assert comp == n + (n // 256) * 4
    assert raw_bf16 / comp < 2.0      # bf16 sources compress < 2x
    assert raw_f32 / comp > 3.8


def test_error_feedback_unbiased_jit_bf16():
    """EF stays unbiased when the producer runs under jit on bf16 grads
    (the mixed-precision training path): accumulated applied updates
    track the true fp32 sum, residual stays bounded."""
    @jax.jit
    def qstep(g, resid):
        q, s, resid = quantize_with_feedback(g.astype(jnp.float32), resid)
        return int8_decompress(q, s, g.shape, jnp.float32), resid

    rng = np.random.default_rng(2)
    true_sum = np.zeros(512, np.float64)
    applied = np.zeros(512, np.float64)
    resid = jnp.zeros(512, jnp.float32)
    for step in range(30):
        g = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
        g16 = g.astype(jnp.bfloat16)
        # the true signal is what bf16 delivered, not the fp32 draw
        true_sum += np.asarray(g16, np.float64)
        deq, resid = qstep(g16, resid)
        applied += np.asarray(deq, np.float64)
    assert np.abs(true_sum - applied).max() < 0.5
    assert float(jnp.abs(resid).max()) < 0.5


def test_bf16_gcn_loss_tracks_fp32():
    """Differential: bf16 compute + fp32 masters must follow the fp32
    loss trajectory step for step within a small tolerance (DESIGN.md
    §12 documents 2e-2 on the smoke graphs)."""
    from repro.core.graph import from_coo
    from repro.models.gnn import gcn
    from repro.models.gnn.common import make_bundle
    from repro.models.gnn.train import train_full_graph

    rng = np.random.default_rng(3)
    n, m, d, c = 80, 400, 16, 5
    g = from_coo(rng.integers(0, n, m), rng.integers(0, n, m),
                 n_src=n, n_dst=n)
    bundle = make_bundle(g)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.integers(0, c, n).astype(np.int32)
    mask = np.ones(n, bool)
    params = gcn.init(jax.random.PRNGKey(0), d, 8, c)
    _, h32 = train_full_graph(gcn.forward, params, bundle, x, y, mask,
                              epochs=6, precision="fp32")
    _, h16 = train_full_graph(gcn.forward, params, bundle, x, y, mask,
                              epochs=6, precision="bf16")
    per_step = np.abs(np.asarray(h32["loss"]) - np.asarray(h16["loss"]))
    assert per_step.max() < 2e-2, per_step
    # and the trajectory actually descends in both precisions
    assert h16["loss"][-1] < h16["loss"][0]


_ELASTIC_PROG = r"""
import os, sys
ckpt = sys.argv[1]
phase = sys.argv[2]
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.launch import shardings as SR
from repro.launch.steps import TrainState, make_train_step, init_state
from repro.launch.train import synthetic_batch
from repro.checkpoint import CheckpointManager
from repro.pjit_utils import ambient_mesh

cfg = get_smoke_config("llama3p2_3b")
mesh = make_mesh((2, 4), ("data", "model")) if phase == "save" \
    else make_mesh((4, 2), ("data", "model"))    # DIFFERENT mesh on restore
specs = None
mgr = CheckpointManager(ckpt)
state = init_state(jax.random.PRNGKey(0), cfg)
pspec = SR.param_specs(state.params, cfg, mesh)
sh = SR.to_named(TrainState(pspec, pspec, pspec,
                            jax.sharding.PartitionSpec()), mesh)
if phase == "save":
    state = jax.device_put(state, sh)
    step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    with ambient_mesh(mesh):
        for i in range(2):
            state, m = step(state, synthetic_batch(cfg, i, 4, 32))
    mgr.save(state, 2)
    print("SAVED", float(m["loss"]))
else:
    restored = mgr.restore_latest(state, shardings=sh)
    assert restored is not None
    state, step_no = restored
    assert step_no == 2
    # verify leaves landed with the new mesh's sharding
    some = state.params["blocks"]["attn"]["wq"]
    assert some.sharding.mesh.shape["data"] == 4
    step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    with ambient_mesh(mesh):
        state, m = step(state, synthetic_batch(cfg, 2, 4, 32))
    assert np.isfinite(float(m["loss"]))
    print("RESTORED_OK", float(m["loss"]))
"""


def test_elastic_restart_different_mesh(tmp_path):
    """Save on a (2,4) mesh, restore + train on a (4,2) mesh."""
    r1 = run_multidevice(_ELASTIC_PROG, str(tmp_path), "save")
    assert r1.returncode == 0, r1.stderr[-3000:]
    assert "SAVED" in r1.stdout
    r2 = run_multidevice(_ELASTIC_PROG, str(tmp_path), "restore")
    assert r2.returncode == 0, r2.stderr[-3000:]
    assert "RESTORED_OK" in r2.stdout

"""Elastic restart (mesh-shape change across restore) + grad compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.compression import (int8_compress, int8_decompress,
                                     quantize_with_feedback,
                                     compressed_allreduce_terms)
from tests.conftest import run_multidevice


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 3)
    q, s = int8_compress(x)
    y = int8_decompress(q, s, x.shape, jnp.float32)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.01  # blockwise int8: <1% relative error on gaussians


def test_error_feedback_unbiased_over_time():
    """With EF, the accumulated applied updates converge to the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    applied = np.zeros(512, np.float32)
    resid = jnp.zeros(512, jnp.float32)
    for step in range(30):
        g = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
        true_sum += np.asarray(g)
        q, s, resid = quantize_with_feedback(g, resid)
        applied += np.asarray(int8_decompress(q, s, g.shape, jnp.float32))
    # applied = true_sum - residual  (residual bounded, doesn't grow)
    err = np.abs(true_sum - applied).max()
    assert err < 0.5, err
    assert float(jnp.abs(resid).max()) < 0.5


def test_compression_ratio():
    params = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((1024,))}
    raw, comp = compressed_allreduce_terms(params)
    assert raw / comp > 3.8  # int8 + one f32 scale per 256 values


_ELASTIC_PROG = r"""
import os, sys
ckpt = sys.argv[1]
phase = sys.argv[2]
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.launch import shardings as SR
from repro.launch.steps import TrainState, make_train_step, init_state
from repro.launch.train import synthetic_batch
from repro.checkpoint import CheckpointManager
from repro.pjit_utils import ambient_mesh

cfg = get_smoke_config("llama3p2_3b")
mesh = make_mesh((2, 4), ("data", "model")) if phase == "save" \
    else make_mesh((4, 2), ("data", "model"))    # DIFFERENT mesh on restore
specs = None
mgr = CheckpointManager(ckpt)
state = init_state(jax.random.PRNGKey(0), cfg)
pspec = SR.param_specs(state.params, cfg, mesh)
sh = SR.to_named(TrainState(pspec, pspec, pspec,
                            jax.sharding.PartitionSpec()), mesh)
if phase == "save":
    state = jax.device_put(state, sh)
    step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    with ambient_mesh(mesh):
        for i in range(2):
            state, m = step(state, synthetic_batch(cfg, i, 4, 32))
    mgr.save(state, 2)
    print("SAVED", float(m["loss"]))
else:
    restored = mgr.restore_latest(state, shardings=sh)
    assert restored is not None
    state, step_no = restored
    assert step_no == 2
    # verify leaves landed with the new mesh's sharding
    some = state.params["blocks"]["attn"]["wq"]
    assert some.sharding.mesh.shape["data"] == 4
    step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    with ambient_mesh(mesh):
        state, m = step(state, synthetic_batch(cfg, 2, 4, 32))
    assert np.isfinite(float(m["loss"]))
    print("RESTORED_OK", float(m["loss"]))
"""


def test_elastic_restart_different_mesh(tmp_path):
    """Save on a (2,4) mesh, restore + train on a (4,2) mesh."""
    r1 = run_multidevice(_ELASTIC_PROG, str(tmp_path), "save")
    assert r1.returncode == 0, r1.stderr[-3000:]
    assert "SAVED" in r1.stdout
    r2 = run_multidevice(_ELASTIC_PROG, str(tmp_path), "restore")
    assert r2.returncode == 0, r2.stderr[-3000:]
    assert "RESTORED_OK" in r2.stdout

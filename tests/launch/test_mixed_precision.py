"""Mixed-precision training + int8-compressed ring exchanges.

Covers the DESIGN.md §12 acceptance surface: bf16 compute with fp32
master weights matches the fp32 loss trajectory on all three GNN apps,
the 4-shard emulated ring moves ≥3x fewer bytes under ``comm="int8"``
(measured through the obs metrics registry, not asserted from the
format), compressed exchanges stay accurate + differentiable through
the straight-through estimator, and the planner's cost model makes at
least one auto decision differently at bf16 than at fp32.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import planner
from repro.core.graph import from_coo
from repro.core.partition import ring_gspmm, ring_reference
from repro.models.gnn import gcn, sage, gat
from repro.models.gnn.common import make_bundle
from repro.models.gnn.train import train_full_graph, train_partitioned
from repro.obs import metrics as M
from repro.optim import Precision
from tests.conftest import run_multidevice


def _graph(seed=0, n=80, m=400):
    rng = np.random.default_rng(seed)
    g = from_coo(rng.integers(0, n, m), rng.integers(0, n, m),
                 n_src=n, n_dst=n)
    x = rng.standard_normal((n, 16)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    return g, x, y, np.ones(n, bool)


# ------------------------------------------------------------------ #
# bf16 + fp32 masters track fp32 (all three apps, full graph)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("app,forward,init", [
    ("gcn", gcn.forward, lambda k, d, c: gcn.init(k, d, 8, c)),
    ("sage", sage.forward, lambda k, d, c: sage.init(k, d, 8, c)),
    ("gat", gat.forward, lambda k, d, c: gat.init(k, d, 8, c, n_heads=2)),
])
def test_bf16_final_loss_matches_fp32(app, forward, init):
    g, x, y, mask = _graph()
    bundle = make_bundle(g)
    params = init(jax.random.PRNGKey(0), x.shape[1], 4)
    _, h32 = train_full_graph(forward, params, bundle, x, y, mask,
                              epochs=6, precision="fp32")
    _, h16 = train_full_graph(forward, params, bundle, x, y, mask,
                              epochs=6, precision="bf16")
    # documented tolerance (DESIGN.md §12): 2e-2 final-loss delta
    assert abs(h32["loss"][-1] - h16["loss"][-1]) < 2e-2, (app, h32, h16)
    if app != "gat":    # GAT's dropout-heavy trajectory is non-monotone
        assert h16["loss"][-1] < h16["loss"][0]


# ------------------------------------------------------------------ #
# compressed ring exchange: bytes, accuracy, gradients
# ------------------------------------------------------------------ #
def test_int8_ring_exchange_bytes_shrink_3x():
    """The acceptance gate: 4-shard emulated ring, fp32 features,
    wire bytes measured by the metrics registry shrink ≥3x."""
    g, x, _, _ = _graph(n=96, m=600)
    pg = planner.get_plan_cache(g).partition(4, "contiguous")
    xp = pg.scatter_nodes(jnp.asarray(x))
    w = jnp.where(pg.mask, 1.0, 0.0)
    resid = jnp.zeros_like(xp)
    prev = M.set_enabled(True)
    try:
        M.reset_metrics()
        out, _ = ring_gspmm(pg, xp, w, comm="int8", residual=resid)
        jax.block_until_ready(out)
        snap = M.snapshot()
    finally:
        M.set_enabled(prev)
    raw = snap["comm.ring.raw_bytes"]["value"]
    wire = snap["comm.ring.wire_bytes"]["value"]
    assert raw > 0 and wire > 0
    assert raw / wire >= 3.0, (raw, wire)


def test_int8_ring_output_close_and_ef_converges():
    """One compressed exchange is already <2% off; with the error
    feedback carried across calls the bias washes out."""
    g, x, _, _ = _graph(n=96, m=600)
    pg = planner.get_plan_cache(g).partition(4, "contiguous")
    xp = pg.scatter_nodes(jnp.asarray(x))
    w = jnp.where(pg.mask, 1.0, 0.0)
    ref = ring_reference(pg, xp, w)
    resid = jnp.zeros_like(xp)
    out, resid = ring_gspmm(pg, xp, w, comm="int8", residual=resid)
    denom = float(jnp.linalg.norm(ref)) or 1.0
    assert float(jnp.linalg.norm(out - ref)) / denom < 0.02
    # second exchange of the SAME payload: EF corrects last step's error
    out2, resid = ring_gspmm(pg, xp, w, comm="int8", residual=resid)
    avg = (out + out2) / 2
    assert (float(jnp.linalg.norm(avg - ref)) / denom
            < float(jnp.linalg.norm(out - ref)) / denom + 1e-6)
    assert bool(jnp.all(jnp.isfinite(resid)))


def test_int8_ring_gradients_flow_straight_through():
    g, x, _, _ = _graph(n=64, m=300)
    pg = planner.get_plan_cache(g).partition(2, "contiguous")
    xp = pg.scatter_nodes(jnp.asarray(x))
    w = jnp.where(pg.mask, 1.0, 0.0)
    resid = jnp.zeros_like(xp)

    def f(z):
        out, _ = ring_gspmm(pg, z, w, comm="int8", residual=resid)
        return jnp.sum(out ** 2)

    def f_ref(z):
        return jnp.sum(ring_reference(pg, z, w) ** 2)

    gq = jax.grad(f)(xp)
    gr = jax.grad(f_ref)(xp)
    assert bool(jnp.all(jnp.isfinite(gq)))
    # straight-through: the quantizer is identity to autodiff, so the
    # gradient matches the uncompressed ring's to quantization error
    denom = float(jnp.linalg.norm(gr)) or 1.0
    assert float(jnp.linalg.norm(gq - gr)) / denom < 0.05


# ------------------------------------------------------------------ #
# partitioned training end-to-end under precision x compression
# ------------------------------------------------------------------ #
def test_partitioned_bf16_int8_trains_and_matches_fp32():
    g, x, y, mask = _graph()
    params = gcn.init(jax.random.PRNGKey(0), x.shape[1], 8, 4)
    _, h32 = train_partitioned(gcn.forward_partitioned, params, g, x, y,
                               mask, n_shards=4, epochs=5,
                               precision="fp32")
    _, hq = train_partitioned(
        gcn.forward_partitioned, params, g, x, y, mask, n_shards=4,
        epochs=5, precision=Precision.parse("bf16", comm="int8"),
        init_comm_fn=gcn.init_comm)
    assert abs(h32["loss"][-1] - hq["loss"][-1]) < 2e-2, (h32, hq)
    assert hq["loss"][-1] < hq["loss"][0]


def test_partitioned_int8_needs_init_comm_fn():
    g, x, y, mask = _graph()
    params = gcn.init(jax.random.PRNGKey(0), x.shape[1], 8, 4)
    with pytest.raises(ValueError, match="init_comm_fn"):
        train_partitioned(gcn.forward_partitioned, params, g, x, y, mask,
                          n_shards=2, epochs=1,
                          precision=Precision.parse("bf16", comm="int8"))


def test_gat_partitioned_rejects_comm_state():
    g, x, y, mask = _graph()
    params = gat.init(jax.random.PRNGKey(0), x.shape[1], 8, 4, n_heads=2)
    pg = planner.get_plan_cache(g).partition(2, "contiguous")
    from repro.models.gnn.common import make_partitioned_bundle
    pb = make_partitioned_bundle(g, 2)
    with pytest.raises(ValueError, match="compressed-comm"):
        gat.forward_partitioned(params, pb, pg.scatter_nodes(jnp.asarray(x)),
                                comm_state=())


# ------------------------------------------------------------------ #
# dtype-aware planning
# ------------------------------------------------------------------ #
def test_planner_auto_flips_with_dtype():
    """On a pad_ratio ≈ 3.2 shape the ell/segment break-even sits
    between the fp32 (≈2.9) and bf16 (≈3.9) thresholds: auto picks
    segment at fp32 and blocked-pull ell at bf16."""
    stats = planner.GraphStats(
        n_src=20000, n_dst=20000, n_edges=200000, avg_in_deg=10.0,
        max_in_deg=640, skew=64.0, ell_padded_slots=640000,
        ell_n_classes=4, pad_ratio=3.2)
    d = 64
    c32 = {s: planner.estimate_cost(s, stats, d, backend="cpu",
                                    dtype=jnp.float32)
           for s in ("segment", "ell")}
    c16 = {s: planner.estimate_cost(s, stats, d, backend="cpu",
                                    dtype=jnp.bfloat16)
           for s in ("segment", "ell")}
    assert min(c32, key=c32.get) == "segment", c32
    assert min(c16, key=c16.get) == "ell", c16


def test_ring_comm_term_priced_at_wire_bytes():
    """int8 wire pricing lowers the ring estimate for fp32 payloads —
    the compression term, not the throughput row, moves the cost."""
    stats = planner.GraphStats(
        n_src=20000, n_dst=20000, n_edges=200000, avg_in_deg=10.0,
        max_in_deg=640, skew=64.0, ell_padded_slots=640000,
        ell_n_classes=4, pad_ratio=3.2)
    raw = planner.estimate_cost("ring", stats, 64, backend="cpu",
                                dtype=jnp.float32, comm="none")
    comp = planner.estimate_cost("ring", stats, 64, backend="cpu",
                                 dtype=jnp.float32, comm="int8")
    assert comp < raw


def test_plan_events_record_dtype_end_to_end():
    from repro.core import gspmm
    from repro.obs import events as obs
    obs.clear_events()
    try:
        g, x, _, _ = _graph(seed=7)
        out = gspmm(g, "u_copy_add_v", u=jnp.asarray(x, jnp.bfloat16))
        jax.block_until_ready(out)
        assert out.dtype == jnp.bfloat16    # no silent promotion back
        rows = [r for r in obs.plan_events() if r["op"] == "u_copy_add_v"]
        assert any(r["dtype"] == "bfloat16" for r in rows), rows
    finally:
        obs.clear_events()


# ------------------------------------------------------------------ #
# the CI leg: 2-shard emulated mesh, bf16 + int8, loss decreases
# ------------------------------------------------------------------ #
_MESH_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core.graph import from_coo
from repro.models.gnn import gcn
from repro.models.gnn.train import train_partitioned
from repro.launch.mesh import make_mesh
from repro.optim import Precision

rng = np.random.default_rng(0)
n, m, d, c = 80, 400, 16, 4
g = from_coo(rng.integers(0, n, m), rng.integers(0, n, m), n_src=n, n_dst=n)
x = rng.standard_normal((n, d)).astype(np.float32)
y = rng.integers(0, c, n).astype(np.int32)
mask = np.ones(n, bool)
mesh = make_mesh((2,), ("data",))
params = gcn.init(jax.random.PRNGKey(0), d, 8, c)
params, hist = train_partitioned(
    gcn.forward_partitioned, params, g, x, y, mask, n_shards=2,
    mesh=mesh, epochs=4, precision=Precision.parse("bf16", comm="int8"),
    init_comm_fn=gcn.init_comm)
losses = hist["loss"]
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
flat, _ = jax.tree_util.tree_flatten(params)
assert all(bool(jnp.all(jnp.isfinite(p))) for p in flat)
print("MESH_BF16_INT8_OK", losses[0], losses[-1])
"""


def test_mesh_bf16_int8_train_leg():
    r = run_multidevice(_MESH_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_BF16_INT8_OK" in r.stdout

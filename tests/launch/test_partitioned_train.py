"""Multi-device partitioned-graph execution (acceptance tests).

Each test re-execs a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process keeps the single real CPU device) via
:func:`tests.conftest.run_multidevice`, which converts host-platform
emulation crashes (signal death) into skips.

Covers the ISSUE-3 acceptance criteria: partitioned forward AND
gradients match single-device full-graph execution for GCN, SAGE and
GAT at 2/4/8 emulated shards; ``strategy="auto"`` selects ``ring`` only
when a mesh is active and falls back cleanly otherwise; the partitioned
train loop (exact and delayed-halo) runs on the mesh.
"""
import pytest

from tests.conftest import run_multidevice

_APP_PROG = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from repro.core import from_coo
from repro.launch.mesh import make_shard_mesh
from repro.models.gnn import gcn, sage, gat
from repro.models.gnn.common import make_bundle, make_partitioned_bundle
from repro.substrate.nn import cross_entropy_loss

mod = {"gcn": gcn, "sage": sage, "gat": gat}[sys.argv[1]]
rng = np.random.default_rng(0)
n, nnz, d, nc = 64, 400, 8, 3
src = rng.integers(0, n, nnz); dst = rng.integers(0, n, nnz)
g = from_coo(src, dst, n_src=n, n_dst=n)
x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
labels = jnp.asarray(rng.integers(0, nc, n).astype(np.int32))
mask = jnp.asarray(rng.random(n) < 0.6)
bundle = make_bundle(g)
params = mod.init(jax.random.PRNGKey(0), d, 8, nc)
ref = mod.forward(params, bundle, x)
gref = ravel_pytree(jax.grad(lambda p: cross_entropy_loss(
    mod.forward(p, bundle, x), labels, mask))(params))[0]
for S in (2, 4, 8):
    mesh = make_shard_mesh(S)
    pb = make_partitioned_bundle(g, S, mesh=mesh)
    pg = pb.pg
    xp = pg.scatter_nodes(x)
    out, _ = mod.forward_partitioned(params, pb, xp)
    err = np.abs(np.asarray(pg.gather_nodes(out)) - np.asarray(ref)).max()
    assert err < 2e-4, f"S={S} forward err={err}"
    yp = pg.scatter_nodes(labels); mp = pg.scatter_nodes(mask)
    gp = ravel_pytree(jax.grad(lambda p: cross_entropy_loss(
        mod.forward_partitioned(p, pb, xp)[0], yp, mp))(params))[0]
    gerr = np.abs(np.asarray(gp) - np.asarray(gref)).max()
    assert gerr < 2e-4, f"S={S} grad err={gerr}"
    print(f"S={S} fwd={err:.2e} grad={gerr:.2e}")
print("APP_OK")
"""

_AUTO_RING_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import from_coo, gspmm, planner, use_ring

rng = np.random.default_rng(0)
n, nnz = 4096, 40000
g = from_coo(rng.integers(0, n, nnz), rng.integers(0, n, nnz),
             n_src=n, n_dst=n)
X = jnp.asarray(rng.normal(size=(n, 64)).astype(np.float32))
ref = gspmm(g, "u_copy_add_v", u=X, strategy="segment")
mesh = jax.make_mesh((8,), ("data",))
with use_ring(mesh):
    out = gspmm(g, "u_copy_add_v", u=X)        # auto
    assert planner.last_plan("u_copy_add_v") == "ring", \
        planner.last_plan("u_copy_add_v")
    err = np.abs(np.asarray(out) - np.asarray(ref)).max()
    assert err < 1e-3, err
# outside the context auto must NOT pick ring, and stays correct
out = gspmm(g, "u_copy_add_v", u=X)
assert planner.last_plan("u_copy_add_v") != "ring"
err = np.abs(np.asarray(out) - np.asarray(ref)).max()
assert err < 1e-3, err
print("AUTO_RING_OK")
"""

_TRAIN_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import from_coo
from repro.launch.mesh import make_shard_mesh
from repro.models.gnn import gcn
from repro.models.gnn.common import make_bundle
from repro.models.gnn.train import train_full_graph, train_partitioned

rng = np.random.default_rng(0)
n, nnz, d, nc = 64, 400, 8, 3
g = from_coo(rng.integers(0, n, nnz), rng.integers(0, n, nnz),
             n_src=n, n_dst=n)
x = rng.normal(size=(n, d)).astype(np.float32)
labels = rng.integers(0, nc, n)
mask = rng.random(n) < 0.6
params = gcn.init(jax.random.PRNGKey(1), d, 8, nc)
mesh = make_shard_mesh(4)
_, hp = train_partitioned(gcn.forward_partitioned, params, g, x, labels,
                          mask, n_shards=4, mesh=mesh, epochs=3,
                          drop=0.0, seed=0)
# single-device reference: same step math (dropout off), no mesh
fw = lambda p, b, xx, **kw: gcn.forward(p, b, xx, drop=0.0, **kw)
_, h1 = train_full_graph(fw, params, make_bundle(g), x, labels, mask,
                         epochs=3, seed=0)
drift = max(abs(a - b) for a, b in zip(hp["loss"], h1["loss"]))
assert drift < 1e-3, f"partitioned vs single-device loss drift {drift}"
# delayed halo: refresh every 2nd epoch, losses stay finite
_, hd = train_partitioned(gcn.forward_partitioned, params, g, x, labels,
                          mask, n_shards=4, mesh=mesh, epochs=4,
                          drop=0.0, halo_staleness=2,
                          init_halo_fn=gcn.init_halo, seed=0)
assert all(np.isfinite(l) for l in hd["loss"]), hd["loss"]
assert hd["refreshed"] == [True, False, True, False]
print("TRAIN_OK")
"""


@pytest.mark.parametrize("app", ["gcn", "sage", "gat"])
def test_partitioned_matches_single_device_2_4_8(app):
    r = run_multidevice(_APP_PROG, app)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "APP_OK" in r.stdout


def test_auto_selects_ring_only_with_mesh():
    r = run_multidevice(_AUTO_RING_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "AUTO_RING_OK" in r.stdout


def test_train_partitioned_exact_and_delayed():
    r = run_multidevice(_TRAIN_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "TRAIN_OK" in r.stdout

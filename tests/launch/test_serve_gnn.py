"""Differential serving parity (DESIGN.md §10).

Every serve path is pinned to the training-path full-graph forward it
must reproduce: for each app (GCN/SAGE/GAT/RGCN) and each serve mode
(layer-wise, full-neighbor fan-out), micro-batched served predictions
equal the direct full forward to 1e-5 — across batch splits, request
orderings, and duplicate node ids inside one batch.

The graph is built with a small uniform in-degree so full-neighbor
fan-out blocks stay tiny (the DEFAULT fanout is the max in-degree,
which makes the fan-out path exact, not approximate).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GNNServer, from_coo
from repro.data import RequestQueue
from repro.models.gnn import gat, gcn, rgcn, sage
from repro.models.gnn.common import make_bundle

N, D_IN, D_HID, K_IN = 100, 8, 8, 4
CLASSES = (4, 16)
APPS = ("gcn", "sage", "gat", "rgcn")
MODES = ("layerwise", "fanout")
TOL = 1e-5


def _square_graph(rng, n=N, k=K_IN):
    """Every node gets exactly ``k`` in-edges → max in-degree is k and
    the full-neighbor fan-out expansion stays small."""
    src = rng.integers(0, n, (n, k)).reshape(-1)
    dst = np.repeat(np.arange(n), k)
    return from_coo(src, dst, n_src=n, n_dst=n)


_built = {}


def _setup(app):
    """(server-ctor kwargs, reference full-forward logits) per app —
    built once, shared by every mode/parametrization."""
    if app in _built:
        return _built[app]
    rng = np.random.default_rng(17)
    key = jax.random.PRNGKey(17)
    feats = rng.standard_normal((N, D_IN)).astype(np.float32)
    if app == "rgcn":
        n_rel = 3
        rels = [(rng.integers(0, N, N * 2), rng.integers(0, N, N * 2))
                for _ in range(n_rel)]
        params = rgcn.init(key, D_IN, D_HID, 5, n_rel)
        ref = rgcn.infer(params, rgcn.build_relgraph(rels, N),
                         jnp.asarray(feats))
        kw = dict(g=None, rels=rels)
    else:
        g = _square_graph(rng)
        mod = {"gcn": gcn, "sage": sage, "gat": gat}[app]
        params = mod.init(key, D_IN, D_HID, 5)
        ref = mod.infer(params, make_bundle(g), jnp.asarray(feats))
        kw = dict(g=g)
    _built[app] = (app, params, feats, kw, np.asarray(ref))
    return _built[app]


_servers = {}


def _server(app, mode):
    if (app, mode) not in _servers:
        name, params, feats, kw, _ = _setup(app)
        _servers[(app, mode)] = GNNServer(name, params, feats=feats,
                                          mode=mode, classes=CLASSES,
                                          cache_rows=32, pin_hot=8, **kw)
    return _servers[(app, mode)]


def _check(app, mode, requests):
    *_, ref = _setup(app)
    srv = _server(app, mode)
    out = srv.serve(requests)
    for rid, ids in requests:
        got = out[rid]
        assert got.shape == (len(np.atleast_1d(ids)), ref.shape[1])
        np.testing.assert_allclose(got, ref[np.asarray(ids)], atol=TOL,
                                   err_msg=f"{app}/{mode} rid={rid}")


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("app", APPS)
def test_served_equals_full_forward(app, mode):
    rng = np.random.default_rng(3)
    _check(app, mode, [(0, rng.integers(0, N, 6))])


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("app", APPS)
def test_parity_across_batch_splits(app, mode):
    ids = np.random.default_rng(4).integers(0, N, 12)
    # one request, many small requests, and uneven splits — all equal
    _check(app, mode, [(0, ids)])
    _check(app, mode, [(i, ids[i:i + 1]) for i in range(len(ids))])
    _check(app, mode, [(0, ids[:5]), (1, ids[5:7]), (2, ids[7:])])


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("app", APPS)
def test_parity_across_request_orderings(app, mode):
    rng = np.random.default_rng(5)
    ids = rng.integers(0, N, 9)
    for _ in range(3):
        perm = rng.permutation(len(ids))
        _check(app, mode, [(0, ids[perm])])


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("app", APPS)
def test_parity_with_duplicate_ids_in_one_batch(app, mode):
    ids = np.array([7, 7, 3, 99, 3, 7, 0, 0])
    _check(app, mode, [(0, ids)])
    # duplicates across requests coalesced into the SAME batch too
    _check(app, mode, [(0, [7, 3, 7]), (1, [3, 3]), (2, [7])])


def test_zero_steady_state_recompiles_all_apps():
    for app in APPS:
        for mode in MODES:
            srv = _server(app, mode)
            srv.warmup()
            before = srv.compiles
            rng = np.random.default_rng(6)
            for i in range(10):
                srv.serve([(i, rng.integers(0, N, rng.integers(1, 17)))])
            assert srv.compiles == before, f"{app}/{mode} recompiled"
            srv.tracker.assert_bounded()


def test_plan_log_has_serve_rows():
    from repro.core import planner
    _server("gcn", "layerwise").serve([(0, [1])])
    log = planner.plan_log()
    assert any(name == "serve:infer" for name, *_ in log)


def test_mode_auto_resolves_per_planner():
    app, params, feats, kw, _ = _setup("gcn")
    srv = GNNServer(app, params, feats=feats, classes=CLASSES,
                    cache_rows=32, pin_hot=8, **kw)
    for cls in CLASSES:
        assert srv.mode_for_class(cls) in MODES
    # tiny graph + tiny fanout: re-using the full-graph table wins
    assert srv.mode_for_class(CLASSES[0]) == "layerwise"


def test_update_features_invalidates_served_table():
    app, params, feats, kw, _ = _setup("gcn")
    srv = GNNServer(app, params, feats=feats.copy(), mode="layerwise",
                    classes=CLASSES, cache_rows=32, pin_hot=8, **kw)
    ids = np.arange(10)
    before = srv.serve([(0, ids)])[0]
    srv.update_features([2], 10 + feats[2])
    after = srv.serve([(1, ids)])[1]
    # node 2's feature reaches its OWN row and its out-neighbors' rows;
    # nothing is served from the pre-update table
    ref = np.asarray(gcn.infer(params, make_bundle(kw["g"]),
                               jnp.asarray(srv.feats)))
    np.testing.assert_allclose(after, ref[ids], atol=TOL)
    assert not np.allclose(before, after, atol=TOL)


def test_end_to_end_request_queue_session():
    """Concurrent requesters through RequestQueue + prefetcher: every
    future resolves to full-forward parity."""
    app, params, feats, kw, ref = _setup("gcn")
    srv = _server("gcn", "layerwise")
    rq = RequestQueue(max_wait=0.001)
    results = {}

    def client(cid):
        rng = np.random.default_rng(cid)
        for j in range(5):
            ids = rng.integers(0, N, rng.integers(1, 9))
            results[(cid, j)] = (ids, rq.submit(ids))

    threads = [threading.Thread(target=client, args=(c,)) for c in range(3)]
    for t in threads:
        t.start()

    def close_when_done():
        for t in threads:
            t.join()
        rq.close()

    threading.Thread(target=close_when_done).start()
    srv.run(rq)
    assert len(results) == 15
    for ids, req in results.values():
        np.testing.assert_allclose(req.result(timeout=5), ref[ids],
                                   atol=TOL)

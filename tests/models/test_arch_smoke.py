"""Per-architecture smoke tests (reduced same-family configs).

For each of the 10 assigned archs: instantiate the reduced config, run one
train-loss evaluation + gradient, and exercise the serve path
(prefill + 2 decode steps), asserting shapes and finiteness.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models.lm import (init_params, loss_fn, prefill, decode_step,
                             init_cache, encode)


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        pos = np.broadcast_to(np.arange(S), (3, B, S)).copy()
        batch["positions"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_and_grad(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    # sane CE magnitude for random init: ~ log(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    B, S, MAX = 2, 8, 16
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    memory = None
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(
            size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
        memory = encode(params, cfg, frames)
    cache = init_cache(cfg, B, MAX, jnp.float32)
    positions = None
    if cfg.family == "vlm":
        positions = jnp.asarray(
            np.broadcast_to(np.arange(S), (3, B, S)).copy())
    logits, cache = prefill(params, cfg, tokens, cache,
                            positions=positions, memory=memory)
    assert logits.shape == (B, cfg.vocab), arch
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits, -1)
    for step in range(2):
        logits, cache = decode_step(params, cfg, tok, cache,
                                    jnp.asarray(S + step), memory=memory)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        tok = jnp.argmax(logits, -1)


@pytest.mark.parametrize("arch", ["mamba2_1p3b", "zamba2_2p7b"])
def test_ssm_decode_matches_prefill(arch):
    """Chunked-prefill then decode == longer prefill (state consistency)."""
    cfg = get_smoke_config(arch)
    B, S, MAX = 1, 8, 16
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX)
    rng = np.random.default_rng(2)
    tokens = np.asarray(rng.integers(0, cfg.vocab, (B, S + 1)))

    c1 = init_cache(cfg, B, MAX, jnp.float32)
    logits_full, _ = prefill(params, cfg, jnp.asarray(tokens), c1)

    c2 = init_cache(cfg, B, MAX, jnp.float32)
    _, c2 = prefill(params, cfg, jnp.asarray(tokens[:, :S]), c2)
    logits_step, _ = decode_step(params, cfg, jnp.asarray(tokens[:, S]),
                                 c2, jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_published_scale():
    """Analytic param counts should land near the published sizes."""
    from repro.configs import get_config
    expect = {
        "qwen2_7b": (7.6e9, 0.15), "qwen2p5_14b": (14.8e9, 0.15),
        "llama3p2_3b": (3.2e9, 0.25), "internlm2_20b": (19.9e9, 0.15),
        "mixtral_8x22b": (141e9, 0.15), "mamba2_1p3b": (1.3e9, 0.3),
        "zamba2_2p7b": (2.7e9, 0.35), "whisper_medium": (0.76e9, 0.35),
        "qwen2_vl_2b": (1.5e9, 0.35), "granite_moe_3b": (3.3e9, 0.4),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, \
            f"{arch}: {n/1e9:.2f}B vs {target/1e9:.2f}B"

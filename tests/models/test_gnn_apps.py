"""Integration tests: all 7 paper applications train and losses decrease."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import from_coo
from repro.data import (make_node_dataset, sbm_graph, bipartite_ratings,
                        relational_graph, NeighborSampler)
from repro.models.gnn import (gcn, sage, gat, monet, rgcn, gcmc, lgnn,
                              make_bundle)
from repro.models.gnn.train import train_full_graph
from repro.substrate.nn import cross_entropy_loss


@pytest.fixture(scope="module")
def tiny():
    g, feats, labels, tm, vm, nc = make_node_dataset("tiny")
    # krel=3 prebuilds MoNet's 3-kernel RelGraph so its fused
    # per-kernel aggregation serves the jitted train step
    return g, feats, labels, tm, vm, nc, make_bundle(g, tiles=True,
                                                     krel=3)


@pytest.mark.parametrize("mod", [gcn, sage, gat],
                         ids=["gcn", "sage", "gat"])
def test_node_classifiers_train(tiny, mod):
    g, feats, labels, tm, vm, nc, bundle = tiny
    params = mod.init(jax.random.PRNGKey(0), feats.shape[1], 32, nc)
    params, hist = train_full_graph(mod.forward, params, bundle, feats,
                                    labels, tm, epochs=4)
    assert hist["loss"][-1] < hist["loss"][0]
    assert np.isfinite(hist["loss"]).all()


def test_monet_trains(tiny):
    """MoNet, deflaked: at lr=1e-2 the Gaussian-kernel parameters (μ, σ)
    oscillate for the first ~4 epochs (loss 3.27 → 3.31 was the observed
    flake), so train at lr=3e-3 — monotone on every seed probed — for 6
    epochs with a FIXED init/dropout seed. The 1e-3 tolerance only
    absorbs cross-platform reduction-order jitter; the expected drop is
    ≥ 1.2 nats, so the margin is ~3 orders below the signal."""
    g, feats, labels, tm, vm, nc, bundle = tiny
    params = monet.init(jax.random.PRNGKey(0), feats.shape[1], 32, nc)
    params, hist = train_full_graph(monet.forward, params, bundle, feats,
                                    labels, tm, epochs=6, lr=3e-3, seed=0)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0] + 1e-3


@pytest.mark.parametrize("strategy", ["push", "segment", "ell", "pallas"])
def test_gcn_strategies_equal(tiny, strategy):
    g, feats, labels, tm, vm, nc, bundle = tiny
    params = gcn.init(jax.random.PRNGKey(1), feats.shape[1], 16, nc)
    ref = gcn.forward(params, bundle, jnp.asarray(feats),
                      strategy="segment")
    out = gcn.forward(params, bundle, jnp.asarray(feats), strategy=strategy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-4)


def test_gat_fused_softmax_matches(tiny):
    g, feats, labels, tm, vm, nc, bundle = tiny
    params = gat.init(jax.random.PRNGKey(2), feats.shape[1], 16, nc)
    a = gat.forward(params, bundle, jnp.asarray(feats), fused_softmax=False)
    b = gat.forward(params, bundle, jnp.asarray(feats), fused_softmax=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_rgcn_trains():
    """R-GCN trains through the fused RelGraph path, and strategy='auto'
    matches the pre-refactor per-relation loop's logits to ≤2e-4
    (acceptance criterion)."""
    rels = relational_graph(150, 4, 300, seed=1)
    rg = rgcn.build_relgraph(rels, 150)
    rgs = [from_coo(s, d, n_src=150, n_dst=150) for s, d in rels]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(150, 12)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 3, 150))
    params = rgcn.init(jax.random.PRNGKey(0), 12, 16, 3, n_rel=4)

    # fused-vs-loop logits parity, before and after a train step
    np.testing.assert_allclose(
        np.asarray(rgcn.forward(params, rg, x)),
        np.asarray(rgcn.forward_loop(params, rgs, x)), atol=2e-4)

    def loss_fn(p):
        return cross_entropy_loss(rgcn.forward(p, rg, x), labels)

    l0 = float(loss_fn(params))
    g = jax.grad(loss_fn)(params)
    params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    assert float(loss_fn(params)) < l0
    np.testing.assert_allclose(
        np.asarray(rgcn.forward(params, rg, x)),
        np.asarray(rgcn.forward_loop(params, rgs, x)), atol=2e-4)


def test_rgcn_sampled_training():
    """R-GCN trains sampled through run_blocks/train_sampled: the
    relational sampler tags every sampled edge with its relation id and
    the block layer fuses all relations per block."""
    from repro.data import NeighborSampler
    from repro.models.gnn.train import train_sampled

    n, n_rel = 200, 5
    rels = relational_graph(n, n_rel, 400, seed=4)
    gm, rel_ids = rgcn.merged_graph(rels, n)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 12)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    ids = np.arange(n)
    sampler = NeighborSampler(gm, fanouts=[4, 4], batch_size=32,
                              seed=0, edge_rel=rel_ids)
    params = rgcn.init(jax.random.PRNGKey(0), 12, 16, 3, n_rel=n_rel)
    params, hist = train_sampled(rgcn.forward_blocks, params, gm, feats,
                                 labels, ids, fanouts=(4, 4),
                                 batch_size=32, epochs=4, lr=1e-2,
                                 seed=0, sampler=sampler)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]


def test_rgcn_sampled_full_fanout_matches_full_graph():
    """With fanout ≥ max in-degree the sampled relational block forward
    equals the full-graph fused forward on the seed rows."""
    from repro.data import NeighborSampler

    n, n_rel = 80, 3
    rels = relational_graph(n, n_rel, 120, seed=6)
    rg = rgcn.build_relgraph(rels, n)
    gm, rel_ids = rgcn.merged_graph(rels, n)
    maxdeg = int(np.asarray(gm.in_degrees).max())
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    params = rgcn.init(jax.random.PRNGKey(1), 8, 12, 3, n_rel=n_rel)
    full = rgcn.forward(params, rg, x)

    batch = 16
    sampler = NeighborSampler(gm, fanouts=[maxdeg, maxdeg],
                              batch_size=batch, seed=0,
                              edge_rel=rel_ids)
    seeds = rng.permutation(n)[:batch]
    mb = sampler.sample(seeds, np.zeros(batch, np.int64))
    xz = jnp.vstack([x, jnp.zeros((1, x.shape[1]), jnp.float32)])
    ids = jnp.asarray(mb.input_ids)
    h = jnp.take(xz, jnp.where(ids >= 0, ids, n), axis=0)
    out = rgcn.forward_blocks(params, mb.blocks, h)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full)[seeds],
                               rtol=1e-4, atol=1e-4)


def test_gcmc_trains():
    """GC-MC trains through the two fused RelGraphs, and strategy='auto'
    matches the pre-refactor per-level loop's logits to ≤2e-4
    (acceptance criterion)."""
    u, i, r = bipartite_ratings(80, 60, 300, 5, seed=2)
    rg_fwd, rg_bwd = gcmc.build_level_relgraphs(u, i, r, 80, 60, 5)
    fwd, bwd = gcmc.build_level_graphs(u, i, r, 80, 60, 5)
    g_all = from_coo(u, i, n_src=80, n_dst=60)
    params = gcmc.init(jax.random.PRNGKey(0), 80, 60, 24, 12, 5)
    xu, xi = jnp.eye(80), jnp.eye(60)
    labels = jnp.asarray(r)

    np.testing.assert_allclose(
        np.asarray(gcmc.forward(params, (rg_fwd, rg_bwd, g_all), xu, xi)),
        np.asarray(gcmc.forward(params, (fwd, bwd, g_all), xu, xi)),
        atol=2e-4)

    def loss_fn(p):
        return cross_entropy_loss(
            gcmc.forward(p, (rg_fwd, rg_bwd, g_all), xu, xi), labels)

    l0 = float(loss_fn(params))
    grads = jax.grad(loss_fn)(params)
    params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, grads)
    assert float(loss_fn(params)) < l0
    np.testing.assert_allclose(
        np.asarray(gcmc.forward(params, (rg_fwd, rg_bwd, g_all), xu, xi)),
        np.asarray(gcmc.forward(params, (fwd, bwd, g_all), xu, xi)),
        atol=2e-4)


def test_lgnn_forward_and_grad():
    src, dst, comm = sbm_graph(100, 2, 0.25, 0.03, seed=3)
    g = from_coo(src, dst, n_src=100, n_dst=100)
    lg = lgnn.build_line_graph(g)
    rg = lgnn.build_relgraph(g, lg)
    params = lgnn.init(jax.random.PRNGKey(0), 100, 8, 16, 2)
    labels = jnp.asarray(comm)

    # fused 3-relation pass matches the three-call reference
    ref, _ = lgnn.forward(params, g, lg)
    out, _ = lgnn.forward(params, g, lg, rg=rg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-4)

    def loss_fn(p):
        logits, _ = lgnn.forward(p, g, lg, rg=rg)
        return cross_entropy_loss(logits, labels)

    l0 = float(loss_fn(params))
    grads = jax.grad(loss_fn)(params)
    gn = sum(float(jnp.abs(x).sum())
             for x in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(l0) and gn > 0
    # embedding table must receive gradient through the CR backward
    assert float(jnp.abs(grads["embed"]).sum()) > 0


def test_edge_output_ops_planned(tiny):
    """Acceptance: every edge-output op in GAT/GCMC/LGNN rides the
    planned gSDDMM layer (``sddmm:<op>`` rows, requested='auto') and
    the fused GAT pipeline logs its single ``attn:fused`` row — and the
    fused pipeline matches the multipass layering."""
    from repro.core import planner
    from repro.data import bipartite_ratings, sbm_graph

    g, feats, labels, tm, vm, nc, bundle = tiny
    params = gat.init(jax.random.PRNGKey(2), feats.shape[1], 16, nc)
    a = gat.forward(params, bundle, jnp.asarray(feats), attn="multipass")
    b = gat.forward(params, bundle, jnp.asarray(feats), attn="fused")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)

    u, i, r = bipartite_ratings(40, 30, 150, 3, seed=4)
    rg_fwd, rg_bwd = gcmc.build_level_relgraphs(u, i, r, 40, 30, 3)
    g_all = from_coo(u, i, n_src=40, n_dst=30)
    gp = gcmc.init(jax.random.PRNGKey(0), 40, 30, 8, 6, 3)
    gcmc.forward(gp, (rg_fwd, rg_bwd, g_all), jnp.eye(40), jnp.eye(30))

    src, dst, comm = sbm_graph(40, 2, 0.3, 0.05, seed=5)
    gl = from_coo(src, dst, n_src=40, n_dst=40)
    lgr = lgnn.build_line_graph(gl)
    lp = lgnn.init(jax.random.PRNGKey(0), 40, 4, 8, 2)
    lgnn.forward(lp, gl, lgr)

    log = planner.plan_log()
    # GAT logits + LGNN's Pᵀ endpoint sums; GCMC's bilinear decode
    assert ("sddmm:u_add_v_copy_e", "auto") in log
    assert ("sddmm:u_dot_v_add_e", "auto") in log
    assert any(k[0] == "attn:fused" for k in log)


@pytest.mark.parametrize("mod", [sage, gcn, gat],
                         ids=["sage", "gcn", "gat"])
def test_sampled_training_end_to_end(tiny, mod):
    """Acceptance: sampled minibatch training under ONE jitted step with
    strategy='auto' for ≥ 3 apps — loss finite and decreasing, block
    plans recorded by the shape-keyed planner."""
    from repro.core import planner
    from repro.models.gnn.train import train_sampled

    g, feats, labels, tm, vm, nc, bundle = tiny
    ids = np.nonzero(tm)[0]
    params = mod.init(jax.random.PRNGKey(0), feats.shape[1], 16, nc)
    params, hist = train_sampled(mod.forward_blocks, params, g, feats,
                                 labels, ids, fanouts=(4, 4),
                                 batch_size=64, strategy="auto",
                                 epochs=5, lr=1e-2, seed=0)
    assert np.isfinite(hist["loss"]).all()
    assert hist["loss"][-1] < hist["loss"][0]
    # the planner planned block ops (auto), not a silent pinned fallback
    assert any(k[0].startswith("block:") and k[1] == "auto"
               for k in planner.plan_log())


def test_sampled_sage_static_shapes():
    g, feats, labels, tm, vm, nc = make_node_dataset("tiny")
    fz = np.vstack([feats, np.zeros((1, feats.shape[1]), np.float32)])
    feats_j = jnp.asarray(fz)
    sampler = NeighborSampler(g, fanouts=[5, 5], batch_size=16)
    params = sage.init(jax.random.PRNGKey(0), feats.shape[1], 16, nc)

    def feats_fn(ids):
        safe = jnp.where(jnp.asarray(ids) >= 0, jnp.asarray(ids),
                         feats_j.shape[0] - 1)
        return jnp.take(feats_j, safe, axis=0)

    ids = np.nonzero(tm)[0]
    shapes = set()
    for n, mb in enumerate(sampler.batches(ids, labels[ids])):
        out = sage.forward_sampled(params, mb.blocks, feats_fn,
                                   batch_size=16)
        assert out.shape == (16, nc)
        shapes.add(tuple(b.graph.n_edges for b in mb.blocks))
        if n >= 2:
            break
    assert len(shapes) == 1  # static shapes -> one jit compilation

"""Property tests for the LM stack's numerical invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis installed")
from hypothesis import given, settings, strategies as st

from repro.models.lm.layers import (rope_angles, apply_rope,
                                    blockwise_attention)
from repro.models.lm.mamba2 import ssd_chunked


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]))
def test_rope_preserves_norm(seed, hd):
    """Rotation must preserve per-pair L2 norms (orthogonality)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 5, 3, hd)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, 1000, (2, 5)))
    ang = rope_angles(pos, hd, 1e4)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_relative_position_property():
    """<R(p)q, R(p+k)v> depends only on the offset k."""
    rng = np.random.default_rng(0)
    hd = 32
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 1, hd)).astype(np.float32))

    def score(p, k):
        aq = rope_angles(jnp.asarray([[p]]), hd, 1e4)
        av = rope_angles(jnp.asarray([[p + k]]), hd, 1e4)
        return float(jnp.sum(apply_rope(q, aq) * apply_rope(v, av)))

    assert abs(score(3, 7) - score(40, 7)) < 1e-3
    assert abs(score(0, 2) - score(100, 2)) < 1e-3


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_blockwise_attention_matches_dense(seed):
    """Online-softmax blockwise == dense softmax attention."""
    rng = np.random.default_rng(seed)
    B, S, H, Dh = 2, 37, 3, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    out = blockwise_attention(q, k, v, causal=True, block=8)

    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q),
                  np.asarray(k)) / np.sqrt(Dh)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("bhqk,bkhd->bqhd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


def test_blockwise_sliding_window():
    """window=w must equal dense attention with a banded mask."""
    rng = np.random.default_rng(1)
    B, S, H, Dh, W = 1, 29, 2, 8, 7
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    out = blockwise_attention(q, k, v, causal=True, window=W, block=8)

    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q),
                  np.asarray(k)) / np.sqrt(Dh)
    qi = np.arange(S)[:, None]
    ki = np.arange(S)[None, :]
    mask = (qi >= ki) & (qi - ki < W)
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("bhqk,bkhd->bqhd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("Q", [16, 32, 64])
def test_ssd_chunk_size_invariance(Q):
    """The chunked SSD scan must not depend on the chunk size."""
    rng = np.random.default_rng(2)
    B, S, H, P, N = 1, 48, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, S, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    y_ref, h_ref = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step h_t = exp(dt·A)h + dt·B xᵀ recurrence."""
    rng = np.random.default_rng(3)
    B, S, H, P, N = 1, 20, 1, 3, 5
    x = rng.normal(size=(B, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, (B, S, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    Bm = rng.normal(size=(B, S, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, N)).astype(np.float32)

    h = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                      # (B,H)
        Bx = np.einsum("bn,bhp->bhpn", Bm[:, t], x[:, t] * dt[:, t][..., None])
        h = h * dA[:, :, None, None] + Bx
        ys.append(np.einsum("bn,bhpn->bhp", Cm[:, t], h))
    ref = np.stack(ys, 1)                              # (B,S,H,P)

    y, h_last = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                            jnp.asarray(Bm), jnp.asarray(Cm), 8)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-3, atol=1e-4)

"""Metrics registry: thread safety, log2 bucket edges, disabled mode."""
import json
import math
import threading

import pytest

from repro import obs
from repro.obs.metrics import Histogram, MetricsRegistry


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.reset_metrics()
    yield
    obs.reset_metrics()


# ------------------------------------------------------------------ #
# thread safety — concurrent writers must not lose updates
# ------------------------------------------------------------------ #
def test_counter_concurrent_exact():
    c = obs.counter("t.concurrent")
    n_threads, per = 8, 10_000

    def worker():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per


def test_histogram_concurrent_exact_count():
    h = obs.histogram("t.hist_concurrent")
    n_threads, per = 8, 5_000

    def worker(seed):
        for i in range(per):
            h.observe(((seed + i) % 100 + 1) / 100.0)

    ts = [threading.Thread(target=worker, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert h.count == n_threads * per
    assert sum(n for _, n in h.buckets()) == n_threads * per


# ------------------------------------------------------------------ #
# histogram bucket boundaries — exact log2 edges via frexp
# ------------------------------------------------------------------ #
def test_bucket_index_power_of_two_edges():
    h = Histogram("t.edges")
    # 2^i lands in bucket i (half-open [2^i, 2^{i+1}))
    for i in (-20, -3, -1, 0, 1, 2):
        assert h.bucket_index(float(2.0 ** i)) == i
    # just under a power of two stays in the bucket below
    assert h.bucket_index(2.0 - 1e-12) == 0
    assert h.bucket_index(4.0 - 1e-12) == 1
    assert h.bucket_index(0.5 - 1e-12) == -2
    # out-of-range values clamp into the edge buckets
    assert h.bucket_index(float(2.0 ** 10)) == h.hi
    assert h.bucket_index(float(2.0 ** -30)) == h.lo


def test_bucket_index_matches_floor_log2():
    h = Histogram("t.floorlog")
    for v in (1e-6, 3.7e-4, 0.02, 0.3, 1.5, 7.0):
        assert h.bucket_index(v) == math.floor(math.log2(v))


def test_nonpositive_goes_to_underflow():
    h = Histogram("t.under")
    assert h.bucket_index(0.0) is None
    assert h.bucket_index(-1.0) is None
    h.observe(0.0)
    h.observe(-5.0)
    h.observe(1.0)
    assert h.count == 3
    rows = dict(h.buckets())
    assert rows[None] == 2          # underflow row
    assert rows[2.0 ** 0] == 1


def test_histogram_snapshot_roundtrips_json():
    h = obs.histogram("t.snap")
    for v in (0.001, 0.002, 0.004, 1.0):
        h.observe(v)
    snap = obs.snapshot()["t.snap"]
    assert snap["type"] == "histogram"
    assert snap["count"] == 4
    assert snap["min"] == pytest.approx(0.001)
    assert snap["max"] == pytest.approx(1.0)
    json.dumps(snap)                # must be JSON-serializable as-is


def test_histogram_quantile_bucketed():
    h = obs.histogram("t.quant")
    for _ in range(99):
        h.observe(0.001)            # bucket [2^-10, 2^-9)
    h.observe(10.0)                 # bucket [8, 16)
    # p50 reports the bucket's upper bound — within 2x of the true value
    assert h.quantile(0.5) <= 0.002
    assert h.quantile(0.99) <= 0.002
    assert h.quantile(1.0) >= 10.0


# ------------------------------------------------------------------ #
# disabled mode — a true no-op, not a cheap-op
# ------------------------------------------------------------------ #
def test_disabled_mode_is_noop():
    prev = obs.set_enabled(False)
    try:
        c = obs.counter("t.dead")
        g = obs.gauge("t.dead_gauge")
        h = obs.histogram("t.dead_hist")
        c.inc(100)
        g.set(3.0)
        h.observe(1.0)
        assert c.value == 0
        assert h.count == 0
        assert obs.snapshot() == {}
    finally:
        obs.set_enabled(prev)
    # the same names created while disabled never entered the registry
    assert "t.dead" not in obs.snapshot()


def test_disabled_instruments_are_shared_null():
    prev = obs.set_enabled(False)
    try:
        assert obs.counter("t.a") is obs.counter("t.b")
        assert obs.counter("t.a") is obs.histogram("t.c")
    finally:
        obs.set_enabled(prev)


def test_set_enabled_returns_previous():
    prev = obs.set_enabled(False)
    try:
        assert obs.set_enabled(True) is False
        assert obs.set_enabled(True) is True
    finally:
        obs.set_enabled(prev)


# ------------------------------------------------------------------ #
# registry semantics
# ------------------------------------------------------------------ #
def test_same_name_same_instrument():
    assert obs.counter("t.same") is obs.counter("t.same")


def test_type_mismatch_raises():
    obs.counter("t.typed")
    with pytest.raises(TypeError):
        obs.histogram("t.typed")


def test_fresh_registry_isolated():
    r = MetricsRegistry()
    r.counter("x").inc()
    assert "x" not in obs.snapshot()
    assert r.snapshot()["x"]["value"] == 1


# ------------------------------------------------------------------ #
# nearest-rank percentile (shared with launch.serve_gnn)
# ------------------------------------------------------------------ #
def test_percentile_nearest_rank():
    xs = list(range(1, 101))        # 1..100
    assert obs.percentile_nearest_rank(xs, 50) == 50
    assert obs.percentile_nearest_rank(xs, 99) == 99
    assert obs.percentile_nearest_rank(xs, 100) == 100
    assert obs.percentile_nearest_rank([7.0], 99) == 7.0
    # p99 of 100 samples is the 99th-smallest by nearest rank; the old
    # floor arithmetic in serve_gnn returned index 99 (the max) — and,
    # worse, p50 of 2 samples returned the larger one
    assert obs.percentile_nearest_rank([1.0, 9.0], 50) == 1.0


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        obs.percentile_nearest_rank([], 50)
    with pytest.raises(ValueError):
        obs.percentile_nearest_rank([1.0], 0)
    with pytest.raises(ValueError):
        obs.percentile_nearest_rank([1.0], 101)

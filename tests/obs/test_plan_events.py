"""Plan-event record format (golden schema) + drift report + e2e.

The schema tests PIN the record layout — BENCH_*.json consumers and
the CI artifacts read these dicts, so a field rename/removal is a
breaking change and must show up here, not downstream.
"""
import jax
import numpy as np
import pytest

from repro import obs
from repro.core import graph as G
from repro.core import planner
from repro.obs import events as E


@pytest.fixture(autouse=True)
def _clean_events():
    obs.clear_events()
    yield
    obs.clear_events()


# ------------------------------------------------------------------ #
# golden schema
# ------------------------------------------------------------------ #
def test_plan_event_fields_golden():
    assert obs.PLAN_EVENT_FIELDS == (
        "op", "family", "requested", "chosen", "count",
        "predicted_cost", "measured_calls", "measured_total_s",
        "measured_mean_s", "dtype")


def test_drift_fields_golden():
    assert obs.DRIFT_FIELDS == (
        "op", "family", "requested", "chosen", "predicted_cost",
        "measured_calls", "measured_mean_s", "family_scale", "ratio",
        "drifted", "dtype")


def test_plan_event_rows_have_exact_keys():
    obs.plan_event("block:u_copy_add_v", "auto", "segment",
                   predicted_cost=10.0)
    obs.measured_event("block:u_copy_add_v", 0.01)
    rows = obs.plan_events()
    assert len(rows) == 1
    assert tuple(rows[0].keys()) == obs.PLAN_EVENT_FIELDS
    r = rows[0]
    assert r["family"] == "block"
    assert r["count"] == 1
    assert r["measured_calls"] == 1
    assert r["measured_mean_s"] == pytest.approx(0.01)


def test_drift_rows_have_exact_keys():
    obs.plan_event("serve:infer", "auto", "layerwise", predicted_cost=5.0)
    obs.measured_event("serve:infer", 0.002)
    rows = planner.drift_report()
    assert len(rows) == 1
    assert tuple(rows[0].keys()) == obs.DRIFT_FIELDS


def test_plan_event_keyed_by_dtype():
    # same (op, requested, chosen) at two dtypes → two rows, not one
    obs.plan_event("block:u_copy_add_v", "auto", "segment",
                   predicted_cost=10.0, dtype="float32")
    obs.plan_event("block:u_copy_add_v", "auto", "segment",
                   predicted_cost=10.0, dtype="bfloat16")
    rows = obs.plan_events()
    assert len(rows) == 2
    assert {r["dtype"] for r in rows} == {"float32", "bfloat16"}
    assert all(r["count"] == 1 for r in rows)


def test_drift_scale_fit_per_family_dtype():
    # one family, two dtypes, 100x apart in time-per-cost: a shared
    # family scale would flag every row as drifted; per-(family, dtype)
    # scales fit each group on its own and flag none
    for i, cost in enumerate((10.0, 20.0, 40.0)):
        obs.plan_event(f"fam:f32op{i}", "auto", "a", predicted_cost=cost,
                       dtype="float32")
        obs.measured_event(f"fam:f32op{i}", cost * 1e-3)
        obs.plan_event(f"fam:b16op{i}", "auto", "a", predicted_cost=cost,
                       dtype="bfloat16")
        obs.measured_event(f"fam:b16op{i}", cost * 1e-1)
    rows = planner.drift_report(threshold=4.0)
    assert len(rows) == 6
    assert not any(r["drifted"] for r in rows)


def test_family_of():
    assert obs.family_of("u_copy_add_v") == "gspmm"
    assert obs.family_of("block:u_copy_add_v") == "block"
    assert obs.family_of("block_bwd:u_copy_add_v") == "block_bwd"
    assert obs.family_of("hetero:u_w_mean_v") == "hetero"
    assert obs.family_of("sddmm:u_add_v_copy_e") == "sddmm"
    assert obs.family_of("attn:fused") == "attn"
    assert obs.family_of("serve:infer") == "serve"


# ------------------------------------------------------------------ #
# drift semantics
# ------------------------------------------------------------------ #
def test_single_row_family_never_drifts():
    obs.plan_event("gone:x", "auto", "a", predicted_cost=100.0)
    obs.measured_event("gone:x", 1.0)
    (r,) = planner.drift_report()
    # the family scale is fit on this one row → ratio is exactly 1
    assert r["ratio"] == pytest.approx(1.0)
    assert not r["drifted"]


def test_outlier_within_family_drifts():
    # three ops whose measured/predicted agree, one 100x off
    for i, cost in enumerate((10.0, 20.0, 40.0)):
        op = f"fam:op{i}"
        obs.plan_event(op, "auto", "a", predicted_cost=cost)
        obs.measured_event(op, cost * 1e-3)
    obs.plan_event("fam:bad", "auto", "a", predicted_cost=10.0)
    obs.measured_event("fam:bad", 10.0 * 1e-3 * 100)
    rows = planner.drift_report(threshold=4.0)
    drifted = {r["op"] for r in rows if r["drifted"]}
    assert drifted == {"fam:bad"}
    # report is sorted worst-first
    assert rows[0]["op"] == "fam:bad"


def test_unmeasured_and_unpredicted_rows_excluded():
    obs.plan_event("fam:nopred", "auto", "a")            # no predicted
    obs.measured_event("fam:nopred", 0.01)
    obs.plan_event("fam:nomeas", "auto", "a", predicted_cost=3.0)
    assert planner.drift_report() == []


def test_drift_threshold_validated():
    with pytest.raises(ValueError):
        planner.drift_report(threshold=1.0)


def test_plan_event_disabled_noop():
    prev = obs.set_enabled(False)
    try:
        obs.plan_event("dead:x", "auto", "a", predicted_cost=1.0)
        obs.measured_event("dead:x", 1.0)
        E.timed("dead:x", lambda: 7)
    finally:
        obs.set_enabled(prev)
    assert obs.plan_events() == []


def test_timed_passes_value_through():
    assert E.timed("t:passthrough", lambda: 41 + 1) == 42
    rows = {r["op"]: r for r in obs.plan_events()}
    # measured-only ops don't appear in plan_events (no plan row) …
    assert "t:passthrough" not in rows
    # … but pair it with a plan row and the timing joins up
    obs.plan_event("t:passthrough", "auto", "x", predicted_cost=1.0)
    (r,) = obs.plan_events()
    assert r["measured_calls"] == 1


# ------------------------------------------------------------------ #
# e2e: the real planner paths emit predicted + measured rows
# ------------------------------------------------------------------ #
def test_gspmm_emits_predicted_and_measured():
    rng = np.random.default_rng(0)
    n, m = 64, 400
    g = G.from_coo(rng.integers(0, n, m), rng.integers(0, n, m),
                   n_src=n, n_dst=n)
    x = jax.numpy.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    from repro.core import gspmm
    out = gspmm(g, "u_copy_add_v", u=x)          # eager → timed
    jax.block_until_ready(out)
    rows = [r for r in obs.plan_events() if r["op"] == "u_copy_add_v"]
    assert rows, "gspmm plan row missing"
    assert any(r["predicted_cost"] is not None for r in rows)
    assert any(r["measured_calls"] > 0 for r in rows)
    drift_ops = {r["op"] for r in planner.drift_report()}
    assert "u_copy_add_v" in drift_ops


def test_sampled_train_emits_block_and_bwd_measurements():
    from repro.models.gnn import sage
    from repro.models.gnn.train import train_sampled
    rng = np.random.default_rng(0)
    n, m = 80, 300
    g = G.from_coo(rng.integers(0, n, m), rng.integers(0, n, m),
                   n_src=n, n_dst=n)
    feats = rng.standard_normal((n, 8)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    params = sage.init(jax.random.PRNGKey(0), 8, 8, 3)
    train_sampled(sage.forward_blocks, params, g, feats, labels,
                  np.arange(60), fanouts=(2, 2), batch_size=32,
                  epochs=1, max_batches=2)
    fams = {r["family"] for r in planner.drift_report()}
    assert "block" in fams
    assert "block_bwd" in fams

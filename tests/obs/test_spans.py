"""Span tracing: Chrome-trace export, nesting, fencing, coverage."""
import json
import time

import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs import spans as S


@pytest.fixture(autouse=True)
def _clean_trace():
    obs.clear_trace()
    yield
    obs.clear_trace()


def test_span_records_chrome_complete_event():
    with obs.span("unit.work", args={"k": 3}):
        time.sleep(0.001)
    (ev,) = obs.trace_events()
    assert ev["name"] == "unit.work"
    assert ev["ph"] == "X"                      # complete event
    assert ev["dur"] >= 1_000                   # ≥ 1ms in µs
    assert ev["args"]["k"] == 3
    assert ev["args"]["depth"] == 0
    assert isinstance(ev["ts"], (int, float))


def test_nesting_depth():
    with obs.span("outer"):
        with obs.span("inner"):
            pass
        with obs.span("inner2"):
            pass
    by_name = {e["name"]: e for e in obs.trace_events()}
    assert by_name["outer"]["args"]["depth"] == 0
    assert by_name["inner"]["args"]["depth"] == 1
    assert by_name["inner2"]["args"]["depth"] == 1
    # children close before the parent and nest inside its window
    out, inn = by_name["outer"], by_name["inner"]
    assert out["ts"] <= inn["ts"]
    assert inn["ts"] + inn["dur"] <= out["ts"] + out["dur"] + 1


def test_fence_blocks_on_device_value():
    with obs.span("unit.fenced") as sp:
        y = sp.fence(jnp.arange(512.0) * 2.0)
    assert float(y[1]) == 2.0                   # fence returns the value
    (ev,) = obs.trace_events()
    assert ev["name"] == "unit.fenced"


def test_export_chrome_trace_loads(tmp_path):
    with obs.span("a"):
        with obs.span("b"):
            pass
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list)
    assert len(doc["traceEvents"]) == 2
    for ev in doc["traceEvents"]:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(ev)


def test_disabled_records_nothing():
    prev = obs.set_enabled(False)
    try:
        with obs.span("dead") as sp:
            sp.fence(jnp.ones(4))
    finally:
        obs.set_enabled(prev)
    assert obs.trace_events() == []


def test_span_coverage_tiles():
    # two adjacent top-level spans covering the whole window
    with obs.span("s1"):
        time.sleep(0.002)
    with obs.span("s2"):
        time.sleep(0.002)
    cov = obs.span_coverage()
    assert cov > 0.5                            # tiny gap between spans
    # nested spans must not double-count: only depth-0 intervals union
    obs.clear_trace()
    with obs.span("outer"):
        with obs.span("inner"):
            time.sleep(0.002)
    assert obs.span_coverage() <= 1.0


def test_span_coverage_empty_is_zero():
    assert obs.span_coverage() == 0.0


def test_clear_trace():
    with obs.span("x"):
        pass
    assert len(obs.trace_events()) == 1
    obs.clear_trace()
    assert obs.trace_events() == []

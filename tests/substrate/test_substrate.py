"""Tests for the paper §4 framework primitives."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.substrate import (batchnorm1d_init, batchnorm1d_apply,
                             batchnorm1d_naive, embedding_init,
                             embedding_lookup, embedding_lookup_naive)


def test_batchnorm_matches_naive():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 10)).astype(np.float32) * 3 + 1)
    st = batchnorm1d_init(10)
    y_opt, _ = batchnorm1d_apply(st, x, train=True)
    y_naive = batchnorm1d_naive(st, x)
    np.testing.assert_allclose(np.asarray(y_opt), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-5)


def test_batchnorm_running_stats_and_eval():
    rng = np.random.default_rng(1)
    st = batchnorm1d_init(4)
    x = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32) * 2 + 5)
    for _ in range(20):
        _, st = batchnorm1d_apply(st, x, train=True, momentum=0.5)
    y, _ = batchnorm1d_apply(st, x, train=False)
    # after convergence of running stats, eval output ~ standardized
    assert abs(float(jnp.mean(y))) < 0.2
    assert abs(float(jnp.std(y)) - 1.0) < 0.2


def test_embedding_backward_is_copy_reduce():
    """CR backward == autodiff scatter backward, exactly."""
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, (3, 17)))
    ct = jnp.asarray(rng.normal(size=(3, 17, 8)).astype(np.float32))

    g_cr = jax.grad(lambda t: jnp.sum(embedding_lookup(t, ids) * ct))(table)
    g_ad = jax.grad(
        lambda t: jnp.sum(embedding_lookup_naive(t, ids) * ct))(table)
    np.testing.assert_allclose(np.asarray(g_cr), np.asarray(g_ad),
                               rtol=1e-5, atol=1e-5)


def test_embedding_forward_gather():
    key = jax.random.PRNGKey(0)
    table = embedding_init(key, 10, 4)
    ids = jnp.asarray([1, 1, 9])
    out = embedding_lookup(table, ids)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(table)[[1, 1, 9]])
